"""Guard: the committed architecture configs must match the assigned table
verbatim (layers, d_model, heads, GQA kv, d_ff, vocab, family extras)."""
import pytest

from repro.configs import ARCHS

# (family, L, d_model, H, kv, d_ff, vocab, extras)
ASSIGNED = {
    "recurrentgemma-9b": ("hybrid", 38, 4096, 16, 1, 12288, 256000,
                          {"window_size": 2048}),
    "gemma2-2b": ("dense", 26, 2304, 8, 4, 9216, 256000,
                  {"attention_pattern": "local_global", "logit_softcap": 30.0}),
    "mamba2-130m": ("ssm", 24, 768, 0, 0, 0, 50280, {"ssm_state": 128}),
    "llama3-405b": ("dense", 126, 16384, 128, 8, 53248, 128256, {}),
    "olmoe-1b-7b": ("moe", 16, 2048, 16, 16, 1024, 50304,
                    {"num_experts": 64, "experts_per_token": 8}),
    "granite-3-8b": ("dense", 40, 4096, 32, 8, 12800, 49155, {}),
    "hubert-xlarge": ("audio", 48, 1280, 16, 16, 5120, 504,
                      {"causal": False}),
    "granite-moe-1b-a400m": ("moe", 24, 1024, 16, 8, 512, 49155,
                             {"num_experts": 32, "experts_per_token": 8}),
    "internvl2-76b": ("vlm", 80, 8192, 64, 8, 28672, 128256,
                      {"num_patches": 256}),
    "granite-8b": ("dense", 36, 4096, 32, 8, 14336, 49152, {}),
}


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_config_matches_assignment(arch):
    cfg = ARCHS[arch]
    fam, L, d, H, kv, ff, V, extras = ASSIGNED[arch]
    assert cfg.family == fam
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    for k, v in extras.items():
        assert getattr(cfg, k) == v, (k, getattr(cfg, k), v)
    assert cfg.source, "every config must cite its source"


def test_all_ten_present():
    assert len(ARCHS) == 10
    families = {c.family for c in ARCHS.values()}
    assert families == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}
