"""Hypothesis property tests on the system's invariants (brief deliverable c):
communication operators, tree algebra, STORM telescoping, Neumann geometry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.tree_util import (client_mean, client_mean_grouped, tree_axpy,
                                  tree_sqnorm, tree_sub, tree_vdot)


@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([2, 4, 8]), d=st.integers(1, 16),
       seed=st.integers(0, 2**30))
def test_client_mean_idempotent_and_preserving(m, d, seed):
    """client_mean is an idempotent projection that preserves the total sum
    (conservation: averaging neither creates nor destroys mass)."""
    x = {"w": jax.random.normal(jax.random.PRNGKey(seed), (m, d))}
    once = client_mean(x)
    twice = client_mean(once)
    np.testing.assert_allclose(once["w"], twice["w"], atol=1e-6)
    np.testing.assert_allclose(jnp.sum(once["w"]), jnp.sum(x["w"]), rtol=1e-5)
    # all clients identical after averaging
    assert float(jnp.max(jnp.std(once["w"], axis=0))) < 1e-6


@settings(max_examples=20, deadline=None)
@given(groups=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**30))
def test_grouped_mean_composes_to_global(groups, seed):
    """grouped mean followed by global mean == global mean (hierarchical
    schedule consistency), and groups=1 degenerates to the global mean."""
    x = {"w": jax.random.normal(jax.random.PRNGKey(seed), (8, 5))}
    g = client_mean_grouped(x, groups)
    np.testing.assert_allclose(client_mean(g)["w"], client_mean(x)["w"],
                               atol=1e-6)
    if groups == 1:
        np.testing.assert_allclose(g["w"], client_mean(x)["w"], atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 32), seed=st.integers(0, 2**30))
def test_tree_algebra(n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = {"x": jax.random.normal(k1, (n,))}
    b = {"x": jax.random.normal(k2, (n,))}
    # polarisation identity: <a,b> = (|a+b|^2 - |a-b|^2) / 4
    lhs = float(tree_vdot(a, b))
    apb = tree_axpy(1.0, a, b)
    amb = tree_sub(b, a)
    rhs = float(tree_sqnorm(apb) - tree_sqnorm(amb)) / 4.0
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30), steps=st.integers(1, 10))
def test_storm_telescopes_to_sgd_in_deterministic_limit(seed, steps):
    """With zero gradient noise, the STORM estimator equals the plain
    gradient after every update (the correction telescopes away)."""
    from repro.kernels.storm.ref import storm_update_ref
    k = jax.random.PRNGKey(seed)
    p = jax.random.normal(k, (16,))
    m = jnp.zeros((16,))

    def grad(p):
        return 2.0 * p          # deterministic oracle

    err0 = float(jnp.linalg.norm(m - grad(p)))
    for _ in range(steps):
        g_new = grad(p - 0.1 * m)      # gradient at the post-update point
        g_old = grad(p)
        p, m = storm_update_ref(p, m, g_new, g_old, 0.1, 0.9)
    # m_{t} − g(p_{t}) = 0.9^t (m_0 − g(p_0)): geometric contraction
    err = float(jnp.linalg.norm(m - grad(p)))
    assert err <= 0.9 ** steps * err0 + 1e-5, (err, err0, steps)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_neumann_partial_sums_monotone(seed):
    """Neumann partial sums of (I − τA)^k form a monotone approximation of
    A⁻¹ in the A-norm for SPD A — the Eq. (6) estimator's bias shrinks with
    every extra term."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q, _ = jnp.linalg.qr(jax.random.normal(k1, (6, 6)))
    ev = jax.random.uniform(k2, (6,), minval=0.5, maxval=2.0)
    A = (q * ev) @ q.T
    v = jnp.ones((6,))
    tau = 0.4
    target = jnp.linalg.solve(A, v)
    acc = jnp.zeros((6,))
    term = v
    errs = []
    for _ in range(12):
        acc = acc + tau * term
        term = term - tau * (A @ term)
        errs.append(float(jnp.linalg.norm(acc - target)))
    assert all(e2 <= e1 + 1e-7 for e1, e2 in zip(errs, errs[1:])), errs
