"""Federated evaluation utilities: perplexity and the Eq. (5)
personalisation gain (private heads must beat the averaged head on their
own client's data after local training)."""
import jax
import jax.numpy as jnp

from repro.config import FederatedConfig
from repro.configs import ARCHS
from repro.data import make_fed_batch_fn
from repro.federation.evaluate import eval_federated, perplexity
from repro.federation.trainer import make_fedbio_local_train_step
from repro.models import build_model


def test_eval_federated_and_personalisation(rng):
    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    M = 3
    fed = FederatedConfig(num_clients=M, local_steps=2, lr_x=0.02, lr_y=0.3,
                          neumann_q=3, neumann_tau=0.3)
    init, step = make_fedbio_local_train_step(model, fed, n_micro=1,
                                              remat=False)
    state = init(rng)
    bf = make_fed_batch_fn(cfg, num_clients=M, per_client=2, seq_len=32,
                           hetero_alpha=0.1)
    out0 = eval_federated(model, state, bf, jax.random.PRNGKey(9),
                          num_clients=M)
    assert out0["perplexity_mean"] > 1.0
    assert len(out0["val_loss_per_client"]) == M

    jstep = jax.jit(step, donate_argnums=(0,))
    key = rng
    for _ in range(16):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, bf(sub))
    out = eval_federated(model, state, bf, jax.random.PRNGKey(9),
                         num_clients=M)
    assert out["val_loss_mean"] < out0["val_loss_mean"]
    # trained private heads should not be worse than the averaged head
    assert out["personalisation_gain_mean"] > -1e-3, out


def test_perplexity_matches_loss(rng):
    cfg = ARCHS["granite-8b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    p = model.init(rng)
    bf = make_fed_batch_fn(cfg, num_clients=1, per_client=2, seq_len=16)
    b = jax.tree.map(lambda v: v[0], bf(rng)["val"])
    loss, _ = model.loss(p, b)
    ppl = perplexity(model, p["body"], p["head"], b)
    assert abs(ppl - float(jnp.exp(loss))) < 1e-2 * ppl
