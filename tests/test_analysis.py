"""Self-tests of ``repro.analysis`` — every rule demonstrated to FIRE.

A static verifier earns trust the same way a test suite does: by failing
on seeded violations.  Each rule here gets a mutation that must trip it —
an extra psum smuggled into the step, an f32 wire under an int8 policy, an
`np.random` call in source, a non-frozen spec dataclass — and the clean
builds/tree must stay silent.  Mutations enter through the public seams
only (a wrapped step function, a fabricated HLO-stats record, a
monkeypatched module boundary); the engine source is never edited.
"""
import os

import jax
import jax.numpy as jnp
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.analysis import LINT_RULES, RULES
from repro.analysis import collectives as coll
from repro.analysis import structure as struct
from repro.analysis.lint import lint_paths, lint_source
from repro.api import Experiment, build

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _spec(name):
    return Experiment.load(os.path.join(_ROOT, "experiments", name))


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

def test_registry_complete():
    assert set(LINT_RULES) == {r for r in RULES if r.startswith("L")}
    for r, rule in RULES.items():
        assert rule.id == r
        assert rule.proves and rule.fixit, r


# ---------------------------------------------------------------------------
# L3xx: the source lint, one seeded violation per rule
# ---------------------------------------------------------------------------

def test_l301_wall_clock_fires():
    src = "import time\nt = time.perf_counter()\n"
    assert _rules(lint_source(src, "x.py")) == {"L301"}


def test_l301_pragma_waives():
    src = ("import time\n"
           "t = time.time()  # analysis: ignore[L301] driver\n")
    assert lint_source(src, "x.py") == []


def test_l302_np_random_fires():
    src = "import numpy as np\nv = np.random.rand(3)\n"
    assert _rules(lint_source(src, "x.py")) == {"L302"}


def test_l302_stdlib_random_fires():
    src = "import random\nv = random.random()\n"
    fs = lint_source(src, "x.py")
    assert _rules(fs) == {"L302"} and len(fs) == 2   # import + call


def test_l303_host_sync_fires_in_engine_only():
    src = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return float(jnp.mean(x)) + x.sum().item()\n")
    assert _rules(lint_source(src, "x.py", engine=True)) == {"L303"}
    assert lint_source(src, "x.py", engine=False) == []


def test_l304_key_chain_fires_in_round_loop():
    src = ("import jax\n"
           "def f(key):\n"
           "    k1, k2 = jax.random.split(key)\n"
           "    return k1\n")
    assert _rules(lint_source(src, "x.py", round_loop=True)) == {"L304"}
    assert lint_source(src, "x.py", round_loop=False) == []
    # fold_in-pure and spec-seeded forms pass
    ok = ("import jax\n"
          "def g(spec, r):\n"
          "    return jax.random.fold_in(jax.random.PRNGKey(spec.seed), r)\n")
    assert lint_source(ok, "x.py", round_loop=True) == []


def test_l305_unfrozen_spec_fires():
    src = ("from dataclasses import dataclass\n"
           "@dataclass\n"
           "class FooSpec:\n"
           "    a: int = 0\n")
    assert _rules(lint_source(src, "x.py")) == {"L305"}
    assert lint_source(src.replace("@dataclass",
                                   "@dataclass(frozen=True)"),
                       "x.py") == []


def test_l306_mutable_default_fires():
    src = "def f(xs=[]):\n    return xs\n"
    assert _rules(lint_source(src, "x.py")) == {"L306"}


def test_committed_tree_lints_clean():
    assert lint_paths([os.path.join(_ROOT, "src", "repro")]) == []


# ---------------------------------------------------------------------------
# W1xx: collectives — a (1, 1) debug mesh traces the true sharded step
# (psum survives in the jaxpr at axis size 1) on the single CPU device
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def local_run():
    exp = _spec("fedbioacc_local.json").edit(
        **{"execution.mesh": (1, 1), "schedule.steps": 2})
    return build(exp)


@pytest.fixture(scope="module")
def int8_run():
    exp = _spec("fedbioacc_int8_topk.json").edit(
        **{"execution.mesh": (1, 1), "schedule.steps": 2})
    return build(exp)


def _seed_psum(run, elems):
    """run with one extra psum of ``elems`` f32 smuggled into the step."""
    def bad_step(state, batch):
        leak = shard_map(lambda x: jax.lax.psum(x, "data"), mesh=run.mesh,
                         in_specs=P(), out_specs=P())(
                             jnp.zeros((elems,), jnp.float32))
        s, aux = run.step(state, batch)
        z = (0 * leak.sum()).astype(jnp.float32)
        return s._replace(vars=jax.tree.map(lambda v: v + z, s.vars)), aux
    bad_step.__dict__.update(run.step.__dict__)
    return run._replace(step=bad_step)


def test_clean_step_has_exactly_planned_collectives(local_run):
    assert coll.audit_step_collectives(local_run) == []


def test_w101_extra_psum_fires(local_run):
    assert _rules(coll.audit_step_collectives(
        _seed_psum(local_run, 7))) == {"W101"}


def test_w102_private_section_on_wire_fires(local_run):
    _, info = coll.expected_step_collectives(local_run)
    private = sorted(info["private_elems"])
    assert private, "fedbioacc_local must carry private sections"
    fs = coll.audit_step_collectives(_seed_psum(local_run, private[0]))
    assert _rules(fs) == {"W102"}
    assert "PRIVATE" in fs[0].message


def _wire(run):
    expected, _ = coll.expected_step_collectives(run)
    return coll.expected_wire_bytes(expected, int(run.mesh.shape["data"]))


def test_wire_model_accepts_exact_bytes(int8_run):
    ok = {"bytes": {}, "counts": {}, "bytes_by_dtype": _wire(int8_run)}
    assert coll.audit_wire(int8_run, coll=ok) == []


def test_w103_f32_wire_under_int8_fires(int8_run):
    want = _wire(int8_run)
    bad = {"bytes": {}, "counts": {},
           "bytes_by_dtype": {"f32": sum(want.values())}}
    fs = coll.audit_wire(int8_run, coll=bad)
    assert "W103" in _rules(fs)
    # the shared dryrun audit raises the same diagnosis directly
    with pytest.raises(RuntimeError, match="int8"):
        coll.check_compressed_collectives(int8_run.spec, int8_run.step.spec,
                                          bad)


def test_w104_byte_mismatch_fires(int8_run):
    want = _wire(int8_run)
    off = dict(want, f32=want.get("f32", 0) + 4)   # one f32 element extra
    fs = coll.audit_wire(int8_run,
                         coll={"bytes": {}, "counts": {},
                               "bytes_by_dtype": off})
    assert _rules(fs) == {"W104"}


def test_w105_resharding_op_fires(int8_run):
    fs = coll.audit_wire(int8_run, coll={
        "bytes": {"all-to-all": 64}, "counts": {"all-to-all": 1},
        "bytes_by_dtype": _wire(int8_run)})
    assert "W105" in _rules(fs)


# ---------------------------------------------------------------------------
# S2xx: structure — slot, jaxpr-identity, and telemetry-inertness seeds
# ---------------------------------------------------------------------------

def test_s201_fires_both_directions(local_run):
    assert struct.audit_state_slots(local_run) == []
    # participation on, stale leaf dropped -> "expects a stale leaf"
    missing = local_run._replace(
        init=lambda key: local_run.init(key)._replace(stale=()))
    fs = struct.audit_state_slots(missing)
    assert _rules(fs) == {"S201"} and "stale" in fs[0].message

    # featureless spec, stale leaf present -> "zero-leaf contract broken"
    run = build(_spec("fedavg.json"))
    assert struct.audit_state_slots(run) == []
    extra = run._replace(
        init=lambda key: run.init(key)._replace(
            stale=jnp.zeros((4,), jnp.int32)))
    fs = struct.audit_state_slots(extra)
    assert _rules(fs) == {"S201"} and "zero-leaf" in fs[0].message


def test_s202_leaked_feature_fires(monkeypatch):
    # re-seed the exact regression the rule exists for: a bare form whose
    # edits miss a normalize() promotion trigger, so the "feature-off"
    # build still carries the uniform sampler's mask/stale machinery
    edits = {k: v for k, v in struct.BARE_EDITS.items()
             if k != "participation.clients_per_round"}
    monkeypatch.setattr(struct, "BARE_EDITS", edits)
    fs = struct.audit_bare_jaxpr(_spec("fedbioacc_local.json"))
    assert _rules(fs) == {"S202"}
    assert "not the pre-feature baseline" in fs[0].message


def test_s203_noninert_telemetry_fires(monkeypatch):
    # force events-only telemetry (metrics=()) to resolve to the default
    # metric groups — exactly the "telemetry stopped compiling away" bug
    from repro.telemetry import spec as tspec
    orig = tspec.resolve_metric_groups
    monkeypatch.setattr(tspec, "resolve_metric_groups",
                        lambda metrics, **kw: orig(None, **kw))
    fs = struct.audit_telemetry_inert(_spec("fedbioacc_telemetry.json"))
    assert _rules(fs) == {"S203"}
