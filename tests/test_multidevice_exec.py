"""Execute (not just compile) a sharded federated train step on 8 host
devices — proves the client-sharded collectives actually run and match the
single-device result bit-for-bit (pure data-parallel semantics).

Runs in a subprocess because the device-count flag must be set before jax
initialises.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.config import FederatedConfig, MeshConfig
    from repro.configs import ARCHS
    from repro.data import make_fed_batch_fn
    from repro.federation.trainer import make_fedbioacc_train_step
    from repro.models import build_model
    from repro.sharding import rules

    assert len(jax.devices()) == 8, jax.devices()
    cfg = ARCHS["gemma2-2b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=2, lr_x=0.02,
                          lr_y=0.02, lr_u=0.02)
    init, step = make_fedbioacc_train_step(model, fed, n_micro=1, remat=False)
    state = init(jax.random.PRNGKey(0))
    bf = make_fed_batch_fn(cfg, num_clients=4, per_client=2, seq_len=32)
    b1 = bf(jax.random.PRNGKey(1))
    b2 = bf(jax.random.PRNGKey(2))

    # single-device reference
    ref, _ = jax.jit(step)(state, b1)
    ref, _ = jax.jit(step)(ref, b2)      # second step crosses a comm round

    # sharded execution on a (4, 2) mesh: clients over "data", TP over "model"
    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    mesh_cfg = MeshConfig()              # axis names match
    s_spec = rules.state_specs(jax.eval_shape(lambda: state), mesh_cfg,
                               placement="client_sharded")
    b_spec = rules.batch_specs(b1, mesh_cfg, client_axis=True)
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda s: isinstance(s, P))
    with mesh:
        metrics_shape = jax.eval_shape(step, state, b1)[1]
        m_spec = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_shape)
        jstep = jax.jit(step, in_shardings=(named(s_spec), named(b_spec)),
                        out_shardings=(named(s_spec), m_spec))
        out, _ = jstep(state, b1)
        out, _ = jstep(out, b2)

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-4)
    print("MULTIDEVICE_OK")
""")


@pytest.mark.timeout(900)
@pytest.mark.mesh_subprocess
def test_sharded_step_executes_and_matches():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=850)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MULTIDEVICE_OK" in res.stdout
