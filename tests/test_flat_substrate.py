"""Flat-buffer STORM substrate (repro.optim.flat): layout round-trips, the
triple-sequence fused step vs the 9-pass tree-map reference (bit-exact), and
end-to-end trajectory equivalence of fuse_storm=True/False in both the core
algorithm and the model-scale trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import flat


def _mixed_tree():
    return {
        "x": {"w": jnp.arange(24.0).reshape(4, 6),
              "b": (jnp.arange(7, dtype=jnp.bfloat16), jnp.float32(3.5))},
        "y": {"h": jnp.arange(5.0) * 2.0, "hb": jnp.full((3,), 2, jnp.bfloat16)},
        "u": {"h": jnp.zeros((5,)), "hb": jnp.zeros((3,), jnp.bfloat16)},
    }


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def test_roundtrip_mixed_dtypes():
    tree = _mixed_tree()
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8)
    bufs = flat.flatten_tree(spec, tree)
    assert len(bufs) == 2                      # one buffer per dtype
    assert all(b.shape[-1] % 8 == 0 for b in bufs)
    back = flat.unflatten_tree(spec, bufs)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert jnp.asarray(a).dtype == b.dtype
        assert jnp.shape(a) == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip_with_client_axis():
    tree = _mixed_tree()
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8)
    M = 3
    btree = jax.tree.map(
        lambda v: jnp.broadcast_to(jnp.asarray(v)[None],
                                   (M,) + jnp.shape(v)), tree)
    bufs = flat.flatten_tree(spec, btree, batch_dims=1)
    assert all(b.shape[0] == M for b in bufs)
    back = flat.unflatten_tree(spec, bufs)
    for a, b in zip(jax.tree.leaves(btree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_sections_are_tile_aligned_and_ordered():
    tree = _mixed_tree()
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8)
    for grp in spec.groups:
        # every tile belongs to exactly one section, sections appear in order
        ids = list(grp.section_ids)
        assert ids == sorted(ids)
        assert grp.padded == len(ids) * grp.block
        for lf in grp.leaves:
            assert lf.offset + lf.size <= grp.padded


def test_padding_is_zero_and_stays_zero():
    tree = {"x": {"w": jnp.ones((5,))}, "y": {"h": jnp.ones((3,))},
            "u": {"h": jnp.ones((3,))}}
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8)
    (buf,) = flat.flatten_tree(spec, tree)
    mom = tuple(jnp.ones_like(b) for b in (buf,))
    g_old = tuple(jnp.full_like(b, 2.0) for b in (buf,))
    (vn,), (mn,) = flat.storm_partial_step(spec, (buf,), mom, g_old,
                                           (0.5, 0.5, 0.5), (0.9, 0.9, 0.9))
    (grp,) = spec.groups
    mask = np.ones(grp.padded, bool)
    for lf in grp.leaves:
        mask[lf.offset:lf.offset + lf.size] = False
    # flatten_tree zero-fills the pad lanes; the elementwise update (with a
    # zero-padded momentum input) must keep them zero
    np.testing.assert_array_equal(np.asarray(buf)[mask], 0.0)
    vn2, _ = flat.storm_partial_step(
        spec, (buf,), (jnp.zeros_like(buf),), (jnp.zeros_like(buf),),
        (0.5, 0.5, 0.5), (0.9, 0.9, 0.9))
    np.testing.assert_array_equal(np.asarray(vn2[0])[mask], 0.0)


# ---------------------------------------------------------------------------
# fused triple-sequence step vs the 9-pass tree-map chain
# ---------------------------------------------------------------------------

def _rand_like(key, tree):
    leaves, treedef = jax.tree.flatten(tree)
    ks = jax.random.split(key, len(leaves))
    return treedef.unflatten([jax.random.normal(k, jnp.shape(l))
                              for k, l in zip(ks, leaves)])


def test_fused_step_bitexact_vs_treemap_chain(rng):
    """The fused launch == the tree-map reference, stage by stage, bit for
    bit (both under jit, f32): (a) one triple-sequence launch vs the 6-pass
    partial-momentum + variable-step chain, (b) the correction add vs the
    3-pass per-leaf add. (The end-to-end composition is compared at 1-2 ulp
    separately — XLA may FMA-contract decay·(m−o)+g across the tree-map
    chain, which no two-launch schedule can reproduce exactly.)"""
    sections = ("x", "y", "u")
    vt = {"x": {"w": jax.random.normal(rng, (16, 33)),
                "b": jax.random.normal(jax.random.fold_in(rng, 1), (7,))},
          "y": {"h": jax.random.normal(jax.random.fold_in(rng, 2), (91,))},
          "u": {"h": jax.random.normal(jax.random.fold_in(rng, 3), (91,))}}
    mt = _rand_like(jax.random.fold_in(rng, 4), vt)
    got = _rand_like(jax.random.fold_in(rng, 5), vt)   # old-iterate oracle
    gnt = _rand_like(jax.random.fold_in(rng, 6), vt)   # new-iterate oracle
    lrs = (0.05, 0.1, 0.2)
    decays = (0.99, 0.98, 0.97)
    spec = flat.make_spec(vt, sections=sections, block=64)

    @jax.jit
    def fused(vt, mt, got, gnt):
        v_b, m_b, go_b, gn_b = (flat.flatten_tree(spec, t)
                                for t in (vt, mt, got, gnt))
        # interpret=True pins the Pallas kernel (the bit-exact claim is about
        # the kernel; the off-TPU jnp lowering is checked at 1 ulp below)
        v_b, mp_b = flat.storm_partial_step(spec, v_b, m_b, go_b, lrs, decays,
                                            interpret=True)
        m_b = flat.buffers_add(mp_b, gn_b)
        return (flat.unflatten_tree(spec, v_b),
                flat.unflatten_tree(spec, mp_b),
                flat.unflatten_tree(spec, m_b))

    @jax.jit
    def fused_dispatched(vt, mt, got, gnt):
        v_b, m_b, go_b, gn_b = (flat.flatten_tree(spec, t)
                                for t in (vt, mt, got, gnt))
        v_b, mp_b = flat.storm_partial_step(spec, v_b, m_b, go_b, lrs, decays)
        m_b = flat.buffers_add(mp_b, gn_b)
        return (flat.unflatten_tree(spec, v_b),
                flat.unflatten_tree(spec, mp_b),
                flat.unflatten_tree(spec, m_b))

    @jax.jit
    def unfused_partial(vt, mt, got):   # partial momentum ×3 + var step ×3
        mp = {s: jax.tree.map(lambda m, o: decays[i] * (m - o),
                              mt[s], got[s]) for i, s in enumerate(sections)}
        vn = {s: jax.tree.map(lambda v, m: v - lrs[i] * m, vt[s], mt[s])
              for i, s in enumerate(sections)}
        return vn, mp

    @jax.jit
    def unfused_add(mp, gnt):           # correction add ×3
        return {s: jax.tree.map(jnp.add, mp[s], gnt[s]) for s in sections}

    va, mpa, ma = fused(vt, mt, got, gnt)
    vb, mpb = unfused_partial(vt, mt, got)
    for a, b in zip(jax.tree.leaves((va, mpa)), jax.tree.leaves((vb, mpb))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mb = unfused_add(mpb, gnt)          # same entering partial momentum
    for a, b in zip(jax.tree.leaves(ma), jax.tree.leaves(mb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @jax.jit
    def unfused_chain(vt, mt, got, gnt):   # fully fused-by-XLA composition
        vn, mp = unfused_partial(vt, mt, got)
        return vn, unfused_add(mp, gnt)
    vc, mc = unfused_chain(vt, mt, got, gnt)
    for a, b in zip(jax.tree.leaves((va, ma)), jax.tree.leaves((vc, mc))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)

    # the backend-dispatched lowering (jnp off-TPU) tracks the kernel to
    # FMA-contraction noise (≤ 1-2 ulp)
    vd, mpd, md = fused_dispatched(vt, mt, got, gnt)
    for a, b in zip(jax.tree.leaves((va, mpa, ma)),
                    jax.tree.leaves((vd, mpd, md))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_full_update_matches_single_sequence_kernel(rng):
    """storm_full_update over a sectioned spec == three independent
    single-sequence reference updates."""
    from repro.kernels.storm.ref import storm_update_ref
    vt = {"x": jax.random.normal(rng, (200,)),
          "y": jax.random.normal(jax.random.fold_in(rng, 1), (130,)),
          "u": jax.random.normal(jax.random.fold_in(rng, 2), (130,))}
    mt = _rand_like(jax.random.fold_in(rng, 3), vt)
    gnt = _rand_like(jax.random.fold_in(rng, 4), vt)
    got = _rand_like(jax.random.fold_in(rng, 5), vt)
    lrs, decays = (0.1, 0.2, 0.3), (0.9, 0.8, 0.7)
    spec = flat.make_spec(vt, sections=("x", "y", "u"), block=64)
    bufs = [flat.flatten_tree(spec, t) for t in (vt, mt, gnt, got)]
    # interpret=True forces the Pallas kernel (interpreted) through the flat
    # layer; the default dispatch is also checked against it below
    v_b, m_b = flat.storm_full_update(spec, *bufs, lrs, decays,
                                      interpret=True)
    vn = flat.unflatten_tree(spec, v_b)
    mn = flat.unflatten_tree(spec, m_b)
    for i, s in enumerate(("x", "y", "u")):
        pr, mr = storm_update_ref(vt[s], mt[s], gnt[s], got[s],
                                  lrs[i], decays[i])
        np.testing.assert_allclose(np.asarray(vn[s]), np.asarray(pr),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(mn[s]), np.asarray(mr),
                                   rtol=1e-6, atol=1e-7)
    # backend-dispatched lowering (jnp off-TPU) == Pallas kernel
    v_d, m_d = flat.storm_full_update(spec, *bufs, lrs, decays)
    for a, b in zip(v_b + m_b, v_d + m_d):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-7, atol=1e-7)


# ---------------------------------------------------------------------------
# end-to-end: fuse_storm=True trajectories match the unfused path
# ---------------------------------------------------------------------------

def test_core_fedbioacc_fuse_storm_matches():
    from repro.config import FederatedConfig
    from repro.core import make_algorithm, quadratic_problem
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.3, hetero=1.0)

    def run(**kw):
        cfg = FederatedConfig(algorithm="fedbioacc", num_clients=8,
                              local_steps=4, lr_x=0.03, lr_y=0.1, lr_u=0.1,
                              **kw)
        alg = make_algorithm(prob, cfg)
        state = alg.init(jax.random.PRNGKey(1))
        rnd = jax.jit(alg.round)
        key = jax.random.PRNGKey(2)
        for _ in range(3):
            key, sub = jax.random.split(key)
            state, _ = rnd(state, sub)
        return state

    a, b = run(), run(fuse_storm=True)
    for n in ("x", "y", "u", "omega", "nu", "q"):
        np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                   np.asarray(getattr(b, n)),
                                   rtol=1e-5, atol=1e-5, err_msg=n)


def test_core_fedbioacc_fuse_oracles_matches_in_deterministic_limit():
    """With noise=0 every oracle draw is identical, so sharing one batch
    across the three directions must reproduce the unfused trajectory."""
    from repro.config import FederatedConfig
    from repro.core import make_algorithm, quadratic_problem
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.0, hetero=1.0)

    def run(**kw):
        cfg = FederatedConfig(algorithm="fedbioacc", num_clients=8,
                              local_steps=4, lr_x=0.03, lr_y=0.1, lr_u=0.1,
                              **kw)
        alg = make_algorithm(prob, cfg)
        state = alg.init(jax.random.PRNGKey(1))
        state, _ = jax.jit(alg.round)(state, jax.random.PRNGKey(2))
        return state

    a, b = run(), run(fuse_oracles=True)
    c = run(fuse_oracles=True, fuse_storm=True)
    for n in ("x", "y", "u", "omega", "nu", "q"):
        np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                   np.asarray(getattr(b, n)),
                                   rtol=1e-6, atol=1e-6, err_msg=n)
        np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                   np.asarray(getattr(c, n)),
                                   rtol=1e-5, atol=1e-5, err_msg=n)


def test_trainer_fuse_storm_matches_unfused_trajectory():
    """fuse_storm=True must reproduce the unfused model-scale FedBiOAcc
    trajectory (flat state end-to-end, pytree views only at boundaries)."""
    from repro.config import FederatedConfig
    from repro.configs import ARCHS
    from repro.data import make_fed_batch_fn
    from repro.federation.trainer import (FlatFedBiOAccTrainState,
                                          make_fedbioacc_train_step)
    from repro.models import build_model

    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=3, lr_x=0.05,
                          lr_y=0.05, lr_u=0.05)
    batch_fn = make_fed_batch_fn(cfg, num_clients=4, per_client=2, seq_len=32)

    i1, s1 = make_fedbioacc_train_step(model, fed, n_micro=1, remat=False)
    i2, s2 = make_fedbioacc_train_step(model, fed, n_micro=1, remat=False,
                                       fuse_storm=True)
    st1 = i1(jax.random.PRNGKey(0))
    st2 = i2(jax.random.PRNGKey(0))
    assert isinstance(st2, FlatFedBiOAccTrainState)
    j1 = jax.jit(s1)
    j2 = jax.jit(s2, donate_argnums=(0,))   # flat buffers are donated
    key = jax.random.PRNGKey(1)
    for _ in range(4):                       # crosses a communication round
        key, sub = jax.random.split(key)
        b = batch_fn(sub)
        st1, _ = j1(st1, b)
        st2, _ = j2(st2, b)
    v2 = s2.views(st2)
    assert int(v2.step) == int(st1.step) == 4
    for n in ("x", "y", "u", "omega", "nu", "q"):
        for a, b in zip(jax.tree.leaves(getattr(st1, n)),
                        jax.tree.leaves(getattr(v2, n))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5, err_msg=n)


def test_trainer_fuse_storm_bf16_keeps_f32_momenta():
    """At bf16 the flat substrate must hold the STORM momenta in f32 buffers
    (the unfused arithmetic promotes them the same way); variables stay bf16
    and the step stays finite."""
    from repro.config import FederatedConfig
    from repro.configs import ARCHS
    from repro.data import make_fed_batch_fn
    from repro.federation.trainer import make_fedbioacc_train_step
    from repro.models import build_model

    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.bfloat16)
    fed = FederatedConfig(num_clients=2, local_steps=2, lr_x=0.05,
                          lr_y=0.05, lr_u=0.05)
    batch_fn = make_fed_batch_fn(cfg, num_clients=2, per_client=2, seq_len=32)
    init, step = make_fedbioacc_train_step(model, fed, n_micro=1,
                                           remat=False, fuse_storm=True)
    state = init(jax.random.PRNGKey(0))
    assert all(b.dtype == jnp.float32 for b in state.mom)
    assert any(b.dtype == jnp.bfloat16 for b in state.vars)
    state, _ = jax.jit(step)(state, batch_fn(jax.random.PRNGKey(1)))
    assert all(b.dtype == jnp.float32 for b in state.mom)
    v = step.views(state)
    for n in ("omega", "nu", "q"):
        assert all(l.dtype == jnp.float32
                   for l in jax.tree.leaves(getattr(v, n))), n
    for leaf in jax.tree.leaves(state.vars) + jax.tree.leaves(state.mom):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
