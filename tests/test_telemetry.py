"""Round-level telemetry (repro.telemetry): the telemetry-off path is the
LITERAL pre-telemetry engine (uint8 bit-identity across all five
algorithms, and telemetry-ON trajectories match it too), in-band metrics
are present/finite, the event log repairs crashed tails and stays
append-safe, and the validate CLI reconciles comm bytes against the
analytic model (catching tampered streams)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FederatedConfig
from repro.optim import sequences as seqs
from repro.telemetry import (EventLog, TelemetrySpec, read_events,
                             resolve_metric_groups, validate_events)
from repro.telemetry.events import TelemetryError

ALGOS = ("fedbio", "fedbioacc", "fedbio_local", "fedbioacc_local", "fedavg")
M = 4

_SHAPES = {"x": {"w": (3, 5)}, "y": {"h": (7,)}, "u": {"v": (11,)},
           "params": {"w": (3, 5), "h": (7,)}}


def _make(algo, telemetry=None, **kw):
    """Toy engine on the flat substrate: templates WITHOUT the client axis,
    init var trees WITH it."""
    cfg = FederatedConfig(algorithm=algo, num_clients=M, local_steps=2,
                          lr_x=0.05, lr_y=0.05, lr_u=0.05, c_nu=1.0,
                          c_omega=1.0, c_u=1.0, alpha_delta=1.0,
                          alpha_u0=4.0, hierarchy_period=0,
                          hierarchy_groups=2)
    aspec = seqs.SPECS[algo]
    tmpl = {s: {k: jax.ShapeDtypeStruct(shape, jnp.float32)
                for k, shape in _SHAPES[s].items()} for s in aspec.sections}

    def one(v, b):
        return {s: jax.tree.map(lambda t: jnp.tanh(t) + 0.01 * b, v[s])
                for s in v}

    eng = seqs.make_engine(cfg, aspec, tmpl, jax.vmap(one), block=8,
                           telemetry=telemetry, **kw)
    key, i, vt = jax.random.PRNGKey(0), 0, {}
    for s in aspec.sections:
        vt[s] = {}
        for k, shape in _SHAPES[s].items():
            vt[s][k] = jax.random.normal(jax.random.fold_in(key, i),
                                         (M,) + shape)
            i += 1
    return eng, eng.init_state(vt)


def _batches(steps):
    key = jax.random.PRNGKey(7)
    return [jax.random.normal(jax.random.fold_in(key, t), (M,))
            for t in range(steps)]


def _assert_bit_identical(sa, sb):
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(
            np.ravel(np.asarray(a)).view(np.uint8),
            np.ravel(np.asarray(b)).view(np.uint8))


@pytest.mark.parametrize("algo", ALGOS)
def test_telemetry_off_on_bit_identity(algo):
    """Telemetry OFF keeps the bare step(state, batch) -> state contract
    (the literal pre-telemetry path), and turning metrics ON leaves the
    trajectory BIT-identical (uint8 view) — metrics are read off the
    already-materialized buffers, never fed back."""
    eng_off, s_off = _make(algo)
    eng_on, s_on = _make(algo, telemetry=TelemetrySpec())
    assert eng_off.step.telemetry_groups == ()
    assert eng_on.step.telemetry_groups == ("norms", "drift")
    mets = None
    for b in _batches(4):
        s_off = eng_off.step(s_off, b)          # bare state: not a tuple
        s_on, mets = eng_on.step(s_on, b)
    _assert_bit_identical(s_off, s_on)
    aspec = seqs.SPECS[algo]
    sec = aspec.sections[0]
    keys = [f"upd_norm/{sec}", f"drift/{sec}"]
    if aspec.has_momentum:          # fedbio/fedbio_local carry no momentum
        keys.append(f"mom_norm/{sec}")
    for k in keys:
        assert k in mets and np.isfinite(float(mets[k])), (k, mets)


def test_storm_step1_update_norm_is_zero():
    """STORM sequences update with the ENTERING momentum — the very first
    step's update norm is exactly 0 (the leading zero the validate CLI's
    trend check drops)."""
    eng, s = _make("fedbioacc", telemetry=TelemetrySpec())
    b = _batches(1)[0]
    _, mets = eng.step(s, b)
    assert float(mets["upd_norm/u"]) == 0.0
    assert float(mets["mom_norm/u"]) > 0.0


def test_metric_group_resolution_and_rejections():
    assert resolve_metric_groups(None) == ("norms", "drift")
    assert resolve_metric_groups(None, compressed=True, guarded=True) == (
        "norms", "drift", "compression", "health")
    with pytest.raises(ValueError, match="unknown telemetry metric"):
        resolve_metric_groups(("norms", "bogus"))
    # explicit groups whose inputs the run doesn't have: clear errors
    with pytest.raises(ValueError, match="'compression' needs"):
        _make("fedbioacc", telemetry=TelemetrySpec(metrics=("compression",)))
    with pytest.raises(ValueError, match="'health' needs"):
        _make("fedbioacc", telemetry=TelemetrySpec(metrics=("health",)))


def test_trainer_rejects_unfused_inband_metrics():
    from repro.federation.trainer import _telemetry_setup
    with pytest.raises(ValueError, match="fuse_storm"):
        _telemetry_setup(TelemetrySpec(metrics=("norms",)), False)
    # events-only spec is fine unfused; fused passes through
    assert _telemetry_setup(TelemetrySpec(metrics=()), False) is None
    t = TelemetrySpec()
    assert _telemetry_setup(t, True) is t


def test_eventlog_append_resume_and_tail_repair(tmp_path):
    p = str(tmp_path / "events.jsonl")
    with EventLog(p, experiment=None) as log:
        log.emit("metrics", step=1, val_loss=2.5)
    # a crashed writer leaves a partial tail line — the next open repairs it
    with open(p, "a") as f:
        f.write('{"event": "metrics", "seq": 2, "ts": 0, "st')
    with pytest.raises(TelemetryError, match="unterminated"):
        read_events(p)
    with EventLog(p, experiment=None) as log:      # repair + new segment
        log.emit("run_end", step=1, status="ok")
    evs = read_events(p)
    assert [e["event"] for e in evs] == ["run_start", "metrics",
                                         "run_start", "run_end"]
    s = validate_events(p)
    assert s["segments"] == 2 and s["events"] == 4


def test_eventlog_rejects_missing_required_keys(tmp_path):
    with EventLog(str(tmp_path / "e.jsonl"), experiment=None) as log:
        with pytest.raises(TelemetryError, match="missing required"):
            log.emit("comm", step=2, round=1)      # no elems/bytes_wire


def test_validate_reconciles_comm_bytes(tmp_path):
    """Exact comm: bytes_wire = reductions x elems x 4 B; a tampered byte
    count fails reconciliation against the embedded spec's model."""
    p = str(tmp_path / "e.jsonl")
    with EventLog(p, experiment={"compression": None}) as log:
        log.emit("comm", step=2, round=1, elems=1000, reductions=2,
                 bytes_wire=8000)
    assert validate_events(p)["comm_reconciled"] == 1
    with EventLog(p, experiment={"compression": None}) as log:
        log.emit("comm", step=4, round=2, elems=1000, reductions=2,
                 bytes_wire=16000)                  # tampered: doubled
    with pytest.raises(TelemetryError, match="disagrees with the analytic"):
        validate_events(p)


def test_validate_reconciles_compressed_comm(tmp_path):
    from repro.federation.compression import (CompressionSpec,
                                              wire_bytes_per_elem)
    cp = CompressionSpec(quant="int8", topk_frac=0.10)
    wire = wire_bytes_per_elem(cp, 256)
    p = str(tmp_path / "e.jsonl")
    with EventLog(p, experiment={"compression": cp._asdict()}) as log:
        log.emit("comm", step=2, round=1, elems=4096, reductions=2,
                 block=256, bytes_wire=int(2 * 4096 * wire))
    assert validate_events(p)["comm_reconciled"] == 1


def test_validate_expect_and_trend(tmp_path):
    p = str(tmp_path / "e.jsonl")
    with EventLog(p, experiment=None) as log:
        for t, v in enumerate((0.0, 3.0, 2.0, 1.0)):   # leading zero dropped
            log.emit("metrics", step=t + 1, **{"mom_norm/u": v})
    validate_events(p, trend_decreasing=("mom_norm/u",))
    with pytest.raises(TelemetryError, match="expected at least one"):
        validate_events(p, expect=("rollback",))
    with pytest.raises(TelemetryError, match="does not trend down"):
        validate_events(p, trend_decreasing=("step",))


def test_telemetry_spec_experiment_roundtrip():
    from repro.api import Experiment
    exp = Experiment().edit(**{
        "execution.fuse_storm": True, "execution.fuse_oracles": True,
        "telemetry.metrics": ["norms", "drift"],
        "telemetry.sink": "events.jsonl"})
    exp.validate()
    back = Experiment.from_json(exp.to_json())
    assert back.telemetry == exp.telemetry
    assert back.telemetry.metrics == ("norms", "drift")
    with pytest.raises(ValueError, match="telemetry"):
        exp.edit(**{"telemetry.metrics": ["bogus"]}).validate()
    with pytest.raises(ValueError, match="fuse_storm"):
        exp.edit(**{"execution.fuse_storm": False}).validate()


@pytest.mark.timeout(900)
def test_faulty_run_emits_rollback_events(tmp_path):
    """A run whose NaNs reach the unscreened mean rolls back, exhausts the
    retry budget, and the event stream records the whole audit trail:
    rollback events with (step, retry, bad_loss), retry_budget_exhausted,
    and a run_end carrying that status — asserted end-to-end through the
    train driver (which must exit non-zero)."""
    from repro.api import Experiment
    root = os.path.join(os.path.dirname(__file__), "..")
    exp = Experiment.load(
        os.path.join(root, "experiments", "fedbioacc_faulty.json")).edit(**{
            "faults.nan_rate": 1.0, "schedule.steps": 6,
            "robustness.screen": False, "robustness.aggregator": "mean"})
    spec = tmp_path / "faulty.json"
    spec.write_text(exp.to_json())
    sink = str(tmp_path / "events.jsonl")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--experiment",
         str(spec), "--telemetry-sink", sink, "--log-every", "1"],
        env=env, capture_output=True, text=True, timeout=850)
    assert res.returncode != 0, res.stdout[-2000:]
    validate_events(sink, expect=("rollback", "retry_budget_exhausted"))
    evs = read_events(sink)
    rb = [e for e in evs if e["event"] == "rollback"]
    assert len(rb) == exp.robustness.retry_budget
    assert {"step", "retry", "bad_loss"} <= set(rb[0])
    assert [e["retry"] for e in rb] == [1, 2]
    assert evs[-1]["event"] == "run_end"
    assert evs[-1]["status"] == "retry_budget_exhausted"


def test_comm_plan_matches_flat_spec():
    """The analytic plan counts exactly the communicated elements of the
    engine's flat layout (padded, shard-replicated extents), doubled for
    the momentum reduction; cadence-skipped rounds return None."""
    from repro.telemetry import comm_plan, round_bytes
    eng, _ = _make("fedbioacc_local")   # y PRIVATE: only x communicates
    plan = comm_plan(eng.spec, eng.aspec, None)
    assert plan is not None and plan.reductions == 2   # storm: vars + mom
    assert [s[0] for s in plan.sections] == ["x"]      # private y excluded
    b1 = round_bytes(plan, 1)
    assert b1 is not None and b1["bytes_wire"] == pytest.approx(
        plan.reductions * b1["elems"] * 4.0)
    # the x group extent covers the padded x run: elems >= 3*5, < 2 blocks
    assert 15 <= b1["elems"] <= 16
