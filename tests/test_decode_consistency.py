"""Decode-with-cache must match the full forward pass at the same position —
for every decoder family (kv ring buffers, sliding windows, SSD state,
RG-LRU state, VLM patch prefix)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS

_DECODERS = [a for a, c in ARCHS.items() if c.family != "audio"]


@pytest.mark.parametrize("arch", sorted(_DECODERS))
def test_decode_matches_forward(arch, rng):
    from repro.models import build_model
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(rng)
    B, S = 2, 33
    tks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tks, "labels": tks}
    pre = {"tokens": tks[:, :-1], "labels": tks[:, :-1]}
    offset = 0
    if cfg.family == "vlm":
        patches = 0.1 * jax.random.normal(rng, (B, cfg.num_patches, cfg.frontend_dim))
        batch["patches"] = patches
        pre["patches"] = patches
        offset = cfg.num_patches
    full, _ = model.forward(params, batch)
    _, caches = model.prefill(params, pre, cache_len=offset + S + 4)
    logits, _ = model.decode_step(params, caches, tks[:, -1:],
                                  jnp.int32(offset + S - 1))
    err = float(jnp.max(jnp.abs(logits - full[:, -1, :])))
    assert err < 2e-4, err


def test_ring_buffer_wraps(rng):
    """Decoding past the cache length must keep matching the windowed
    forward (ring-buffer overwrite correctness)."""
    from repro.models import build_model
    cfg = ARCHS["gemma2-2b"].reduced()   # window 64 local layers
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(rng)
    B, S = 1, 80
    tks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": tks, "labels": tks})
    # prefill 70 then decode 10 with cache_len == 80 (local layers wrap at 64)
    _, caches = model.prefill(
        params, {"tokens": tks[:, :70], "labels": tks[:, :70]}, cache_len=S)
    for i in range(70, S):
        logits, caches = model.decode_step(params, caches, tks[:, i:i + 1],
                                           jnp.int32(i))
        err = float(jnp.max(jnp.abs(logits - full[:, i, :])))
        assert err < 2e-4, (i, err)
