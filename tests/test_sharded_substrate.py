"""Sharded flat substrate (repro.optim.flat ``shards=`` / ``ShardCtx``):

* layout invariance on one device — the shard-major interleaved layout
  (sections padded to block·shards, every shard chunk carrying the same
  tile-aligned section pattern) must round-trip bit-exactly and produce the
  same unflattened results as the shards=1 layout for both the fused
  launches and the masked reductions;
* execution invariance on a real ≥8-device mesh (subprocess — the host
  device-count flag must precede jax init): ``client_mean_masked`` under
  ``shard_map`` with true ``lax.psum``/``psum_scatter`` collectives matches
  the single-device path within float-reassociation tolerance, private
  sections BIT-exactly; sharded fused trajectories match the single-device
  engine for all five algorithms, including under m = M/2 participation and
  the comm/compute overlap schedule.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import flat


def _mixed_tree():
    return {
        "x": {"w": jnp.arange(24.0).reshape(4, 6),
              "b": (jnp.arange(7, dtype=jnp.bfloat16), jnp.float32(3.5))},
        "y": {"h": jnp.arange(5.0) * 2.0,
              "hb": jnp.full((3,), 2, jnp.bfloat16)},
        "u": {"h": jnp.ones((5,)), "hb": jnp.ones((3,), jnp.bfloat16)},
    }


def _client_stack(tree, m):
    return jax.tree.map(
        lambda v: jnp.stack([jnp.asarray(v) + i for i in range(m)]), tree)


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_spec_roundtrip_and_invariants(shards):
    """Sections pad to block·shards; section_ids is one per-chunk pattern
    tiled ``shards`` times; the extents cover each chunk exactly; and
    flatten/unflatten round-trips bit-exactly."""
    tree = _mixed_tree()
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8,
                          shards=shards)
    assert spec.shards == shards
    for grp in spec.groups:
        assert grp.padded % (grp.block * shards) == 0
        chunk = grp.padded // shards
        pattern = grp.section_ids[: chunk // grp.block]
        assert np.array_equal(grp.section_ids, np.tile(pattern, shards))
        assert grp.extents[0][1] == 0 and grp.extents[-1][2] == chunk
        for (_, _, stop), (_, start, _) in zip(grp.extents, grp.extents[1:]):
            assert stop == start            # extents tile the chunk
    bufs = flat.flatten_tree(spec, tree)
    back = flat.unflatten_tree(spec, bufs)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a).reshape(-1).view(np.uint8),
            np.asarray(b).reshape(-1).view(np.uint8))


def test_sharded_layout_comm_and_launch_invariance():
    """The interleaved shards=2 layout must give the same (unflattened)
    results as shards=1 for the masked reduction and the fused launch —
    the layout is a pure storage permutation."""
    tree = _mixed_tree()
    M = 8
    btree = _client_stack(tree, M)
    s1 = flat.make_spec(tree, sections=("x", "y", "u"), block=8, shards=1)
    s2 = flat.make_spec(tree, sections=("x", "y", "u"), block=8, shards=2)
    b1 = flat.flatten_tree(s1, btree, batch_dims=1)
    b2 = flat.flatten_tree(s2, btree, batch_dims=1)
    w = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)

    o1 = flat.client_mean_masked(s1, b1, ("mean", "none", "group"), weights=w)
    o2 = flat.client_mean_masked(s2, b2, ("mean", "none", "group"), weights=w)
    for a, b in zip(jax.tree.leaves(flat.unflatten_tree(s1, o1)),
                    jax.tree.leaves(flat.unflatten_tree(s2, o2))):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    lrs, decays = (0.05, 0.1, 0.2), (0.99, 0.98, 0.97)
    mom1 = tuple(jnp.ones_like(x) for x in b1)
    mom2 = tuple(jnp.ones_like(x) for x in b2)
    g1 = tuple(0.5 * jnp.ones_like(x) for x in b1)
    g2 = tuple(0.5 * jnp.ones_like(x) for x in b2)
    v1, mp1 = flat.storm_partial_step(s1, b1, mom1, g1, lrs, decays)
    v2, mp2 = flat.storm_partial_step(s2, b2, mom2, g2, lrs, decays)
    for t1, t2 in ((v1, v2), (mp1, mp2)):
        for a, b in zip(jax.tree.leaves(flat.unflatten_tree(s1, t1)),
                        jax.tree.leaves(flat.unflatten_tree(s2, t2))):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_shard_ctx_validation():
    """Mismatched spec/mesh shards and missing axes fail loudly."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(2, 1)
    ctx = flat.make_shard_ctx(mesh)
    tree = {"x": jnp.ones((16,)), "y": jnp.ones((16,))}
    spec = flat.make_spec(tree, sections=("x", "y"), block=8, shards=2)
    btree = _client_stack(tree, 4)
    bufs = flat.flatten_tree(spec, btree, batch_dims=1)
    with pytest.raises(ValueError, match="shards"):
        flat.client_mean_masked(spec, bufs, ("mean", "none"), shard=ctx)
    with pytest.raises(ValueError, match="axis"):
        flat.make_shard_ctx(mesh, model_axis="nope")


# ---------------------------------------------------------------------------
# multi-device execution (subprocess: the device-count flag must be set
# before jax initialises)
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.config import FederatedConfig
    from repro.configs import ARCHS
    from repro.data import make_fed_batch_fn
    from repro.federation import trainer as tr
    from repro.federation.participation import ParticipationSpec
    from repro.optim import flat

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    ctx = flat.make_shard_ctx(mesh)

    # --- substrate level: psum comm vs single-device, private bit-exact ---
    key = jax.random.PRNGKey(0)
    tree = {"x": jnp.zeros((70,)), "y": jnp.zeros((30,)),
            "u": jnp.zeros((26,))}
    M = 8
    btree = jax.tree.map(
        lambda v: jax.random.normal(key, (M,) + v.shape), tree)
    s1 = flat.make_spec(tree, sections=("x", "y", "u"), block=8, shards=1)
    s2 = flat.make_spec(tree, sections=("x", "y", "u"), block=8, shards=2)
    b1 = flat.flatten_tree(s1, btree, batch_dims=1)
    b2 = flat.flatten_tree(s2, btree, batch_dims=1)
    w = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
    for ctx_i in (ctx, flat.make_shard_ctx(mesh, use_scatter=True)):
        for modes in (("mean", "none", "group"), ("mean", "none", "mean")):
            ref = flat.unflatten_tree(s1, flat.client_mean_masked(
                s1, b1, modes, weights=w))
            out = flat.unflatten_tree(s2, jax.jit(
                lambda b: flat.client_mean_masked(
                    s2, b, modes, weights=w, shard=ctx_i))(b2))
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)
            # private y: bit-exact vs the INPUT (never entered a collective)
            for a, b in zip(jax.tree.leaves(btree["y"]),
                            jax.tree.leaves(out["y"])):
                np.testing.assert_array_equal(
                    np.asarray(a).view(np.uint8),
                    np.asarray(b).view(np.uint8))
    # the sharded comm compiles to a REAL all-reduce (no broadcast-mean)
    hlo = jax.jit(lambda b: flat.client_mean_masked(
        s2, b, ("mean", "none", "mean"), shard=ctx)
        ).lower(b2).compile().as_text()
    assert "all-reduce" in hlo, "sharded comm lowered without a collective"

    # the guard rails fire on real multi-device meshes too
    try:
        flat.client_mean_masked(s1, b1, ("mean", "none", "mean"), shard=ctx)
        raise SystemExit("spec/mesh shards mismatch not caught")
    except ValueError as e:
        assert "shards" in str(e), e
    try:
        flat.client_mean_masked(
            s2, tuple(b[:5] for b in b2), ("mean", "none", "mean"),
            shard=ctx)
        raise SystemExit("client-axis divisibility not caught")
    except ValueError as e:
        assert "divisible" in str(e), e
    try:
        flat.make_shard_ctx(mesh, model_axis="nope")
        raise SystemExit("missing mesh axis not caught")
    except ValueError as e:
        assert "axis" in str(e), e
    print("SUBSTRATE_OK")

    # --- engine level: all five algos, sharded vs single-device ---
    cfg = ARCHS["mamba2-130m"].reduced()
    from repro.models import build_model
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=2, lr_x=0.05,
                          lr_y=0.05, lr_u=0.05, neumann_q=2,
                          neumann_tau=0.3)
    batch_fn = make_fed_batch_fn(cfg, num_clients=4, per_client=1,
                                 seq_len=16)
    FIELDS = {"fedbio": ("x", "y", "u"),
              "fedbioacc": ("x", "y", "u", "omega", "nu", "q"),
              "fedbio_local": ("x", "y"),
              "fedbioacc_local": ("x", "y", "omega", "nu"),
              "fedavg": ("params", "mom")}

    def run(algo, steps=3, **kw):
        maker = getattr(tr, f"make_{algo}_train_step")
        init, step = maker(model, fed, n_micro=1, remat=False,
                           fuse_storm=True, storm_block=256, **kw)
        st = init(jax.random.PRNGKey(0))
        jstep = jax.jit(step, donate_argnums=(0,))
        key = jax.random.PRNGKey(1)
        for _ in range(steps):
            key, sub = jax.random.split(key)
            st, _ = jstep(st, batch_fn(sub))
        return step.views(st)

    for algo, fields in FIELDS.items():
        ref = run(algo)
        out = run(algo, mesh=mesh)
        for n in fields:
            for a, b in zip(jax.tree.leaves(getattr(ref, n)),
                            jax.tree.leaves(getattr(out, n))):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-4,
                    err_msg=f"{algo}.{n}")
        print(f"ALGO_OK {algo}")

    # --- m = M/2 participation + overlap schedule, sharded vs single ---
    pspec = ParticipationSpec(sampler="uniform", clients_per_round=2)
    for kw in ({"participation": pspec}, {"overlap": True},
               {"participation": pspec, "overlap": True}):
        ref = run("fedbioacc", **kw)
        out = run("fedbioacc", mesh=mesh, **kw)
        for n in FIELDS["fedbioacc"]:
            for a, b in zip(jax.tree.leaves(getattr(ref, n)),
                            jax.tree.leaves(getattr(out, n))):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-4,
                    err_msg=f"participation/overlap {kw} {n}")
    print("PARTICIPATION_OVERLAP_OK")
""")


@pytest.mark.timeout(900)
@pytest.mark.mesh_subprocess
def test_sharded_substrate_executes_and_matches():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=850)
    assert res.returncode == 0, res.stderr[-4000:]
    for marker in ("SUBSTRATE_OK", "ALGO_OK fedbio", "ALGO_OK fedbioacc",
                   "ALGO_OK fedbio_local", "ALGO_OK fedbioacc_local",
                   "ALGO_OK fedavg", "PARTICIPATION_OVERLAP_OK"):
        assert marker in res.stdout, (marker, res.stdout, res.stderr[-2000:])
