import os
import sys

# tests must see the single real CPU device (the 512-device override is
# exclusively for launch.dryrun)
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
