import os
import sys

# tests must see the single real CPU device (the 512-device override is
# exclusively for launch.dryrun)
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_collection_modifyitems(config, items):
    # every @pytest.mark.mesh_subprocess test spawns an 8-device subprocess
    # replaying a full trajectory — under pytest-xdist, pin them all into
    # ONE serial group (same worker, never concurrent with each other) so
    # CPU contention cannot push their numeric tolerances over the edge
    if not config.pluginmanager.hasplugin("xdist"):
        return
    for item in items:
        if item.get_closest_marker("mesh_subprocess"):
            item.add_marker(pytest.mark.xdist_group("mesh_subprocess"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
