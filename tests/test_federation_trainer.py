"""Model-scale federated train steps: semantics of local steps vs
communication rounds, loss descent, and the fused-STORM equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FederatedConfig
from repro.configs import ARCHS
from repro.data import make_fed_batch_fn
from repro.federation.trainer import (make_fedavg_train_step,
                                      make_fedbio_train_step,
                                      make_fedbioacc_train_step)
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["granite-8b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=3, lr_x=0.05, lr_y=0.05,
                          lr_u=0.05)
    batch_fn = make_fed_batch_fn(cfg, num_clients=4, per_client=2, seq_len=32)
    return cfg, model, fed, batch_fn


def _client_spread(tree):
    return max(float(jnp.max(jnp.std(v.astype(jnp.float32), axis=0)))
               for v in jax.tree.leaves(tree))


@pytest.mark.parametrize("maker", [make_fedbio_train_step,
                                   make_fedbioacc_train_step])
def test_clients_drift_then_sync(setup, maker):
    """Between communication rounds client states diverge; at step % I == 0
    they are exactly averaged (spread returns to 0)."""
    cfg, model, fed, batch_fn = setup
    init, step = maker(model, fed, n_micro=1, remat=False)
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    key = jax.random.PRNGKey(1)
    assert _client_spread(state.x) == 0.0
    spreads = []
    for t in range(3):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
        spreads.append(_client_spread(state.x))
    # step 2 drifts (fedbioacc's zero-momentum init makes step 1 a warm-up
    # no-op for x; fedbio drifts immediately); step 3 (== I) averages
    if maker is make_fedbio_train_step:
        assert spreads[0] > 0.0
    assert spreads[1] > 0.0
    assert spreads[2] < 1e-6, spreads


def test_fedbioacc_loss_descends(setup):
    cfg, model, fed, batch_fn = setup
    init, step = make_fedbioacc_train_step(model, fed, n_micro=1, remat=False)
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step, donate_argnums=(0,))
    key = jax.random.PRNGKey(1)

    def val_loss(state):
        p = {"body": jax.tree.map(lambda v: v[0], state.x),
             "head": jax.tree.map(lambda v: v[0], state.y)}
        b = jax.tree.map(lambda v: v[0], batch_fn(jax.random.PRNGKey(99)))
        return float(model.loss(p, b["val"])[0])

    l0 = val_loss(state)
    for _ in range(12):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    lT = val_loss(state)
    assert lT < l0, (l0, lT)
    assert not np.isnan(lT)


def test_fedavg_loss_descends(setup):
    cfg, model, fed, batch_fn = setup
    init, step = make_fedavg_train_step(model, fed, n_micro=1, remat=False)
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step, donate_argnums=(0,))
    key = jax.random.PRNGKey(1)

    def val_loss(state):
        p = jax.tree.map(lambda v: v[0], state.params)
        b = jax.tree.map(lambda v: v[0], batch_fn(jax.random.PRNGKey(99)))
        return float(model.loss(p, b["val"])[0])

    l0 = val_loss(state)
    for _ in range(10):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    assert val_loss(state) < l0


def test_microbatching_matches_full_batch(setup):
    """n_micro>1 gradient accumulation must not change the step (up to fp)."""
    cfg, model, fed, batch_fn = setup
    init1, step1 = make_fedbio_train_step(model, fed, n_micro=1, remat=False)
    init2, step2 = make_fedbio_train_step(model, fed, n_micro=2, remat=True)
    state = init1(jax.random.PRNGKey(0))
    batch = batch_fn(jax.random.PRNGKey(1))
    s1, _ = jax.jit(step1)(state, batch)
    s2, _ = jax.jit(step2)(state, batch)
    for a, b in zip(jax.tree.leaves(s1.x), jax.tree.leaves(s2.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)
