"""Compressed communication policies (quantized + top-k sparsified
reductions with error feedback):

* the Pallas int8 pack/unpack kernel (interpret mode) is BIT-identical to
  its jnp lowering — same rounding, same zero-tile guard — so the substrate
  can dispatch per backend without changing a communicated bit;
* compression OFF is a no-op: engines built with ``compression=None`` and
  with the kwarg omitted produce uint8-identical trajectories for all five
  algorithms, and the ``FlatState`` keeps its pre-compression structure
  (``ef == ()``: zero pytree leaves — checkpoints and jit caches intact);
* error-feedback accumulation is exact under participation masks: for a
  top-k-only policy ``sent + new_e == acc`` bitwise, and non-participants'
  rows AND feedback buffers are frozen bit-exactly;
* quantized compression composes with the hierarchical grouped mean; top-k
  does not (asserted at the substrate, ValueError'd at the engine/spec);
* ``make_engine`` / ``Experiment.validate`` reject every inconsistent
  combination with actionable errors, and the spec round-trips JSON;
* the dryrun HLO audit passes iff the narrow-dtype collective bytes cover
  the analytic wire model;
* sharded compressed trajectories match the unsharded path on a real
  8-device mesh (subprocess) within wire-quantization tolerance — private
  sections bit-exactly (they never enter a collective, compressed or not).
"""
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.storm.quantpack import (quantpack_flat, quantpack_flat_jnp,
                                           quantunpack_flat,
                                           quantunpack_flat_jnp)
from repro.optim import flat
from repro.optim import sequences as seqs
from repro.federation.compression import (CompressionSpec,
                                          uplink_bytes_per_elem,
                                          wire_bytes_per_elem)


# ---------------------------------------------------------------------------
# kernel: pack/unpack bit-identity
# ---------------------------------------------------------------------------

def _edge_buffer(block):
    """Tiles exercising the edge cases: zeros (guarded divisor), ties,
    negatives, huge/tiny magnitudes, exact half-way rounding points."""
    rng = np.random.default_rng(0)
    tiles = [np.zeros(block),                                 # all-zero tile
             np.full(block, -3.25),                           # ties
             rng.normal(size=block) * 1e4,
             rng.normal(size=block) * 1e-4,
             np.linspace(-127.0, 127.0, block)]               # half-way pts
    return jnp.asarray(np.concatenate(tiles), jnp.float32)


@pytest.mark.parametrize("block", [8, 64, 256])
def test_quantpack_kernel_bit_identical_to_jnp(block):
    x = _edge_buffer(block)
    qk, sk = quantpack_flat(x, block=block, interpret=True)
    qj, sj = quantpack_flat_jnp(x, block=block)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qj))
    np.testing.assert_array_equal(
        np.asarray(sk).view(np.uint8), np.asarray(sj).view(np.uint8))
    dk = quantunpack_flat(qk, sk, block=block, interpret=True)
    dj = quantunpack_flat_jnp(qj, sj, block=block)
    np.testing.assert_array_equal(
        np.asarray(dk).view(np.uint8), np.asarray(dj).view(np.uint8))
    # zero tile stays exactly zero; |q| bounded by 127
    np.testing.assert_array_equal(np.asarray(dk[:block]), np.zeros(block))
    assert int(np.abs(np.asarray(qk)).max()) <= 127


def test_quant_dequant_error_bounded():
    """Symmetric per-tile int8: |x - dequant| <= scale/2 per element."""
    block = 64
    x = _edge_buffer(block)
    q, s = quantpack_flat_jnp(x, block=block)
    d = quantunpack_flat_jnp(q, s, block=block)
    err = np.abs(np.asarray(x) - np.asarray(d)).reshape(-1, block)
    bound = np.asarray(s)[:, None] / 2 + 1e-7
    assert (err <= bound).all()


# ---------------------------------------------------------------------------
# toy engine fixture (all five algorithms)
# ---------------------------------------------------------------------------

def _toy_cfg(**kw):
    base = dict(local_steps=2, hierarchy_period=0, hierarchy_groups=2,
                lr_x=0.05, lr_y=0.05, lr_u=0.05,
                c_nu=1.0, c_omega=1.0, c_u=1.0,
                alpha_delta=1.0, alpha_u0=4.0)
    base.update(kw)
    return SimpleNamespace(**base)


_TPL = {"x": {"w": np.zeros((3, 5), np.float32)},
        "y": {"h": np.zeros((7,), np.float32)},
        "u": {"g": np.zeros((11,), np.float32)},
        "params": {"w": np.zeros((3, 5), np.float32)}}


def _toy_oracle(views, batch):
    return {s: jax.tree.map(lambda v: 0.3 * v + batch, views[s])
            for s in views}


def _toy_run(name, compression, steps=4, cfg=None, shard=None, M=4,
             **engine_kw):
    aspec = seqs.SPECS[name]
    t = {s: _TPL[s] for s in aspec.sections}
    eng = seqs.make_engine(cfg or _toy_cfg(), aspec, t, _toy_oracle,
                           block=8, compression=compression, shard=shard,
                           **engine_kw)
    rng = np.random.default_rng(7)
    init = {s: {k: jnp.asarray(rng.normal(size=(M,) + v.shape)
                               .astype(np.float32))
                for k, v in _TPL[s].items()} for s in aspec.sections}
    st = eng.init_state(init)
    for i in range(steps):
        st = eng.step(st, jnp.float32(0.01 * i))
    return st


@pytest.mark.parametrize("name", sorted(seqs.SPECS))
def test_compression_off_bit_identical(name):
    """compression=None must be byte-for-byte the engine with the kwarg
    omitted — and keep the FlatState's zero-leaf ``ef`` slot."""
    aspec = seqs.SPECS[name]
    t = {s: _TPL[s] for s in aspec.sections}
    e_off = seqs.make_engine(_toy_cfg(), aspec, t, _toy_oracle, block=8,
                             compression=None)
    e_omit = seqs.make_engine(_toy_cfg(), aspec, t, _toy_oracle, block=8)
    rng = np.random.default_rng(7)
    init = {s: {k: jnp.asarray(rng.normal(size=(4,) + v.shape)
                               .astype(np.float32))
                for k, v in _TPL[s].items()} for s in aspec.sections}
    sa, sb = e_off.init_state(init), e_omit.init_state(init)
    for i in range(4):
        sa = e_off.step(sa, jnp.float32(0.01 * i))
        sb = e_omit.step(sb, jnp.float32(0.01 * i))
    assert sa.ef == () and sb.ef == ()
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(
            np.asarray(a).reshape(-1).view(np.uint8),
            np.asarray(b).reshape(-1).view(np.uint8))


def test_compressed_engine_carries_ef_and_stays_finite():
    st = _toy_run("fedbioacc", CompressionSpec(quant="int8", topk_frac=0.25))
    assert st.ef, "top-k with error feedback must carry FlatState.ef"
    for grpd in st.ef:
        for e in grpd:
            assert np.isfinite(np.asarray(e)).all()
    st_q = _toy_run("fedbioacc", CompressionSpec(quant="bf16"))
    assert st_q.ef == (), "quant-only compression needs no feedback state"


# ---------------------------------------------------------------------------
# substrate: error feedback exact under participation masks
# ---------------------------------------------------------------------------

def _flat_fixture(M=4, block=8):
    tree = {"x": jnp.zeros((24,)), "y": jnp.zeros((16,))}
    spec = flat.make_spec(tree, sections=("x", "y"), block=block)
    rng = np.random.default_rng(3)
    btree = jax.tree.map(
        lambda v: jnp.asarray(rng.normal(size=(M,) + v.shape),
                              jnp.float32), tree)
    bufs = flat.flatten_tree(spec, btree, batch_dims=1)
    return spec, btree, bufs


def test_error_feedback_exact_and_frozen_under_mask():
    """Top-k-only policy: sent + new_e == acc BITWISE (the kept entries are
    where-selected verbatim, the dropped ones subtract to themselves), and
    non-participants' rows and feedback buffers are frozen bit-exactly.
    One participant with weight 1 makes the participant mean the sent row
    itself, so the identity is observable from the public API."""
    spec, btree, bufs = _flat_fixture()
    ccfg = flat.CompressCfg(quant=None, topk_frac=0.5)
    rng = np.random.default_rng(11)
    ef = tuple(jnp.asarray(rng.normal(size=b.shape), jnp.float32)
               for b in bufs)
    w = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    out, ef_out = flat.client_mean_masked(spec, bufs, ("mean", "mean"),
                                          weights=w, compress=ccfg, ef=ef)
    for b, e0, o, e1 in zip(bufs, ef, out, ef_out):
        b, e0, o, e1 = (np.asarray(v) for v in (b, e0, o, e1))
        acc = b + e0
        # participant 0: out row + its new feedback == row + entering
        # feedback, bitwise (top-k only: sent entries are acc verbatim,
        # dropped entries leave new_e = acc - 0; the sole participant's
        # weighted mean is its own send, scaled by M/M)
        np.testing.assert_array_equal((o[0] + e1[0]).view(np.uint8),
                                      acc[0].view(np.uint8))
        # exactly ceil(0.5 * block) survivors per tile (distinct magnitudes)
        kept = (o[0].reshape(-1, 8) != 0).sum(axis=1)
        assert (kept == 4).all(), kept
        # non-participants: rows AND feedback frozen bit-exactly
        for i in (1, 2, 3):
            np.testing.assert_array_equal(o[i].view(np.uint8),
                                          b[i].view(np.uint8))
            np.testing.assert_array_equal(e1[i].view(np.uint8),
                                          e0[i].view(np.uint8))


def test_private_sections_never_compressed():
    """'none'-mode tiles pass through bit-identically no matter the
    compression policy."""
    spec, btree, bufs = _flat_fixture()
    ccfg = flat.CompressCfg(quant="int8", topk_frac=0.25)
    ef = tuple(jnp.zeros_like(b) for b in bufs)
    out, _ = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                     compress=ccfg, ef=ef)
    got = flat.unflatten_tree(spec, out)
    for a, b in zip(jax.tree.leaves(btree["y"]), jax.tree.leaves(got["y"])):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))


def test_grouped_mean_quant_composes_topk_asserts():
    spec, btree, bufs = _flat_fixture()
    out = flat.client_mean_masked(spec, bufs, ("group", "none"),
                                  num_groups=2,
                                  compress=flat.CompressCfg(quant="int8"))
    assert isinstance(out, tuple) and len(out) == 2
    vals, ef_out = out
    assert ef_out == ()
    for v in vals:
        assert np.isfinite(np.asarray(v)).all()
    with pytest.raises(AssertionError):
        flat.client_mean_masked(
            spec, bufs, ("group", "none"), num_groups=2,
            compress=flat.CompressCfg(quant="int8", topk_frac=0.25),
            ef=tuple(jnp.zeros_like(b) for b in bufs))


# ---------------------------------------------------------------------------
# engine / spec rejection + serialization
# ---------------------------------------------------------------------------

def test_make_engine_rejects_inconsistent_compression():
    bad = [
        (CompressionSpec(), {}, "no compressor"),
        (CompressionSpec(quant="fp4"), {}, "unknown"),
        (CompressionSpec(quant="int8", topk_frac=1.5), {}, "0, 1"),
        (CompressionSpec(quant="int8", sections=("zz",)), {}, "unknown"),
        (CompressionSpec(quant="int8", topk_frac=0.1),
         {"hierarchy_period": 2}, "hierarch"),
    ]
    for csp, cfg_kw, match in bad:
        with pytest.raises(ValueError, match=match):
            _toy_run("fedbioacc", csp, steps=0, cfg=_toy_cfg(**cfg_kw))
    with pytest.raises(ValueError, match="private"):
        _toy_run("fedbioacc_local",
                 CompressionSpec(quant="int8", sections=("y",)), steps=0)
    from repro.federation.faults import FaultSpec, make_faults
    with pytest.raises(ValueError, match="faults"):
        _toy_run("fedbioacc", CompressionSpec(quant="int8"), steps=0,
                 faults=make_faults(FaultSpec(nan_rate=0.1), 4))


def test_quant_composes_with_hierarchy_at_engine():
    st = _toy_run("fedbioacc", CompressionSpec(quant="int8"),
                  cfg=_toy_cfg(hierarchy_period=2))
    for v in st.vars:
        assert np.isfinite(np.asarray(v)).all()


def test_experiment_spec_roundtrip_and_rejections():
    from repro.api.spec import Experiment, SpecError
    base = Experiment().edit(**{"execution.fuse_storm": True,
                                "schedule.steps": 2})
    c = base.edit(**{"compression.quant": "int8",
                     "compression.topk_frac": 0.1,
                     "compression.sections": ["x"]})
    c.validate()
    r = Experiment.from_json(c.to_json())
    assert r == c and hash(r) == hash(c)
    assert r.compression.sections == ("x",)

    cases = [
        ({"execution.fuse_storm": False, "compression.quant": "int8"},
         "fuse_storm"),
        ({"compression.quant": "fp4"}, "unknown quant"),
        ({"compression.topk_frac": 1.5}, "not in"),
        ({"compression.error_feedback": False}, "no compressor"),
        ({"compression.topk_frac": 0.1, "schedule.hierarchy_period": 2},
         "hierarch"),
        ({"compression.quant": "int8", "compression.sections": ["zz"]},
         "not sections"),
        ({"compression.quant": "int8", "compression.sections": []},
         "compresses nothing"),
        ({"compression.quant": "int8", "faults.nan_rate": 0.1},
         "faults"),
        ({"algorithm.name": "fedbioacc_local", "compression.quant": "int8",
          "compression.sections": ["y"]}, "PRIVATE"),
    ]
    for edits, match in cases:
        with pytest.raises(SpecError, match=match):
            base.edit(**edits).validate()


def test_bytes_models():
    assert uplink_bytes_per_elem(CompressionSpec(), 256) == 4.0
    assert wire_bytes_per_elem(CompressionSpec(quant="bf16"), 256) == 2.0
    up = uplink_bytes_per_elem(
        CompressionSpec(quant="int8", topk_frac=0.1), 256)
    assert 4.0 / up >= 4.0, up          # the acceptance headline
    w = wire_bytes_per_elem(CompressionSpec(quant="int8"), 256)
    assert w == 1.0 + 4.0 / 256


def test_dryrun_collective_audit():
    from repro.api.spec import Experiment
    from repro.launch.dryrun import _check_compressed_collectives
    exp = Experiment().edit(**{"execution.fuse_storm": True,
                               "compression.quant": "int8"})
    tree = {"x": jnp.zeros((24,)), "y": jnp.zeros((16,)),
            "u": jnp.zeros((8,))}
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8, shards=2)
    elems = sum(b - a for g in spec.groups for _, a, b in g.extents)
    ok = _check_compressed_collectives(
        exp, spec, {"bytes_by_dtype": {"s8": 2 * elems, "f32": 999}})
    assert ok["ok"] and ok["expected_bytes"] == 2 * elems
    with pytest.raises(RuntimeError, match="f32 collectives"):
        _check_compressed_collectives(
            exp, spec, {"bytes_by_dtype": {"f32": 8 * elems}})


def test_hlo_stats_bytes_by_dtype():
    from repro.launch.hlo_stats import collective_bytes
    hlo = "\n".join([
        "  %ar = s8[64]{0} all-reduce(s8[64]{0} %q), replica_groups={}",
        "  %ar2 = f32[8]{0} all-reduce(f32[8]{0} %s), replica_groups={}",
        "  %ag = bf16[32]{0} all-gather(bf16[16]{0} %p), dimensions={0}",
    ])
    out = collective_bytes(hlo)
    assert out["bytes_by_dtype"] == {"s8": 64, "f32": 32, "bf16": 64}
    assert out["total_bytes"] == 160          # existing keys unchanged
    assert out["counts"]["all-reduce"] == 2


# ---------------------------------------------------------------------------
# sharded vs unsharded compressed (8-device subprocess)
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from types import SimpleNamespace
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.optim import flat, sequences as seqs

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    ctx = flat.make_shard_ctx(mesh)

    # --- substrate: compressed masked reduction, sharded vs single ---
    key = jax.random.PRNGKey(0)
    tree = {"x": jnp.zeros((70,)), "y": jnp.zeros((30,)),
            "u": jnp.zeros((26,))}
    M = 8
    btree = jax.tree.map(
        lambda v: jax.random.normal(key, (M,) + v.shape), tree)
    s1 = flat.make_spec(tree, sections=("x", "y", "u"), block=8, shards=1)
    s2 = flat.make_spec(tree, sections=("x", "y", "u"), block=8, shards=2)
    b1 = flat.flatten_tree(s1, btree, batch_dims=1)
    b2 = flat.flatten_tree(s2, btree, batch_dims=1)
    w = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
    ccfg = flat.CompressCfg(quant="int8", topk_frac=0.25)
    ef1 = tuple(jnp.zeros_like(b) for b in b1)
    ef2 = tuple(jnp.zeros_like(b) for b in b2)
    o1, e1 = flat.client_mean_masked(s1, b1, ("mean", "none", "mean"),
                                     weights=w, compress=ccfg, ef=ef1)
    o2, e2 = jax.jit(lambda b, e: flat.client_mean_masked(
        s2, b, ("mean", "none", "mean"), weights=w, shard=ctx,
        compress=ccfg, ef=e))(b2, ef2)
    t1, t2 = flat.unflatten_tree(s1, o1), flat.unflatten_tree(s2, o2)
    for sec in ("x", "u"):       # wire quant is the one extra lossy stage
        for a, b in zip(jax.tree.leaves(t1[sec]), jax.tree.leaves(t2[sec])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0.05, atol=0.15,
                                       err_msg=f"compressed {sec}")
    # private y: bit-exact vs the INPUT on BOTH paths
    for t in (t1, t2):
        for a, b in zip(jax.tree.leaves(btree["y"]),
                        jax.tree.leaves(t["y"])):
            np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                          np.asarray(b).view(np.uint8))
    # non-participants sent nothing: their error-feedback rows stay zero
    # (bit-exact freeze of the entering zeros) on BOTH paths
    for a, b in zip(e1, e2):
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_array_equal(a[[1, 4]], np.zeros_like(a[[1, 4]]))
        np.testing.assert_array_equal(b[[1, 4]], np.zeros_like(b[[1, 4]]))
    print("COMPRESSED_SUBSTRATE_OK")

    # --- engine: compressed trajectories, sharded vs single-device ---
    cfg = SimpleNamespace(local_steps=2, hierarchy_period=0,
                          hierarchy_groups=2, lr_x=0.05, lr_y=0.05,
                          lr_u=0.05, c_nu=1.0, c_omega=1.0, c_u=1.0,
                          alpha_delta=1.0, alpha_u0=4.0)
    tpl = {"x": {"w": np.zeros((3, 5), np.float32)},
           "y": {"h": np.zeros((7,), np.float32)},
           "u": {"g": np.zeros((11,), np.float32)},
           "params": {"w": np.zeros((3, 5), np.float32)}}

    def oracle(views, batch):
        return {s: jax.tree.map(lambda v: 0.3 * v + batch, views[s])
                for s in views}

    from repro.federation.compression import CompressionSpec
    csp = CompressionSpec(quant="int8", topk_frac=0.25)
    for name in ("fedbioacc", "fedbioacc_local", "fedavg"):
        aspec = seqs.SPECS[name]
        t = {s: tpl[s] for s in aspec.sections}
        rng = np.random.default_rng(7)
        init = {s: {k: jnp.asarray(
            rng.normal(size=(4,) + v.shape).astype(np.float32))
            for k, v in tpl[s].items()} for s in aspec.sections}

        def run(shard):
            eng = seqs.make_engine(cfg, aspec, t, oracle, block=8,
                                   compression=csp, shard=shard)
            st = eng.init_state(jax.tree.map(jnp.array, init))
            for i in range(4):
                st = eng.step(st, jnp.float32(0.01 * i))
            # sharded/unsharded buffer layouts differ — compare the views
            return eng.views(st)[0]

        v1, v2 = run(None), run(ctx)
        for sec in aspec.sections:
            for a, b in zip(jax.tree.leaves(v1[sec]),
                            jax.tree.leaves(v2[sec])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=0.05, atol=0.2,
                                           err_msg=f"{name}/{sec}")
        print(f"COMPRESSED_ENGINE_OK {name}")
""")


@pytest.mark.timeout(900)
@pytest.mark.mesh_subprocess
def test_sharded_compressed_matches_unsharded():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=850)
    assert res.returncode == 0, res.stderr[-4000:]
    for marker in ("COMPRESSED_SUBSTRATE_OK", "COMPRESSED_ENGINE_OK fedbioacc",
                   "COMPRESSED_ENGINE_OK fedbioacc_local",
                   "COMPRESSED_ENGINE_OK fedavg"):
        assert marker in res.stdout, (marker, res.stdout, res.stderr[-2000:])
