"""Straggler-resilient elastic rounds (repro.federation.stragglers + the
engine integration): deterministic heavy-tailed compute-time draws, the
deadline/quorum/backoff round decision (arrivals >= quorum on EVERY
accepted round), late-arrival policy semantics on the flat substrate,
over-provisioned sampling, the adaptive deadline riding FlatState,
stragglers-off bit-identity, EF freezing for non-arrivals, the declarative
spec surface, and the quorum-miss telemetry trail end to end."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AlgorithmSpec, Experiment, ProblemSpec, ScheduleSpec,
                       SpecError, StragglerSpec)
from repro.config import FederatedConfig
from repro.federation.participation import (ParticipationSpec,
                                            make_participation)
from repro.federation.stragglers import (ARRIVAL_HIST_BINS,
                                         arrival_histogram,
                                         expected_arrival_fraction,
                                         make_stragglers, over_provision,
                                         simulate_rounds)
from repro.optim import flat
from repro.optim import sequences as seqs

M = 4

# seed=0 round 0 over M=4: times ~[0.37, 1.56, 0.79, 0.33] — client 1
# misses a 1.0 deadline while quorum 0.25 holds without extensions
_MIXED = StragglerSpec(base_time=1.0, tail=1.0, deadline=1.0, quorum=0.25,
                       max_extensions=0, adapt_rate=0.0, seed=0,
                       over_provision=0)


# ---------------------------------------------------------------------------
# compute-time draws: determinism, resume, tails
# ---------------------------------------------------------------------------

def test_times_deterministic_and_resumable():
    """Same (seed, round, client) ⇒ same times across independent engines
    and regardless of evaluation order (resume safety), incl. under jit."""
    spec = StragglerSpec(tail=1.0, seed=5)
    s1, s2 = make_stragglers(spec, 8), make_stragglers(spec, 8)
    seq1 = [np.asarray(s1.round_times(r)) for r in range(10)]
    for r in (0, 4, 9):                     # s2 jumps straight to round r
        np.testing.assert_array_equal(seq1[r], np.asarray(s2.round_times(r)))
    jt = jax.jit(s1.round_times)
    np.testing.assert_array_equal(seq1[3], np.asarray(jt(jnp.int32(3))))
    s3 = make_stragglers(spec._replace(seed=6), 8)
    assert any(not np.array_equal(seq1[r], np.asarray(s3.round_times(r)))
               for r in range(10))
    # rounds are independent draws, not a shared permutation
    assert not np.array_equal(seq1[0], seq1[1])


def test_times_lognormal_shape():
    """tail=0 collapses to base_time exactly; a heavy tail spreads the
    distribution around the base_time median."""
    s0 = make_stragglers(StragglerSpec(base_time=2.5, tail=0.0), 16)
    np.testing.assert_allclose(np.asarray(s0.round_times(0)),
                               np.full(16, 2.5), rtol=1e-6)
    s1 = make_stragglers(StragglerSpec(base_time=1.0, tail=1.0, seed=3), 256)
    t = np.asarray(s1.round_times(0))
    assert t.min() > 0.0
    assert np.median(t) == pytest.approx(1.0, rel=0.35)
    assert t.max() / t.min() > 10.0         # heavy heterogeneity


def test_make_stragglers_validation():
    assert make_stragglers(None, 4) is None
    for bad in ({"late_policy": "defer"}, {"base_time": 0.0},
                {"tail": -1.0}, {"deadline": 0.0}, {"over_provision": -1},
                {"quorum": 0.0}, {"quorum": 1.5}, {"backoff": 0.5},
                {"max_extensions": -1}, {"target_percentile": 0.0},
                {"adapt_rate": 1.5}, {"start_round": -1}):
        with pytest.raises(ValueError, match=next(iter(bad))):
            make_stragglers(StragglerSpec(**bad), 4)


# ---------------------------------------------------------------------------
# the round decision: deadline, quorum, backoff ladder, fallback
# ---------------------------------------------------------------------------

def _decide(strag, r, sampled, dl):
    arr, eff, ext, nd = strag.round_decision(r, jnp.asarray(sampled,
                                                            jnp.float32),
                                             jnp.float32(dl))
    return np.asarray(arr), float(eff), int(ext), float(nd)


def test_round_decision_generous_deadline():
    """A deadline beating every sampled time accepts the whole sample with
    zero extensions at the deadline itself."""
    strag = make_stragglers(_MIXED, M)
    arr, eff, ext, _ = _decide(strag, 0, np.ones(M), 100.0)
    np.testing.assert_array_equal(arr, np.ones(M))
    assert eff == 100.0 and ext == 0


def test_round_decision_mixed_round():
    """The seed-0 round: client 1 misses the 1.0 deadline, quorum holds
    without extensions, non-sampled clients never arrive."""
    strag = make_stragglers(_MIXED, M)
    arr, eff, ext, _ = _decide(strag, 0, np.ones(M), 1.0)
    np.testing.assert_array_equal(arr, [1.0, 0.0, 1.0, 1.0])
    assert eff == 1.0 and ext == 0
    # a non-sampled client is not an arrival even if its draw is fast
    arr2, _, _, _ = _decide(strag, 0, [0.0, 1.0, 1.0, 1.0], 100.0)
    np.testing.assert_array_equal(arr2, [0.0, 1.0, 1.0, 1.0])


def test_round_decision_extension_ladder():
    """A deadline below the quorum order statistic extends through
    deadline * backoff**k; ext reports the first rung that makes quorum."""
    strag = make_stragglers(_MIXED._replace(quorum=0.75, backoff=2.0,
                                            max_extensions=3), M)
    t = np.sort(np.asarray(strag.round_times(0)))    # q = 3 → t[2] ≈ 0.79
    dl = 0.9 * t[2]                                  # rung 0 misses quorum
    arr, eff, ext, _ = _decide(strag, 0, np.ones(M), dl)
    assert ext >= 1 and eff == pytest.approx(dl * 2.0 ** ext, rel=1e-6)
    assert arr.sum() >= 3


def test_round_decision_full_miss_falls_back_to_quorum():
    """When the ENTIRE sampled set misses every rung of the ladder the
    round falls back to the quorum-th order statistic: arrivals == quorum
    exactly, ext == max_extensions + 1 (the exhausted marker) — so
    arrivals >= quorum holds on every accepted round by construction."""
    strag = make_stragglers(_MIXED._replace(max_extensions=1, backoff=1.5), M)
    t = np.sort(np.asarray(strag.round_times(0)))
    dl = 0.01                       # dl and dl*1.5 both below min time
    assert dl * 1.5 < t[0]
    arr, eff, ext, _ = _decide(strag, 0, np.ones(M), dl)
    q = int(strag.quorum_count(jnp.ones(M)))
    assert int(arr.sum()) == q == 1
    assert ext == 2                 # max_extensions + 1
    assert eff == pytest.approx(t[q - 1], rel=1e-6)


def test_round_decision_quorum_always_met():
    """Fuzz the invariant across rounds, deadlines and sample sizes."""
    strag = make_stragglers(StragglerSpec(tail=1.5, quorum=0.6, seed=9,
                                          max_extensions=1), 8)
    for r in range(6):
        for dl in (0.05, 0.5, 2.0):
            sampled = np.asarray(
                jax.random.bernoulli(jax.random.fold_in(
                    jax.random.PRNGKey(r), int(dl * 100)), 0.7, (8,)),
                np.float32)
            if sampled.sum() == 0:
                sampled[0] = 1.0
            arr, _, _, _ = _decide(strag, r, sampled, dl)
            q = int(strag.quorum_count(jnp.asarray(sampled)))
            assert int(arr.sum()) >= q >= 1
            assert np.all(arr <= sampled)


def test_round_decision_warmup_and_adaptive_ema():
    """Rounds before start_round stay synchronous (everyone sampled
    arrives, deadline untouched); after it the next deadline follows
    d' = (1-rate) d + rate * t_p with the target-percentile statistic."""
    spec = _MIXED._replace(start_round=2, adapt_rate=0.5,
                           target_percentile=0.75)
    strag = make_stragglers(spec, M)
    for r in (0, 1):
        arr, eff, ext, nd = _decide(strag, r, np.ones(M), 1.0)
        np.testing.assert_array_equal(arr, np.ones(M))
        assert eff == 0.0 and ext == 0 and nd == 1.0
    t = np.sort(np.asarray(strag.round_times(2)))
    t_p = t[int(np.ceil(0.75 * M)) - 1]
    _, _, _, nd = _decide(strag, 2, np.ones(M), 1.0)
    assert nd == pytest.approx(0.5 * 1.0 + 0.5 * t_p, rel=1e-6)
    # adapt_rate=0 keeps the deadline static
    s0 = make_stragglers(spec._replace(adapt_rate=0.0, start_round=0), M)
    _, _, _, nd0 = _decide(s0, 0, np.ones(M), 1.7)
    assert nd0 == pytest.approx(1.7)


def test_over_provision_and_histogram():
    sg = StragglerSpec(over_provision=2)
    p = ParticipationSpec(sampler="uniform", clients_per_round=4)
    assert over_provision(sg, p, 8).clients_per_round == 6
    assert over_provision(sg, p._replace(clients_per_round=7),
                          8).clients_per_round == 8     # capped at M
    full = ParticipationSpec(sampler="full")
    assert over_provision(sg, full, 8) is full          # no count to bump
    assert over_provision(sg._replace(over_provision=0), p, 8) is p
    assert over_provision(sg, None, 8) is None
    # histogram: one entry per SAMPLED client; arrivals land in bins 0-3
    t = jnp.array([0.2, 0.9, 1.7, 5.0])
    h = np.asarray(arrival_histogram(t, jnp.float32(1.0),
                                     jnp.array([1.0, 1.0, 1.0, 0.0])))
    assert h.shape == (ARRIVAL_HIST_BINS,)
    assert h.sum() == 3.0                               # client 3 unsampled
    assert h[:4].sum() == 2.0 and h[-1] == 0.0


def test_simulate_rounds_elastic_beats_barrier():
    """The simulated clock: wall_clock <= wait_for_slowest on every round
    (rounds close early once everyone is in), strictly cheaper in sum
    under a heavy tail, and arrivals >= quorum throughout."""
    strag = make_stragglers(StragglerSpec(tail=1.0, deadline=1.5,
                                          quorum=0.5, seed=1), 8)
    part = make_participation(
        ParticipationSpec(sampler="uniform", clients_per_round=6), 8)
    rows = simulate_rounds(strag, part, 24)
    assert len(rows) == 24
    for r in rows:
        assert r["wall_clock"] <= r["wait_for_slowest"] + 1e-9
        assert r["arrivals"] >= r["quorum"] >= 1
        assert r["arrivals"] <= r["sampled"] == 6
    assert (sum(r["wall_clock"] for r in rows)
            < 0.9 * sum(r["wait_for_slowest"] for r in rows))
    frac = expected_arrival_fraction(strag, part, 24)
    assert 0.0 < frac <= 1.0
    assert expected_arrival_fraction(None, part) == 1.0


# ---------------------------------------------------------------------------
# engine integration on the toy flat substrate
# ---------------------------------------------------------------------------

_SHAPES = {"x": {"w": (3, 5)}, "y": {"h": (7,)}, "u": {"v": (11,)},
           "params": {"w": (3, 5), "h": (7,)}}
ALGOS = ("fedbio", "fedbioacc", "fedbio_local", "fedbioacc_local", "fedavg")


def _make(algo, **kw):
    cfg = FederatedConfig(algorithm=algo, num_clients=M, local_steps=2,
                          lr_x=0.05, lr_y=0.05, lr_u=0.05, c_nu=1.0,
                          c_omega=1.0, c_u=1.0, alpha_delta=1.0,
                          alpha_u0=4.0, hierarchy_period=0,
                          hierarchy_groups=2)
    aspec = seqs.SPECS[algo]
    tmpl = {s: {k: jax.ShapeDtypeStruct(shape, jnp.float32)
                for k, shape in _SHAPES[s].items()} for s in aspec.sections}

    def one(v, b):
        return {s: jax.tree.map(lambda t: jnp.tanh(t) + 0.01 * b, v[s])
                for s in v}

    eng = seqs.make_engine(cfg, aspec, tmpl, jax.vmap(one), block=8, **kw)
    key, i, vt = jax.random.PRNGKey(0), 0, {}
    for s in aspec.sections:
        vt[s] = {}
        for k, shape in _SHAPES[s].items():
            vt[s][k] = jax.random.normal(jax.random.fold_in(key, i),
                                         (M,) + shape)
            i += 1
    return eng, eng.init_state(vt)


def _batches(steps):
    key = jax.random.PRNGKey(7)
    return [jax.random.normal(jax.random.fold_in(key, t), (M,))
            for t in range(steps)]


def _bits(a, b):
    np.testing.assert_array_equal(np.ravel(np.asarray(a)).view(np.uint8),
                                  np.ravel(np.asarray(b)).view(np.uint8))


@pytest.mark.parametrize("algo", ALGOS)
def test_stragglers_off_is_the_synchronous_engine(algo):
    """Stragglers OFF keeps the zero-leaf deadline convention and — with a
    warmup-only straggler layer attached next to the same sampler — the
    vars/mom/stale trajectory stays BIT-identical to the synchronous
    engine: the warmup path is the literal pre-straggler round."""
    pspec = ParticipationSpec(sampler="uniform", clients_per_round=3)
    part = make_participation(pspec, M)
    eng_off, s_off = _make(algo, participation=part)
    assert s_off.deadline == ()
    strag = make_stragglers(StragglerSpec(start_round=10 ** 6), M)
    eng_on, s_on = _make(algo, participation=make_participation(pspec, M),
                         stragglers=strag)
    assert float(s_on.deadline) == strag.spec.deadline
    for b in _batches(4):
        s_off = eng_off.step(s_off, b)
        s_on = eng_on.step(s_on, b)
    for a, b in zip(s_off.vars, s_on.vars):
        _bits(a, b)
    for a, b in zip(s_off.mom, s_on.mom):
        _bits(a, b)
    _bits(s_off.stale, s_on.stale)
    assert float(s_on.deadline) == strag.spec.deadline   # warmup: untouched


def test_late_policy_semantics_on_engine():
    """One elastic round with a known miss (seed 0: client 1): `drop`
    freezes the straggler's row bit-exact and ages its staleness, `carry`
    lets the row advance locally while still aging, `cancel` freezes the
    row and treats the client as served (no aging)."""
    states = {}
    for policy in ("drop", "carry", "cancel"):
        strag = make_stragglers(_MIXED._replace(late_policy=policy), M)
        eng, s = _make("fedbioacc", stragglers=strag)
        init_vars = s.vars
        for b in _batches(2):                      # exactly one round
            s = eng.step(s, b)
        states[policy] = (init_vars, s)
    for policy in ("drop", "cancel"):
        init_vars, s = states[policy]
        for v0, v1 in zip(init_vars, s.vars):
            _bits(v0[1], v1[1])                    # straggler row frozen
            assert not np.array_equal(np.asarray(v0[0]), np.asarray(v1[0]))
    init_vars, s = states["carry"]
    assert any(not np.array_equal(np.asarray(v0[1]), np.asarray(v1[1]))
               for v0, v1 in zip(init_vars, s.vars))   # kept computing
    np.testing.assert_array_equal(np.asarray(states["drop"][1].stale),
                                  [0, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(states["carry"][1].stale),
                                  [0, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(states["cancel"][1].stale),
                                  [0, 0, 0, 0])
    # drop and cancel aggregate the same arrivals-only mean: the arrived
    # rows of the communicated section match bit-exactly
    _bits(states["drop"][1].vars[0][0], states["cancel"][1].vars[0][0])


def test_deadline_rides_state_and_updates_at_comm_steps(tmp_path):
    """The adaptive deadline is a FlatState leaf: constant within a round,
    stepped through the EMA exactly at comm steps, carried bit-exactly
    through a checkpoint round-trip (the resume path), and seedable
    through init_state(deadline=...)."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    strag = make_stragglers(_MIXED._replace(adapt_rate=0.5), M)
    eng, s = _make("fedbioacc", stragglers=strag)
    assert float(s.deadline) == 1.0
    bs = _batches(4)
    s1 = eng.step(s, bs[0])
    assert float(s1.deadline) == 1.0              # mid-round: unchanged
    s2 = eng.step(s1, bs[1])                      # comm step closes round 0
    _, _, _, nd = strag.round_decision(0, jnp.ones(M), jnp.float32(1.0))
    assert float(s2.deadline) == pytest.approx(float(nd), rel=1e-6)
    assert float(s2.deadline) != 1.0
    # resume: the checkpointed state (deadline scalar included) continues
    # bit-exactly against the uninterrupted trajectory
    d = str(tmp_path / "ck")
    save_checkpoint(d, s2, {"step": 2})
    r2 = load_checkpoint(d, jax.eval_shape(lambda: s2))
    _bits(r2.deadline, s2.deadline)
    s3, r3 = eng.step(s2, bs[2]), eng.step(r2, bs[2])
    _bits(s3.deadline, r3.deadline)
    for a, b in zip(s3.vars, r3.vars):
        _bits(a, b)
    # and init_state can seed a custom deadline outright
    _, fresh = _make("fedbioacc", stragglers=strag)
    assert float(fresh.deadline) == 1.0


def test_ef_rows_freeze_for_non_arrivals():
    """Stragglers x compression at the substrate level: a client whose
    weight is zeroed by the arrival mask leaves its error-feedback row
    bit-exactly frozen, and a re-drawn mask from the SAME snapshot leaves
    the new non-participants' rows frozen instead (the retry contract)."""
    tree = {"x": jnp.zeros((6,), jnp.float32)}
    spec = flat.make_spec(jax.tree.map(
        lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), tree),
        sections=("x",), block=8)
    key = jax.random.PRNGKey(0)
    bufs = flat.flatten_tree(
        spec, {"x": jax.random.normal(key, (M, 6))}, batch_dims=1)
    ef = tuple(jax.random.normal(jax.random.fold_in(key, 9), b.shape)
               for b in bufs)
    ccfg = flat.CompressCfg(quant="int8", topk_frac=0.5)
    w1 = jnp.array([1.0, 0.0, 1.0, 1.0])          # client 1 missed
    _, ef1 = flat.client_mean_masked(spec, bufs, ("mean",), weights=w1,
                                     compress=ccfg, ef=ef)
    _bits(ef1[0][1], ef[0][1])                    # non-arrival frozen
    assert not np.array_equal(np.asarray(ef1[0][0]), np.asarray(ef[0][0]))
    w2 = jnp.array([0.0, 1.0, 1.0, 1.0])          # retry re-draw: client 0
    _, ef2 = flat.client_mean_masked(spec, bufs, ("mean",), weights=w2,
                                     compress=ccfg, ef=ef)
    _bits(ef2[0][0], ef[0][0])
    assert not np.array_equal(np.asarray(ef2[0][1]), np.asarray(ef[0][1]))


def test_rollback_guard_restores_ef_stale_and_deadline():
    """Satellite contract: a rollback retry restores FlatState.ef, .stale
    and .deadline bit-exactly from the snapshot, bumping only the retry
    slot (so fault masks re-draw against identical buffers)."""
    from repro.federation.faults import RobustnessSpec, RollbackGuard
    key = jax.random.PRNGKey(0)
    good = seqs.FlatState(
        vars=(jax.random.normal(key, (M, 8)),),
        mom=(jax.random.normal(jax.random.fold_in(key, 1), (M, 8)),),
        step=jnp.int32(2), stale=jnp.array([0, 2, 1, 0], jnp.int32),
        retry=jnp.zeros((), jnp.int32),
        ef=((jax.random.normal(jax.random.fold_in(key, 2), (M, 8)),), ()),
        deadline=jnp.float32(1.25))
    guard = RollbackGuard(RobustnessSpec(retry_budget=2, ring=2))
    assert guard.observe(2, good, key, 1.0) is None
    bad = good._replace(step=jnp.int32(4),
                        vars=(jnp.full((M, 8), jnp.nan),))
    step, restored, _ = guard.observe(4, bad, key, float("nan"))
    assert step == 2 and int(restored.retry) == 1
    _bits(restored.vars[0], good.vars[0])
    _bits(restored.mom[0], good.mom[0])
    _bits(restored.stale, good.stale)
    _bits(restored.ef[0][0], good.ef[0][0])
    _bits(restored.deadline, good.deadline)


# ---------------------------------------------------------------------------
# declarative surface: Experiment round-trip, validation, edit sweeps
# ---------------------------------------------------------------------------

def _exp(**edits):
    base = Experiment(
        algorithm=AlgorithmSpec("fedbioacc"),
        problem=ProblemSpec(arch="mamba2-130m", reduced=True, num_clients=8,
                            per_client=1, seq_len=16),
        schedule=ScheduleSpec(steps=4, local_steps=2, lr_x=0.05, lr_y=0.05,
                              lr_u=0.05, neumann_q=2, neumann_tau=0.3))
    base = base.edit(**{"execution.fuse_storm": True,
                        "execution.storm_block": 128})
    return base.edit(**edits) if edits else base


def test_spec_roundtrip_and_edit_promotion():
    exp = _exp(**{"participation.sampler": "uniform",
                  "participation.clients_per_round": 4,
                  "stragglers.deadline": 1.5, "stragglers.quorum": 0.5,
                  "stragglers.late_policy": "carry"})
    assert exp.stragglers == StragglerSpec(deadline=1.5, quorum=0.5,
                                           late_policy="carry")
    exp.validate()
    back = Experiment.from_json(exp.to_json())
    assert back == exp
    plain = Experiment.from_json(_exp().to_json())
    assert plain.stragglers is None
    d = json.loads(exp.to_json())
    assert d["stragglers"]["deadline"] == 1.5
    assert d["stragglers"]["late_policy"] == "carry"


def test_spec_validation_errors():
    with pytest.raises(SpecError, match="fuse_storm"):
        _exp(**{"execution.fuse_storm": False,
                "stragglers.deadline": 1.0}).validate()
    with pytest.raises(SpecError, match="hierarch"):
        _exp(**{"schedule.hierarchy_period": 2,
                "stragglers.deadline": 1.0}).validate()
    with pytest.raises(SpecError, match="late_policy"):
        _exp(**{"stragglers.late_policy": "defer"}).validate()
    with pytest.raises(SpecError, match="quorum"):
        _exp(**{"stragglers.quorum": 1.5,
                "stragglers.over_provision": 0}).validate()
    # over-provisioning needs a counted sampler (default is "full")
    with pytest.raises(SpecError, match="over_provision"):
        _exp(**{"stragglers.over_provision": 2}).validate()
    _exp(**{"stragglers.over_provision": 2,
            "participation.sampler": "uniform",
            "participation.clients_per_round": 4}).validate()
    with pytest.raises(SpecError, match="stragglers"):
        _exp(**{"telemetry.metrics": ["stragglers"]}).validate()


def test_build_composes_over_provisioned_sampler():
    """build() hands the trainer the over-provisioned sampler: the engine's
    recorded participation requests m + b clients."""
    from repro.api import build
    exp = _exp(**{"participation.sampler": "uniform",
                  "participation.clients_per_round": 4,
                  "stragglers.over_provision": 2,
                  "stragglers.deadline": 1.5})
    run = build(exp)
    assert run.step.stragglers is not None
    assert run.step.participation.spec.clients_per_round == 6
    state = run.init(jax.random.PRNGKey(0))
    assert float(state.deadline) == 1.5


# ---------------------------------------------------------------------------
# launch.train: quorum-miss telemetry end to end (subprocess)
# ---------------------------------------------------------------------------

def _train_env():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return env


@pytest.mark.timeout(900)
def test_quorum_miss_round_emits_events(tmp_path):
    """An impossible deadline (every sampled client misses every backoff
    rung) drives the fallback each round: the run completes on quorum-sized
    arrival sets and the stream carries deadline + quorum_miss events with
    exhausted extension counts — and passes the validate CLI's straggler
    invariants."""
    exp = Experiment.load(os.path.join(os.path.dirname(__file__), "..",
                                       "experiments",
                                       "fedbioacc_straggler.json"))
    exp = exp.edit(**{"stragglers.deadline": 0.01,
                      "stragglers.adapt_rate": 0.0,
                      "stragglers.max_extensions": 1,
                      "schedule.steps": 4})
    path = str(tmp_path / "exp.json")
    exp.save(path)
    sink = str(tmp_path / "events.jsonl")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--experiment", path,
         "--log-every", "2", "--telemetry-sink", sink],
        env=_train_env(), capture_output=True, text=True, timeout=850)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    from repro.telemetry import validate_events
    s = validate_events(sink, expect=("run_start", "metrics", "deadline",
                                      "quorum_miss", "run_end"))
    assert s["deadlines_checked"] >= 2
    from repro.telemetry import read_events
    evs = [e for e in read_events(sink) if e.get("event") == "deadline"]
    for e in evs:
        assert e["extensions"] == 2          # max_extensions + 1: exhausted
        assert e["arrivals"] >= e["quorum"] >= 1
        assert e["deadline"] > 0.01          # fallback order statistic
