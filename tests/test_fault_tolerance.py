"""Fault-tolerant rounds (repro.federation.faults + the guarded substrate):
deterministic/resumable fault draws with retry re-draws, health-masked
robust aggregation on the flat substrate, guards-off bit-identity, the
guarded-vs-unguarded divergence claim, the rollback guard, declarative
spec round-trips, atomic checkpoint writes, and crash auto-resume."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AlgorithmSpec, Experiment, ProblemSpec, ScheduleSpec,
                       SpecError)
from repro.config import FederatedConfig
from repro.configs import ARCHS
from repro.data import make_fed_batch_fn
from repro.federation.faults import (FaultSpec, RobustnessSpec,
                                     RollbackError, RollbackGuard,
                                     expected_fault_fraction, make_faults)
from repro.federation.trainer import make_fedbioacc_train_step
from repro.models import build_model
from repro.optim import flat


# ---------------------------------------------------------------------------
# fault draws: determinism, resume, retry re-draw
# ---------------------------------------------------------------------------

def _masks(f, r, retry=0):
    return tuple(np.asarray(m) for m in f.round_masks(jnp.int32(r), retry))


def test_fault_masks_deterministic_and_resumable():
    """Same (seed, round, retry) ⇒ same masks across independent engines
    and regardless of evaluation order (resume safety), incl. under jit."""
    spec = FaultSpec(dropout_rate=0.3, nan_rate=0.3, byzantine_rate=0.3,
                     seed=5)
    f1, f2 = make_faults(spec, 8), make_faults(spec, 8)
    seq1 = [_masks(f1, r) for r in range(10)]
    for r in (0, 4, 9):                       # f2 jumps straight to round r
        for a, b in zip(seq1[r], _masks(f2, r)):
            np.testing.assert_array_equal(a, b)
    jm = jax.jit(lambda r: f1.round_masks(r))
    for a, b in zip(seq1[3], tuple(np.asarray(m) for m in jm(jnp.int32(3)))):
        np.testing.assert_array_equal(a, b)
    # different seed ⇒ different process
    f3 = make_faults(spec._replace(seed=6), 8)
    assert any(not np.array_equal(a, b)
               for r in range(10) for a, b in zip(seq1[r], _masks(f3, r)))


def test_fault_masks_retry_redraws():
    """A retried round re-draws its faults — fold_in(round, retry) — while
    retry=0 reproduces the original draw."""
    f = make_faults(FaultSpec(nan_rate=0.5, seed=0), 16)
    base = _masks(f, 2, retry=0)
    again = _masks(f, 2, retry=0)
    redraw = [_masks(f, 2, retry=k) for k in (1, 2)]
    for a, b in zip(base, again):
        np.testing.assert_array_equal(a, b)
    assert any(not np.array_equal(base[1], rd[1]) for rd in redraw)


def test_fault_mask_exclusivity_and_start_round():
    """Dropped clients never also corrupt; NaN precludes byzantine; rounds
    before start_round are clean."""
    spec = FaultSpec(dropout_rate=0.5, nan_rate=0.9, byzantine_rate=0.9,
                     seed=1, start_round=3)
    f = make_faults(spec, 32)
    for r in range(3):
        keep, nan, byz = _masks(f, r)
        np.testing.assert_array_equal(keep, np.ones(32))
        assert nan.sum() == 0 and byz.sum() == 0
    keep, nan, byz = _masks(f, 5)
    assert (1 - keep).sum() > 0 and nan.sum() > 0 and byz.sum() > 0
    assert np.all(nan * (1 - keep) == 0)      # dropped ⇒ sends nothing
    assert np.all(byz * (1 - keep) == 0)
    assert np.all(byz * nan == 0)             # NaN rows aren't also scaled


def test_fault_spec_validation_and_fraction():
    with pytest.raises(ValueError, match="nan_rate"):
        make_faults(FaultSpec(nan_rate=1.5), 4)
    with pytest.raises(ValueError, match="dropout_rate"):
        make_faults(FaultSpec(dropout_rate=-0.1), 4)
    assert make_faults(None, 4) is None
    frac = expected_fault_fraction(make_faults(FaultSpec(nan_rate=0.3), 16),
                                   num_rounds=128)
    assert frac["nan"] == pytest.approx(0.3, abs=0.06)
    assert frac["dropout"] == 0.0
    assert expected_fault_fraction(None) == {"dropout": 0.0, "nan": 0.0,
                                             "byzantine": 0.0}


# ---------------------------------------------------------------------------
# robust aggregation on the flat substrate
# ---------------------------------------------------------------------------

def _flat_setup(M=4, dtype=jnp.float32):
    tree = {"x": jnp.zeros((6,), dtype), "y": jnp.zeros((3,), dtype)}
    spec = flat.make_spec(jax.tree.map(
        lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), tree),
        sections=("x", "y"), block=8)
    key = jax.random.PRNGKey(0)
    btree = {s: jax.random.normal(jax.random.fold_in(key, i),
                                  (M,) + tree[s].shape).astype(dtype)
             for i, s in enumerate(tree)}
    return spec, flat.flatten_tree(spec, btree, batch_dims=1)


def _no_fault(M):
    z = jnp.zeros((M,), jnp.float32)
    return (z, z, 10.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_all_healthy_robust_mean_bitwise_identical(dtype):
    """With zero fault masks and the "mean" aggregator the guarded
    reduction must reproduce the unguarded client mean BIT-for-bit — the
    robustness layer is a strict generalisation, not a numerical fork."""
    spec, bufs = _flat_setup(4, dtype)
    plain = flat.client_mean_masked(spec, bufs, ("mean", "mean"))
    rob = flat.RobustCfg(aggregator="mean", screen=True, z_thresh=3.0)
    guard = flat.client_mean_masked(spec, bufs, ("mean", "mean"),
                                    corrupt=_no_fault(4), robust=rob)
    unguard = flat.client_mean_masked(spec, bufs, ("mean", "mean"),
                                      corrupt=_no_fault(4), robust=None)
    for a, b, c in zip(plain, guard, unguard):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(c).view(np.uint8))


def test_nan_sender_screened_and_recovered():
    """A NaN sender is excluded from the healthy mean, every participant
    (including the faulty one — recovery) receives the finite aggregate,
    and without guards the same fault poisons all participants."""
    spec, bufs = _flat_setup(4)
    nan = jnp.array([0.0, 1.0, 0.0, 0.0])
    corrupt = (nan, jnp.zeros(4), 10.0)
    rob = flat.RobustCfg(aggregator="mean")
    out = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                  corrupt=corrupt, robust=rob)
    want = (bufs[0][0, :8] + bufs[0][2, :8] + bufs[0][3, :8]) / 3.0
    for m in range(4):                        # all rows get the healthy mean
        np.testing.assert_allclose(np.asarray(out[0][m, :8]),
                                   np.asarray(want), rtol=1e-6)
    # private y section untouched (the fault models what is SENT)
    np.testing.assert_array_equal(np.asarray(out[0][:, 8:]),
                                  np.asarray(bufs[0][:, 8:]))
    bad = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                  corrupt=corrupt, robust=None)
    assert not bool(jnp.all(jnp.isfinite(bad[0][:, :8])))


def test_byzantine_sender_z_screened():
    """A wildly scaled row trips the update-norm z-score screen even though
    it is perfectly finite."""
    spec, bufs = _flat_setup(8)
    byz = jnp.zeros(8).at[3].set(1.0)
    corrupt = (jnp.zeros(8), byz, 1e4)
    rob = flat.RobustCfg(aggregator="mean", z_thresh=2.0)
    out = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                  corrupt=corrupt, robust=rob)
    honest = np.asarray(jnp.mean(jnp.delete(bufs[0][:, :8], 3, axis=0),
                                 axis=0) * 8.0 / 7.0)
    # aggregate ≈ honest mean, nowhere near the 1e4-scaled row's pull
    np.testing.assert_allclose(np.asarray(out[0][0, :8]),
                               honest * 7.0 / 8.0, rtol=1e-5)
    assert float(jnp.max(jnp.abs(out[0]))) < 100.0


def test_clip_bounds_byzantine_pull():
    """Norm clipping bounds every row's contribution to clip_factor x the
    mean update norm.  With the screen OFF the outlier inflates the
    reference norm itself (tau ≈ clip_factor x scale/M here), so clipping
    alone shrinks but cannot remove the pull; composed with the screen the
    outlier is excluded from tau and the aggregate matches the honest mean."""
    spec, bufs = _flat_setup(8)
    byz = jnp.zeros(8).at[1].set(1.0)
    corrupt = (jnp.zeros(8), byz, 1e4)
    unclipped = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                        corrupt=corrupt, robust=None)
    rob = flat.RobustCfg(aggregator="clip", screen=False, clip_factor=2.0)
    clipped = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                      corrupt=corrupt, robust=rob)
    assert (float(jnp.max(jnp.abs(clipped[0][:, :8])))
            < 0.6 * float(jnp.max(jnp.abs(unclipped[0][:, :8]))))
    screened = flat.client_mean_masked(
        spec, bufs, ("mean", "none"), corrupt=corrupt,
        robust=rob._replace(screen=True, z_thresh=2.0))
    honest = np.asarray(jnp.mean(jnp.delete(bufs[0][:, :8], 1, axis=0),
                                 axis=0))
    np.testing.assert_allclose(np.asarray(screened[0][0, :8]), honest,
                               rtol=1e-4)


def test_trimmed_mean_drops_outlier_coordinates():
    """The coordinate-wise trimmed mean excludes the extreme row outright
    (screen off — trimming alone must cope)."""
    M = 5
    spec, bufs = _flat_setup(M)
    byz = jnp.zeros(M).at[2].set(1.0)
    corrupt = (jnp.zeros(M), byz, 1e4)
    rob = flat.RobustCfg(aggregator="trim", screen=False, trim_frac=0.2)
    out = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                  corrupt=corrupt, robust=rob)
    assert float(jnp.max(jnp.abs(out[0][:, :8]))) < 100.0
    # all-healthy trim on symmetric data stays near the plain mean
    plain = flat.client_mean_masked(spec, bufs, ("mean", "none"))
    out2 = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                   corrupt=_no_fault(M), robust=rob)
    assert (float(jnp.max(jnp.abs(out2[0] - plain[0])))
            < float(jnp.max(jnp.abs(plain[0]))))


def test_all_unhealthy_round_passes_through():
    """If every participant is screened out the round aggregates nothing:
    all rows pass through bit-identically (the rollback guard owns
    recovery, not the reduction)."""
    spec, bufs = _flat_setup(4)
    corrupt = (jnp.ones(4), jnp.zeros(4), 10.0)   # every sender NaN
    rob = flat.RobustCfg(aggregator="mean")
    out = flat.client_mean_masked(spec, bufs, ("mean", "mean"),
                                  corrupt=corrupt, robust=rob)
    for a, b in zip(out, bufs):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))


def test_nonparticipants_never_touched_by_faults():
    """A faulty NON-participant (zero weight) cannot poison the round and
    keeps its own row bit-identically."""
    spec, bufs = _flat_setup(4)
    w = jnp.array([1.0, 0.0, 1.0, 1.0])
    nan = jnp.array([0.0, 1.0, 0.0, 0.0])     # the absent client is faulty
    corrupt = (nan, jnp.zeros(4), 10.0)
    for rob in (None, flat.RobustCfg(aggregator="mean")):
        out = flat.client_mean_masked(spec, bufs, ("mean", "none"),
                                      weights=w, corrupt=corrupt, robust=rob)
        assert bool(jnp.all(jnp.isfinite(out[0])))
        np.testing.assert_array_equal(np.asarray(out[0][1]).view(np.uint8),
                                      np.asarray(bufs[0][1]).view(np.uint8))


def test_guarded_rejects_grouped_means():
    spec, bufs = _flat_setup(4)
    with pytest.raises(AssertionError):
        flat.client_mean_masked(spec, bufs, ("group", "none"), num_groups=2,
                                corrupt=_no_fault(4),
                                robust=flat.RobustCfg())


# ---------------------------------------------------------------------------
# model-scale: guards-off bit-identity, divergence, rollback machinery
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["granite-8b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=2, lr_x=0.05,
                          lr_y=0.05, lr_u=0.05)
    batch_fn = make_fed_batch_fn(cfg, num_clients=4, per_client=1,
                                 seq_len=16)
    return cfg, model, fed, batch_fn


def _run(model, fed, batch_fn, steps=8, **kw):
    init, step = make_fedbioacc_train_step(model, fed, n_micro=1,
                                           remat=False, fuse_storm=True,
                                           storm_block=128, **kw)
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    key = jax.random.PRNGKey(1)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    return state, step


def _val_loss(model, batch_fn, step, state):
    s = step.views(state)
    p = {"body": jax.tree.map(lambda v: v[0], s.x),
         "head": jax.tree.map(lambda v: v[0], s.y)}
    b = jax.tree.map(lambda v: v[0], batch_fn(jax.random.PRNGKey(99)))
    return float(model.loss(p, b["val"])[0])


def test_guards_off_bit_identity_and_divergence_claim(setup):
    """The PR's acceptance claim, end to end on a real model: (a) attaching
    a zero-rate fault process and/or the "mean" robust aggregator leaves
    the fused trajectory BIT-identical; (b) under NaN injection the
    unguarded run demonstrably diverges while the guarded run stays finite
    and lands within 2x of the clean run's final loss."""
    cfg, model, fed, batch_fn = setup
    clean, cstep = _run(model, fed, batch_fn)
    for kw in ({"faults": FaultSpec()},
               {"robustness": RobustnessSpec(aggregator="mean")},
               {"faults": FaultSpec(),
                "robustness": RobustnessSpec(aggregator="mean")}):
        got, _ = _run(model, fed, batch_fn, **kw)
        for a, b in zip(clean.vars, got.vars):
            np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                          np.asarray(b).view(np.uint8))
        for a, b in zip(clean.mom, got.mom):
            np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                          np.asarray(b).view(np.uint8))
    faulty = FaultSpec(nan_rate=0.4, seed=2)
    bad, _ = _run(model, fed, batch_fn, faults=faulty)
    assert not all(bool(jnp.all(jnp.isfinite(b))) for b in bad.vars), \
        "unguarded NaN injection must poison the trajectory"
    good, gstep = _run(model, fed, batch_fn, faults=faulty,
                       robustness=RobustnessSpec(aggregator="clip"))
    assert all(bool(jnp.all(jnp.isfinite(b))) for b in good.vars)
    l_clean = _val_loss(model, batch_fn, cstep, clean)
    l_good = _val_loss(model, batch_fn, gstep, good)
    assert np.isfinite(l_good) and l_good <= 2.0 * l_clean, (l_good, l_clean)


def test_faults_require_fused_engine(setup):
    cfg, model, fed, batch_fn = setup
    with pytest.raises(ValueError, match="fuse_storm"):
        make_fedbioacc_train_step(model, fed, n_micro=1, remat=False,
                                  faults=FaultSpec(nan_rate=0.1))
    with pytest.raises(ValueError, match="hierarch"):
        make_fedbioacc_train_step(
            model, dataclasses.replace(fed, hierarchy_period=2),
            n_micro=1, remat=False,
            fuse_storm=True, storm_block=128,
            robustness=RobustnessSpec(aggregator="clip"))


def test_rollback_guard_exhausts_budget_on_persistent_faults(setup):
    """Full rollback machinery on a real engine: nan_rate=1.0 from round 1
    — the guard snapshots the clean round, rolls back on the NaN loss
    (bumping the retry slot so fault masks re-draw), and fails loudly with
    RollbackError once the budget is spent."""
    cfg, model, fed, batch_fn = setup
    init, step = make_fedbioacc_train_step(
        model, fed, n_micro=1, remat=False, fuse_storm=True, storm_block=128,
        faults=FaultSpec(nan_rate=1.0, seed=0, start_round=1),
        robustness=RobustnessSpec(aggregator="mean", screen=False,
                                  retry_budget=2, ring=2))
    # screen=False: the engine aggregates the NaNs (the guard must catch it)
    guard = RollbackGuard(RobustnessSpec(retry_budget=2, ring=2))
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    key = jax.random.PRNGKey(1)
    t = 0
    with pytest.raises(RollbackError, match="retry budget"):
        while t < 8:
            key, sub = jax.random.split(key)
            state, _ = jstep(state, batch_fn(sub))
            t += 1
            loss = _val_loss(model, batch_fn, step, state)
            rb = guard.observe(t, state, key, loss)
            if rb is not None:
                t, state, key = rb
    assert guard.retries == 2
    assert len(guard.rollback_steps) == 2
    assert int(state.retry) >= 1              # fault draws were re-keyed


# ---------------------------------------------------------------------------
# RollbackGuard unit semantics (no model)
# ---------------------------------------------------------------------------

class _Toy:
    def __init__(self, v, retry=jnp.zeros((), jnp.int32)):
        self.v, self.retry = v, retry

    def _replace(self, retry):
        return _Toy(self.v, retry)


def test_rollback_guard_snapshot_and_rollback():
    g = RollbackGuard(RobustnessSpec(spike_factor=10.0, retry_budget=3,
                                     ring=2))
    k0 = jax.random.PRNGKey(0)
    assert g.observe(1, _Toy(1), k0, 5.0) is None
    assert g.observe(2, _Toy(2), k0, 6.0) is None
    step, state, key = g.observe(3, _Toy(3), k0, float("nan"))
    assert step == 2 and state.v == 2 and g.retries == 1
    assert int(state.retry) == 1              # fault re-draw keyed
    assert not np.array_equal(np.asarray(key), np.asarray(k0))
    # a spike (not just NaN) also rolls back; within-factor losses don't
    assert g.observe(3, _Toy(3), k0, 6.5) is None
    step, _, _ = g.observe(4, _Toy(4), k0, 100.0)
    assert step == 3 and g.retries == 2
    # states without a retry slot (tuple sentinel) pass through untouched
    class _Plain:
        retry = ()
    g2 = RollbackGuard(RobustnessSpec())
    g2.observe(1, _Plain(), k0, 1.0)
    _, s, _ = g2.observe(2, _Plain(), k0, float("inf"))
    assert s.retry == ()


def test_rollback_guard_failure_modes():
    g = RollbackGuard(RobustnessSpec(retry_budget=1))
    with pytest.raises(RollbackError, match="no .*good"):
        g.observe(1, _Toy(1), jax.random.PRNGKey(0), float("nan"))
    g.observe(1, _Toy(1), jax.random.PRNGKey(0), 1.0)
    g.observe(2, _Toy(1), jax.random.PRNGKey(0), float("nan"))
    with pytest.raises(RollbackError, match="retry budget"):
        g.observe(2, _Toy(1), jax.random.PRNGKey(0), float("nan"))
    with pytest.raises(ValueError):
        RollbackGuard(RobustnessSpec(retry_budget=-1))


# ---------------------------------------------------------------------------
# declarative surface: Experiment round-trip, validation, edit sweeps
# ---------------------------------------------------------------------------

def _exp(**edits):
    base = Experiment(
        algorithm=AlgorithmSpec("fedbioacc"),
        problem=ProblemSpec(arch="mamba2-130m", reduced=True, num_clients=4,
                            per_client=1, seq_len=16),
        schedule=ScheduleSpec(steps=4, local_steps=2, lr_x=0.05, lr_y=0.05,
                              lr_u=0.05, neumann_q=2, neumann_tau=0.3))
    base = base.edit(**{"execution.fuse_storm": True,
                        "execution.storm_block": 128})
    return base.edit(**edits) if edits else base


def test_spec_roundtrip_and_edit_promotion():
    exp = _exp(**{"faults.nan_rate": 0.2, "faults.byzantine_rate": 0.1,
                  "robustness.aggregator": "trim",
                  "robustness.retry_budget": 5})
    assert exp.faults == FaultSpec(nan_rate=0.2, byzantine_rate=0.1)
    assert exp.robustness.aggregator == "trim"
    exp.validate()
    back = Experiment.from_json(exp.to_json())
    assert back == exp
    # absent guards serialize as null and stay absent
    plain = Experiment.from_json(_exp().to_json())
    assert plain.faults is None and plain.robustness is None
    d = json.loads(exp.to_json())
    assert d["faults"]["nan_rate"] == 0.2
    assert d["robustness"]["aggregator"] == "trim"


def test_spec_validation_errors():
    with pytest.raises(SpecError, match="fuse_storm"):
        _exp(**{"execution.fuse_storm": False,
                "faults.nan_rate": 0.1}).validate()
    with pytest.raises(SpecError, match="hierarchy"):
        _exp(**{"schedule.hierarchy_period": 2,
                "robustness.aggregator": "clip"}).validate()
    with pytest.raises(SpecError, match="nan_rate"):
        _exp(**{"faults.nan_rate": 1.5}).validate()
    with pytest.raises(SpecError, match="aggregator"):
        _exp(**{"robustness.aggregator": "median"}).validate()
    with pytest.raises(SpecError, match="trim_frac"):
        _exp(**{"robustness.aggregator": "trim",
                "robustness.trim_frac": 0.5}).validate()
    with pytest.raises(SpecError, match="spike_factor"):
        _exp(**{"robustness.spike_factor": 0.5}).validate()


# ---------------------------------------------------------------------------
# atomic checkpoints
# ---------------------------------------------------------------------------

def _tree(v):
    return {"a": jnp.full((3,), float(v)),
            "b": jnp.full((2, 2), float(v), jnp.bfloat16)}


def test_checkpoint_atomic_and_pruned(tmp_path):
    from repro.checkpoint import (checkpoint_metadata, load_checkpoint,
                                  save_checkpoint)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, _tree(1), {"step": 2})
    save_checkpoint(d, _tree(2), {"step": 4})
    got = load_checkpoint(d, jax.eval_shape(lambda: _tree(0)))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.full((3,), 2.0))
    assert checkpoint_metadata(d)["step"] == 4
    names = sorted(os.listdir(d))
    assert "arrays-00000004.npz" in names       # stale step-2 file pruned
    assert "arrays-00000002.npz" not in names
    assert not any(n.endswith(".tmp") for n in names)


def test_checkpoint_survives_partial_write(tmp_path):
    """A crash mid-write (torn temp files, a half-written next arrays file)
    must leave the previous checkpoint loadable — the manifest swap is the
    only commit point."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, _tree(7), {"step": 2})
    # simulate dying at every stage of the NEXT save
    open(os.path.join(d, "arrays-00000004.npz.tmp"), "wb").write(b"\x00" * 9)
    open(os.path.join(d, "arrays-00000004.npz"), "wb").write(b"garbage")
    open(os.path.join(d, "manifest.json.tmp"), "wb").write(b"{ tru")
    got = load_checkpoint(d, jax.eval_shape(lambda: _tree(0)))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.full((3,), 7.0))
    # and the recovered run's next save cleans the debris up
    save_checkpoint(d, _tree(8), {"step": 4})
    names = sorted(os.listdir(d))
    assert not any(n.endswith(".tmp") for n in names)
    assert "arrays-00000004.npz" in names
    got = load_checkpoint(d, jax.eval_shape(lambda: _tree(0)))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.full((3,), 8.0))


def test_checkpoint_corruption_drill(tmp_path):
    """The bit-flip drill: a single flipped byte in the arrays file fails
    the manifest's sha256 verification loudly, naming the corrupt file —
    and a pre-digest manifest (no "sha256" key) still loads."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.io import CheckpointCorruptError
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, _tree(5), {"step": 2})
    name = "arrays-00000002.npz"
    path = os.path.join(d, name)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF                  # one flipped byte
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointCorruptError, match=name):
        load_checkpoint(d, jax.eval_shape(lambda: _tree(0)))
    # legacy manifests without digests skip verification (old checkpoints)
    save_checkpoint(d, _tree(6), {"step": 4})
    with open(os.path.join(d, "manifest.json")) as fh:
        manifest = json.load(fh)
    del manifest["sha256"]
    with open(os.path.join(d, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    got = load_checkpoint(d, jax.eval_shape(lambda: _tree(0)))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.full((3,), 6.0))


def test_restart_backoff_capped_and_jittered():
    """The crash-supervisor wait is exponential but bounded (no unbounded
    2**attempt sleeps), deterministic per (token, attempt), and jittered
    across tokens so a fleet of restarts does not stampede."""
    from repro.launch.train import _RESTART_WAIT_CAP, _restart_wait
    for attempt in range(12):
        w = _restart_wait(0.5, attempt, "ckpt-a")
        assert 0.0 <= w <= _RESTART_WAIT_CAP
        assert w == _restart_wait(0.5, attempt, "ckpt-a")   # deterministic
    # pre-cap waits stay within the +/-25% jitter band of base * 2**attempt
    for attempt in range(4):
        base = 0.5 * 2 ** attempt
        w = _restart_wait(0.5, attempt, "ckpt-a")
        assert 0.75 * base <= w <= 1.25 * base
    # huge attempts saturate at the cap (jitter still applies, never above)
    assert _restart_wait(0.5, 50, "ckpt-a") <= _RESTART_WAIT_CAP
    # different tokens land on different points of the band
    ws = {_restart_wait(0.5, 3, t) for t in ("a", "b", "c", "d")}
    assert len(ws) > 1


def test_checkpoint_legacy_layout_fallback(tmp_path):
    """Old checkpoints (manifest without an ``arrays`` pointer + arrays.npz)
    still load."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, _tree(3), {"step": 1})
    with open(os.path.join(d, "manifest.json")) as fh:
        manifest = json.load(fh)
    os.rename(os.path.join(d, manifest.pop("arrays")),
              os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    got = load_checkpoint(d, jax.eval_shape(lambda: _tree(0)))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.full((3,), 3.0))


# ---------------------------------------------------------------------------
# launch.train: fail-loudly + crash auto-resume (subprocess)
# ---------------------------------------------------------------------------

def _train_env():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return env


@pytest.mark.timeout(900)
def test_crash_auto_resume_supervisor(tmp_path):
    """--max-restarts: a hard mid-run crash (injected after the step-2
    checkpoint) is survived — the supervisor relaunches with --resume and
    the run completes, its checkpoint landing at the final step."""
    exp = _exp(**{"algorithm.name": "fedavg", "algorithm.params": {},
                  "schedule.steps": 4})
    path = str(tmp_path / "exp.json")
    exp.save(path)
    ckpt = str(tmp_path / "ckpt")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--experiment", path,
         "--ckpt-dir", ckpt, "--ckpt-every", "2", "--log-every", "2",
         "--max-restarts", "2", "--restart-backoff", "0.1",
         "--crash-at-step", "3"],
        env=_train_env(), capture_output=True, text=True, timeout=850)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "crash-at-step" in res.stdout
    assert "resumed from" in res.stdout
    from repro.checkpoint import checkpoint_metadata
    assert checkpoint_metadata(ckpt)["step"] == 4
    assert checkpoint_metadata(ckpt).get("key") is not None


def test_nonfinite_loss_fails_loudly(tmp_path, monkeypatch):
    """Without robustness guards a non-finite eval loss exits non-zero,
    names the offending round, and drops a diagnostic checkpoint."""
    from repro.launch import train as train_mod
    exp = _exp(**{"algorithm.name": "fedavg", "algorithm.params": {},
                  "schedule.steps": 2})
    path = str(tmp_path / "exp.json")
    exp.save(path)
    monkeypatch.setattr(train_mod, "build", lambda e: _nan_run(e))
    with pytest.raises(SystemExit, match="round 1"):
        train_mod.main(["--experiment", path, "--log-every", "1",
                        "--ckpt-dir", str(tmp_path / "ck")])
    assert os.path.isdir(str(tmp_path / "ck" / "diagnostic"))


def _nan_run(exp):
    """A stub Run whose eval loss is NaN from the first round."""
    from repro.api.build import Run, build as real_build
    run = real_build(exp)
    return Run(**{**run._asdict(), "eval_fn": lambda s: float("nan")})
