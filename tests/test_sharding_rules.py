"""Partition-spec inference: known-leaf expectations, divisibility fallbacks,
and an end-to-end pjit execution of the federated step on a debug mesh."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig
from repro.configs import ARCHS
from repro.models import build_model
from repro.sharding import rules


@pytest.fixture(scope="module")
def llama_param_shapes():
    cfg = ARCHS["llama3-405b"]
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _find(specs, *keys):
    node = specs
    for k in keys:
        node = node[k]
    return node


def test_llama_specs_tensor_parallel(llama_param_shapes):
    specs = rules.param_specs(llama_param_shapes, MeshConfig(),
                              placement="client_sharded", client_axis=False,
                              fsdp=False)
    stage0 = specs["body"]["stages"][0]["0_attn"]
    # column-parallel qkv: [reps, d, h*hd] -> (None, None, "model")
    assert stage0["mix"]["wq"] == P(None, None, "model")
    # row-parallel wo: [reps, h*hd, d] -> (None, "model", None)
    assert stage0["mix"]["wo"] == P(None, "model", None)
    assert stage0["ln1"]["scale"] == P(None, None)
    # head [d, V]: vocab 128256 divisible -> (None, "model")
    assert specs["head"]["w"] == P(None, "model")
    # embed table [V, d] is COL -> model on last dim
    assert specs["body"]["embed"]["table"] == P(None, "model")


def test_llama_specs_fsdp(llama_param_shapes):
    specs = rules.param_specs(llama_param_shapes, MeshConfig(),
                              placement="client_replicated", client_axis=False)
    stage0 = specs["body"]["stages"][0]["0_attn"]
    # FSDP adds "data" on the remaining largest dim
    assert stage0["mix"]["wq"] == P(None, "data", "model")
    assert stage0["mix"]["wo"] == P(None, "model", "data")


def test_vocab_not_divisible_falls_back():
    cfg = ARCHS["hubert-xlarge"]     # vocab 504, not divisible by 16
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = rules.param_specs(shapes, MeshConfig(), client_axis=False,
                              fsdp=False)
    # head w [1280, 504]: model goes to d_model instead
    assert specs["head"]["w"] == P("model", None)


def test_moe_expert_parallel():
    cfg = ARCHS["olmoe-1b-7b"]
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = rules.param_specs(shapes, MeshConfig(), client_axis=False,
                              fsdp=False)
    ffn = specs["body"]["stages"][0]["0_attn"]["ffn"]
    # experts [reps, E, d, f] -> E sharded over "model"
    assert ffn["wi"] == P(None, "model", None, None)
    assert ffn["router"] == P(None, None, None)


def test_client_axis_sharding():
    cfg = ARCHS["gemma2-2b"]
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    M_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((16,) + s.shape, s.dtype), shapes)
    specs = rules.param_specs(M_shapes, MeshConfig(),
                              placement="client_sharded", client_axis=True)
    assert specs["head"]["w"] == P("data", None, "model")
    multi = rules.param_specs(M_shapes, MeshConfig(multi_pod=True),
                              placement="client_sharded", client_axis=True)
    assert multi["head"]["w"] == P(("pod", "data"), None, "model")


def test_generic_cache_specs():
    mesh = MeshConfig()
    caches = [{"0_attn": (jax.ShapeDtypeStruct((13, 128, 32768, 8, 128), jnp.bfloat16),) * 2}]
    specs = rules.cache_specs(caches, mesh)
    k_spec = specs[0]["0_attn"][0]
    # [reps, B, S, hkv, hd]: B -> data, S -> model (sharding hd/hkv forces a
    # full cache gather every attention layer — §Perf pair 2)
    assert k_spec == P(None, "data", "model", None, None)


def test_end_to_end_pjit_step_runs(rng):
    """The federated train step executes under pjit with inferred specs on a
    (1,1) debug mesh — catches spec/shape mismatches structurally."""
    from repro.config import FederatedConfig
    from repro.data import make_fed_batch_fn
    from repro.federation.trainer import make_fedbioacc_train_step
    from repro.launch.mesh import make_debug_mesh

    cfg = ARCHS["gemma2-2b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=2, local_steps=2)
    init, step = make_fedbioacc_train_step(model, fed, n_micro=1, remat=False)
    state = init(rng)
    batch_fn = make_fed_batch_fn(cfg, num_clients=2, per_client=1, seq_len=16)
    batch = batch_fn(rng)
    mesh = make_debug_mesh(1, 1)
    mesh_cfg = MeshConfig()
    s_spec = rules.state_specs(jax.eval_shape(lambda: state),
                               mesh_cfg, placement="client_sharded")
    # a (1,1) mesh accepts any spec axes? no — axes names must exist; debug
    # mesh has ("data","model") so specs are valid.
    from jax.sharding import NamedSharding
    in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), s_spec,
                         is_leaf=lambda s: isinstance(s, P))
    with mesh:
        jitted = jax.jit(step, in_shardings=(in_sh, None))
        new, _ = jitted(state, batch)
    assert int(new.step) == 1
