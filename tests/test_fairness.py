"""Fair Federated Learning via bilevel optimization (paper §5 conclusion):
the upper variable learns client weights that equalise client risk."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FederatedConfig
from repro.core import make_algorithm
from repro.core.problems import fair_federated_problem


def _train(prob, algo="fedbio", rounds=200, lr_x=0.5, lr_y=0.5):
    cfg = FederatedConfig(algorithm=algo, num_clients=prob.num_clients,
                          local_steps=4, lr_x=lr_x, lr_y=lr_y, lr_u=0.3)
    alg = make_algorithm(prob, cfg)
    state = alg.init(jax.random.PRNGKey(1))
    rnd = jax.jit(alg.round)
    key = jax.random.PRNGKey(2)
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        state, _ = rnd(state, sub)
    lam = alg.mean_x(state)
    ybar = jax.tree.map(lambda v: jnp.mean(v, 0), state.y)
    return lam, ybar


def test_fair_weights_upweight_hard_clients():
    prob = fair_federated_problem(jax.random.PRNGKey(0), num_clients=8,
                                  hard_clients=2)
    lam, y = _train(prob)
    w = np.asarray(jax.nn.softmax(lam))
    hard = np.asarray(prob.data["hard_mask"])
    # learned weights put more mass on the hard clients than uniform
    assert w[hard].mean() > w[~hard].mean(), w
    assert w[hard].mean() > 1.0 / 8


def test_fairness_improves_worst_client():
    """The bilevel objective is a smooth-max of client risks: the learned
    weighting must strictly improve the worst-served (minority) client over
    uniform weights (λ frozen at 0 = plain FedAvg-style training)."""
    prob = fair_federated_problem(jax.random.PRNGKey(0), num_clients=8,
                                  hard_clients=2)
    lam_fair, y_fair = _train(prob, rounds=200, lr_x=2.0)
    # uniform baseline: only the lower problem is trained (lr_x = 0)
    _, y_unif = _train(prob, rounds=200, lr_x=0.0)
    losses_fair = np.asarray(prob.client_val_losses(lam_fair, y_fair))
    losses_unif = np.asarray(prob.client_val_losses(jnp.zeros(8), y_unif))
    assert losses_fair.max() < losses_unif.max(), (losses_fair, losses_unif)
    hard = np.asarray(prob.data["hard_mask"])
    assert losses_fair[hard].mean() < losses_unif[hard].mean()
