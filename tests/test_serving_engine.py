"""Continuous-batching engine correctness: interleaved multi-request decode
must produce exactly the tokens each request would get decoded in isolation
(greedy), across decoder families and with slot reuse."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import ServeEngine


def _isolated_greedy(model, params, prompt, max_new, cache_len):
    batch = {"tokens": prompt[None, :], "labels": prompt[None, :]}
    last, caches = model.prefill(params, batch, cache_len=cache_len)
    tok = jnp.argmax(last[0]).astype(jnp.int32)
    out = [int(tok)]
    pos = prompt.shape[0]
    for _ in range(max_new - 1):
        logits, caches = model.decode_step(params, caches, tok[None, None],
                                           jnp.int32(pos))
        tok = jnp.argmax(logits[0]).astype(jnp.int32)
        out.append(int(tok))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-130m",
                                  "recurrentgemma-9b"])
def test_engine_matches_isolated_decode(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(rng)
    cache_len = 64
    engine = ServeEngine(model, params, max_slots=2, cache_len=cache_len)

    # 5 requests with different prompt lengths through 2 slots -> slot reuse
    prompts = [jax.random.randint(jax.random.fold_in(rng, i), (8 + 3 * i,),
                                  0, cfg.vocab_size) for i in range(5)]
    budgets = [6, 4, 8, 5, 7]
    rids = [engine.submit(p, n) for p, n in zip(prompts, budgets)]
    results = engine.run_to_completion()
    assert set(results) == set(rids)
    for rid, p, n in zip(rids, prompts, budgets):
        want = _isolated_greedy(model, params, p, n, cache_len)
        assert results[rid] == want, (arch, rid, results[rid], want)


def test_engine_eos_frees_slot(rng):
    cfg = ARCHS["granite-8b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(rng)
    engine = ServeEngine(model, params, max_slots=1, cache_len=32)
    p = jax.random.randint(rng, (8,), 0, cfg.vocab_size)
    # pick the greedy 2nd token as the "EOS" so the first request stops early
    iso = _isolated_greedy(model, params, p, 3, 32)
    rid1 = engine.submit(p, max_new=10, eos=iso[1])
    rid2 = engine.submit(p, max_new=3)
    results = engine.run_to_completion()
    assert results[rid1] == iso[:2]            # stopped at EOS
    assert results[rid2] == iso[:3]            # ran after slot freed
