"""§Perf optimization variants must be *exactly* equivalent to the
paper-faithful paths they replace (same math, different schedule)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FederatedConfig
from repro.configs import ARCHS
from repro.core import hypergrad as hg
from repro.core.problems import quadratic_problem
from repro.data import make_fed_batch_fn
from repro.federation.trainer import (make_fedbio_train_step,
                                      make_fedbioacc_train_step)
from repro.models import build_model


def test_fused_oracles_match_unfused_quadratic(rng):
    prob = quadratic_problem(rng, num_clients=2, dx=6, dy=5, noise=0.1)
    b = jax.tree.map(lambda v: v[0], prob.sample_batches(rng))
    x, y, u = jnp.ones((6,)), 0.5 * jnp.ones((5,)), jnp.arange(5.0)
    om1 = hg.grad_y(prob.g, x, y, b)
    mu1 = hg.nu_direction(prob.g, prob.f, x, y, u, b, b)
    p1 = hg.u_residual(prob.g, prob.f, x, y, u, b, b)
    om2, mu2, p2 = hg.fused_oracles(prob.g, prob.f, x, y, u, b)
    np.testing.assert_allclose(om1, om2, rtol=1e-6)
    np.testing.assert_allclose(mu1, mu2, rtol=1e-6)
    np.testing.assert_allclose(p1, p2, rtol=1e-6)


@pytest.mark.parametrize("maker", [make_fedbio_train_step,
                                   make_fedbioacc_train_step])
def test_fused_train_step_matches_model_scale(maker, rng):
    cfg = ARCHS["granite-8b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=2, local_steps=2, lr_x=0.05,
                          lr_y=0.05, lr_u=0.05)
    init1, step1 = maker(model, fed, n_micro=1, remat=False,
                         fuse_oracles=False)
    init2, step2 = maker(model, fed, n_micro=1, remat=False,
                         fuse_oracles=True)
    state = init1(rng)
    batch_fn = make_fed_batch_fn(cfg, num_clients=2, per_client=2, seq_len=32)
    batch = batch_fn(rng)
    # run two steps so FedBiOAcc's momenta are exercised
    s1, _ = jax.jit(step1)(state, batch)
    s2, _ = jax.jit(step2)(state, batch)
    batch2 = batch_fn(jax.random.fold_in(rng, 1))
    s1, _ = jax.jit(step1)(s1, batch2)
    s2, _ = jax.jit(step2)(s2, batch2)
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-3)


def test_grouped_gqa_matches_repeat(rng):
    """The grouped einsum GQA path equals explicit kv-head repetition."""
    from repro.config import ModelConfig
    from repro.models.layers import attention, attn_init
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=64)
    params = attn_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 48, 64))
    pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48))
    out, _ = attention(params, x, cfg, window=0, positions=pos)
    # reference with explicit repeat
    import math
    q = (x @ params["wq"]).reshape(2, 48, 8, 8)
    k = (x @ params["wk"]).reshape(2, 48, 2, 8)
    v = (x @ params["wv"]).reshape(2, 48, 2, 8)
    from repro.models.layers import rope
    q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
    kf, vf = jnp.repeat(k, 4, axis=2), jnp.repeat(v, 4, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / math.sqrt(8)
    mask = jnp.tril(jnp.ones((48, 48), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, vf).reshape(2, 48, 64) @ params["wo"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_client_pure_specs():
    from jax.sharding import PartitionSpec as P
    from repro.config import MeshConfig
    from repro.sharding import rules
    model = build_model(ARCHS["mamba2-130m"])
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    M_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((256,) + s.shape, s.dtype), shapes)
    specs = rules.param_specs(M_shapes, MeshConfig(),
                              placement="client_pure", client_axis=True)
    # client axis consumes the whole mesh, everything else unsharded
    assert specs["head"]["w"] == P(("data", "model"), None, None)
    stage = specs["body"]["stages"][0]["0_ssm"]["ssm"]
    assert stage["in_proj"] == P(("data", "model"), None, None, None)
