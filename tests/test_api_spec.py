"""repro.api: Experiment JSON round-trip, registry-built Runs bit-identical
to the pre-redesign factory path (fused + unfused, with participation, and
on a device mesh via subprocess), actionable validation errors, and
checkpoint spec embedding / resume."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AlgorithmSpec, ExecutionSpec, Experiment,
                       ParticipationSpec, ProblemSpec, ScheduleSpec,
                       SpecError, build, federated_config)

_ALGOS = {
    "fedbio": ("x", "y", "u"),
    "fedbioacc": ("x", "y", "u", "omega", "nu", "q"),
    "fedbio_local": ("x", "y"),
    "fedbioacc_local": ("x", "y", "omega", "nu"),
    "fedavg": ("params", "mom"),
}


def _exp(algo, **edits) -> Experiment:
    base = Experiment(
        algorithm=AlgorithmSpec(algo),
        problem=ProblemSpec(arch="mamba2-130m", reduced=True, num_clients=4,
                            per_client=1, seq_len=16),
        schedule=ScheduleSpec(steps=3, local_steps=2, lr_x=0.05, lr_y=0.05,
                              lr_u=0.05, neumann_q=2, neumann_tau=0.3))
    return base.edit(**edits) if edits else base


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", sorted(_ALGOS))
def test_json_roundtrip_equality(algo):
    exp = _exp(algo,
               **{"algorithm.params": {"momentum": 0.8} if algo == "fedavg"
                  else ({"c_nu": 0.9} if "acc" in algo else {}),
                  "participation.sampler": "uniform",
                  "participation.clients_per_round": 2,
                  "schedule.comm_every": {"params" if algo == "fedavg"
                                          else "x": 2}})
    exp.validate()
    back = Experiment.from_json(exp.to_json())
    assert back == exp
    # a second trip through disk-shaped text is still stable
    assert Experiment.from_json(back.to_json()) == exp


def test_json_rejects_unknowns_and_bad_version():
    with pytest.raises(SpecError, match="version"):
        Experiment.from_json(json.dumps({"version": 99}))
    with pytest.raises(SpecError, match="unknown keys"):
        Experiment.from_json(json.dumps({"problem": {"archh": "x"}}))
    with pytest.raises(SpecError, match="top-level"):
        Experiment.from_json(json.dumps({"extra": 1}))
    with pytest.raises(SpecError, match="parse"):
        Experiment.from_json("{nope")


def test_edit_list_values_stay_hashable_and_roundtrip():
    """edit() on the NamedTuple participation spec coerces list values the
    same way from_json does — the spec stays hashable and round-trip
    equal (a sweep editing client_weights must not defeat --resume's
    exact-match check)."""
    exp = _exp("fedbio").edit(
        **{"participation.client_weights": [1.0, 2.0, 3.0, 4.0]})
    hash(exp)
    assert Experiment.from_json(exp.to_json()) == exp


def test_validation_errors_are_actionable():
    with pytest.raises(SpecError, match="unknown algorithm"):
        _exp("fedbio").edit(**{"algorithm.name": "nope"}).validate()
    with pytest.raises(SpecError, match="not hyperparams"):
        _exp("fedbio").edit(**{"algorithm.params": {"c_nu": 1.0}}).validate()
    with pytest.raises(SpecError, match="unknown arch"):
        _exp("fedbio").edit(**{"problem.arch": "nope"}).validate()
    with pytest.raises(SpecError, match="fuse_storm"):
        _exp("fedbio").edit(**{"execution.mesh": (2, 1)}).validate()
    with pytest.raises(SpecError, match="divisible"):
        _exp("fedbio").edit(**{"execution.mesh": (3, 1),
                               "execution.fuse_storm": True}).validate()
    with pytest.raises(SpecError, match="not a section"):
        _exp("fedavg").edit(**{"schedule.comm_every": {"u": 2}}).validate()
    with pytest.raises(SpecError, match="client_weights"):
        _exp("fedbio").edit(
            **{"participation.sampler": "weighted"}).validate()
    with pytest.raises(SpecError, match="no such field"):
        _exp("fedbio").edit(**{"schedule.nope": 1})


def test_normalize_promotes_samplers_for_every_consumer():
    """clients_per_round / trace_path on the default 'full' sampler promote
    in the SPEC's normal form (build applies it), not as a CLI quirk — the
    same JSON means the same run via build()/dryrun/benchmarks/resume."""
    exp = _exp("fedbio").edit(**{"participation.clients_per_round": 2})
    assert exp.normalize().participation.sampler == "uniform"
    assert exp.normalize().normalize() == exp.normalize()   # idempotent
    run = build(exp)
    assert run.spec.participation.sampler == "uniform"
    assert run.participation is not None                    # 2-of-4 sampling
    exp = _exp("fedbio").edit(**{"participation.trace_path": "log.json"})
    assert exp.normalize().participation.sampler == "trace"
    with pytest.raises(SpecError, match="trace_path"):
        _exp("fedbio").edit(**{"participation.trace_path": "log.json",
                               "participation.sampler": "uniform"}).validate()
    with pytest.raises(SpecError, match="clients_per_round"):
        _exp("fedbio").edit(**{"participation.sampler": "trace",
                               "participation.clients_per_round": 2}).validate()


def test_federated_config_carries_algorithm_params():
    exp = _exp("fedbioacc", **{"algorithm.params": {"c_nu": 0.7,
                                                    "alpha_u0": 4.0}})
    fed = federated_config(exp)
    assert fed.c_nu == 0.7 and fed.alpha_u0 == 4.0
    assert fed.c_omega == 1.0          # registry default
    assert fed.num_clients == 4 and fed.local_steps == 2


# ---------------------------------------------------------------------------
# registry-built runs == pre-redesign factory path, bit-identical
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def factory_setup():
    from repro.configs import ARCHS
    from repro.data import make_fed_batch_fn
    from repro.models import build_model

    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    batch_fn = make_fed_batch_fn(cfg, num_clients=4, per_client=1,
                                 seq_len=16, seed=0)
    return model, batch_fn


def _traj(init, step, batch_fn, steps):
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    key = jax.random.PRNGKey(1)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    return step.views(state) if hasattr(step, "views") else state


@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("algo", sorted(_ALGOS))
def test_built_run_bit_identical_to_factory(factory_setup, algo, fuse):
    """build(Experiment.from_json(exp.to_json())) reproduces the direct
    make_*_train_step call BIT-identically — fused and unfused, and under
    2-of-4 uniform participation for the STORM algorithms."""
    from repro.federation import trainer as tr

    model, batch_fn = factory_setup
    part = (ParticipationSpec("uniform", 2, seed=7)
            if algo in ("fedbioacc", "fedbioacc_local") else None)
    exp = _exp(algo)
    if fuse:
        exp = exp.edit(**{"execution.fuse_storm": True,
                          "execution.storm_block": 256})
    if part is not None:
        exp = exp.edit(**{"participation.sampler": "uniform",
                          "participation.clients_per_round": 2,
                          "participation.seed": 7})

    run = build(Experiment.from_json(exp.to_json()))
    v_run = _traj(run.init, run.step, run.batch_fn, 3)

    maker = getattr(tr, f"make_{algo}_train_step")
    kw = dict(fuse_storm=True, storm_block=256) if fuse else {}
    init, step = maker(model, federated_config(exp), n_micro=1, remat=False,
                       participation=part, **kw)
    v_fac = _traj(init, step, batch_fn, 3)

    for n in _ALGOS[algo]:
        for a, b in zip(jax.tree.leaves(getattr(v_run, n)),
                        jax.tree.leaves(getattr(v_fac, n))):
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8),
                err_msg=f"{algo}.{n}")


def test_comm_every_spec_reaches_engine(factory_setup):
    """schedule.comm_every={'u': 2}: at the first comm round x averages
    while u still differs across clients (the async cadence knob, driven
    from the declarative spec)."""
    model, batch_fn = factory_setup
    exp = _exp("fedbio", **{"schedule.local_steps": 1,
                            "schedule.comm_every": {"u": 2}})
    run = build(exp)
    state = run.init(jax.random.PRNGKey(0))
    state, _ = jax.jit(run.step)(state, run.batch_fn(jax.random.PRNGKey(1)))

    def spread(tree):
        return max(float(jnp.max(jnp.std(v.astype(jnp.float32), axis=0)))
                   for v in jax.tree.leaves(tree))

    assert spread(state.x) < 1e-7
    assert spread(state.u) > 1e-6


# ---------------------------------------------------------------------------
# checkpoint spec embedding + resume
# ---------------------------------------------------------------------------

def test_checkpoint_embeds_spec_and_resumes_exactly(tmp_path):
    """An interrupted run (checkpoint = raw state + experiment.json) resumed
    through the embedded spec continues the exact uninterrupted trajectory,
    bit for bit — the fused FlatState round-trips through npz."""
    from repro.checkpoint import (load_checkpoint, load_experiment,
                                  save_checkpoint)

    exp = _exp("fedbioacc", **{"execution.fuse_storm": True,
                               "execution.storm_block": 256,
                               "schedule.steps": 4})
    run = build(exp)
    keys = []
    key = jax.random.PRNGKey(exp.schedule.seed)
    for _ in range(4):
        key, sub = jax.random.split(key)
        keys.append(sub)
    jstep = jax.jit(run.step)

    state = run.init(jax.random.PRNGKey(exp.schedule.seed))
    for k in keys[:2]:
        state, _ = jstep(state, run.batch_fn(k))
    ckpt = str(tmp_path / "ck")
    save_checkpoint(ckpt, state, {"step": 2}, experiment=exp)

    # --- "new process": rebuild from the embedded spec alone -------------
    exp2 = load_experiment(ckpt)
    assert exp2 == exp
    run2 = build(exp2)
    like = jax.eval_shape(run2.init, jax.random.PRNGKey(exp2.schedule.seed))
    state2 = load_checkpoint(ckpt, like)
    for k in keys[2:]:
        state2, _ = jax.jit(run2.step)(state2, run2.batch_fn(k))

    # --- uninterrupted reference -----------------------------------------
    ref = run.init(jax.random.PRNGKey(exp.schedule.seed))
    for k in keys:
        ref, _ = jstep(ref, run.batch_fn(k))

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a).ravel().view(np.uint8),
                                      np.asarray(b).ravel().view(np.uint8))


def test_resume_flag_mismatch_fails_loudly(tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.launch import train as train_cli

    exp = _exp("fedbio")
    ckpt = str(tmp_path / "ck")
    save_checkpoint(ckpt, {"x": jnp.zeros(())}, {"step": 2}, experiment=exp)
    ns = train_cli._parser().parse_args(["--resume", ckpt, "--lr-x", "0.5"])
    with pytest.raises(SystemExit, match="contradict"):
        train_cli._resolve_experiment(ns, {"lr_x": 0.5})
    # a flag that MATCHES the embedded spec is not a mismatch
    ns = train_cli._parser().parse_args(["--resume", ckpt, "--lr-x", "0.05"])
    got, start = train_cli._resolve_experiment(ns, {"lr_x": 0.05})
    assert got == exp and start == 2


def test_cli_flags_build_the_same_spec():
    """The CLI is a pure adapter: flags produce the Experiment, never a
    separate kwargs path."""
    from repro.launch import train as train_cli

    ov = {"arch": "mamba2-130m", "reduced": True, "algo": "fedbioacc_local",
          "clients": 8, "clients_per_round": 4, "seed": 3,
          "fuse_storm": True, "comm_every": "x=2"}
    exp = train_cli.apply_overrides(
        Experiment().edit(**{"problem.reduced": False}), ov)
    assert exp.algorithm.name == "fedbioacc_local"
    assert exp.problem.num_clients == 8 and exp.problem.reduced
    assert exp.participation.sampler == "uniform"       # promoted
    assert exp.participation.clients_per_round == 4
    assert exp.problem.data_seed == 3 and exp.schedule.seed == 3
    assert exp.schedule.comm_every_dict == {"x": 2}
    exp.validate()


# ---------------------------------------------------------------------------
# mesh (subprocess: the device-count flag must precede jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.api import Experiment, build

    exp_json = %r
    exp = Experiment.from_json(exp_json)

    def traj(exp):
        run = build(exp)
        state = run.init(jax.random.PRNGKey(0))
        if run.mesh is not None:
            assert run.shardings(state) is not None
        jstep = jax.jit(run.step, donate_argnums=(0,))
        key = jax.random.PRNGKey(1)
        for _ in range(3):
            key, sub = jax.random.split(key)
            state, _ = jstep(state, run.place_batch(run.batch_fn(sub)))
        return run.views(state)

    sharded = traj(exp)
    single = traj(exp.edit(**{"execution.mesh": None,
                              "execution.overlap": False}))
    for n in ("x", "y", "u", "omega", "nu", "q"):
        for a, b in zip(jax.tree.leaves(getattr(sharded, n)),
                        jax.tree.leaves(getattr(single, n))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5, rtol=2e-4, err_msg=n)
    print("MESH_OK")
""")


@pytest.mark.timeout(900)
@pytest.mark.mesh_subprocess
def test_mesh_experiment_matches_single_device():
    """A spec carrying a (4, 2) mesh builds the sharded run (shard_map
    launches + psum reductions) and reproduces the single-device fused
    trajectory — driven purely from JSON, in a subprocess with 8 forced
    host devices."""
    exp = _exp("fedbioacc", **{"execution.fuse_storm": True,
                               "execution.storm_block": 256,
                               "execution.mesh": (4, 2)})
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT % exp.to_json()],
        env=env, capture_output=True, text=True, timeout=850)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "MESH_OK" in res.stdout
