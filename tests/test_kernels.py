"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp ref.py oracles, in interpret mode.

The deterministic sweeps run everywhere; only the property tests need
hypothesis (skipped with a pointer to requirements-dev.txt when absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # keep the non-property tests runnable
    given = settings = st = None

needs_hypothesis = pytest.mark.skip(reason="hypothesis not installed "
                                    "(pip install -r requirements-dev.txt)")

from repro.kernels.flash.ops import flash_attention
from repro.kernels.flash.ref import flash_attention_ref
from repro.kernels.lru.ops import lru_scan
from repro.kernels.lru.ref import lru_scan_ref
from repro.kernels.storm.ops import storm_update
from repro.kernels.storm.ref import storm_update_ref

# ---------------------------------------------------------------------------
# storm
# ---------------------------------------------------------------------------

STORM_SHAPES = [(64,), (1000, 33), (3, 5, 7), (70000,)]
STORM_DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", STORM_SHAPES)
@pytest.mark.parametrize("dtype", STORM_DTYPES)
def test_storm_shapes_dtypes(shape, dtype, rng):
    ks = jax.random.split(rng, 4)
    p = jax.random.normal(ks[0], shape).astype(dtype)
    m = jax.random.normal(ks[1], shape)
    gn = jax.random.normal(ks[2], shape)
    go = jax.random.normal(ks[3], shape)
    pn, mn = storm_update({"p": p}, {"p": m}, {"p": gn}, {"p": go}, 0.05, 0.9)
    prn, mrn = storm_update_ref(p, m, gn, go, 0.05, 0.9)
    np.testing.assert_allclose(np.asarray(pn["p"], np.float32),
                               np.asarray(prn, np.float32), rtol=2e-2, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mn["p"]), np.asarray(mrn),
                               rtol=1e-5, atol=1e-6)


if st is not None:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 4000), lr=st.floats(0.0, 1.0),
           decay=st.floats(0.0, 1.0), seed=st.integers(0, 2**30))
    def test_storm_property(n, lr, decay, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        p, m, gn, go = (jax.random.normal(k, (n,)) for k in ks)
        pn, mn = storm_update({"x": p}, {"x": m}, {"x": gn}, {"x": go},
                              lr, decay)
        prn, mrn = storm_update_ref(p, m, gn, go, lr, decay)
        np.testing.assert_allclose(pn["x"], prn, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(mn["x"], mrn, rtol=1e-5, atol=1e-6)
else:
    @needs_hypothesis
    def test_storm_property():
        pass


def test_storm_decay_one_is_plain_momentum_carry(rng):
    """decay=1, g_new=g_old ⇒ momentum unchanged (STORM telescoping)."""
    p = jax.random.normal(rng, (256,))
    m = jax.random.normal(jax.random.fold_in(rng, 1), (256,))
    g = jax.random.normal(jax.random.fold_in(rng, 2), (256,))
    _, mn = storm_update({"x": p}, {"x": m}, {"x": g}, {"x": g}, 0.1, 1.0)
    np.testing.assert_allclose(mn["x"], m, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", STORM_DTYPES)
def test_storm3_matches_per_segment_ref(dtype, rng):
    """Triple-sequence kernel: per-block (lr, decay) scalars from SMEM must
    reproduce the per-segment reference exactly."""
    from repro.kernels.storm.kernel import storm3_step_flat, storm3_update_flat
    from repro.kernels.storm.ref import storm3_update_ref
    block, ntiles = 1024, 6
    n = block * ntiles
    ks = jax.random.split(rng, 4)
    p = jax.random.normal(ks[0], (n,)).astype(dtype)
    m, gn, go = (jax.random.normal(k, (n,)) for k in ks[1:])
    lrs = jnp.asarray([0.1, 0.1, 0.2, 0.2, 0.3, 0.3])
    decays = jnp.asarray([0.9, 0.9, 0.8, 0.8, 0.7, 0.7])
    pn, mn = storm3_update_flat(p, m, gn, go, lrs, decays, block=block)
    prn, mrn = storm3_update_ref(p, m, gn, go, lrs, decays, block)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(pn, np.float32),
                               np.asarray(prn, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(mrn),
                               rtol=1e-5, atol=1e-6)
    # half-step variant == full update with g_new = 0
    pn2, mp = storm3_step_flat(p, m, go, lrs, decays, block=block)
    pn0, mn0 = storm3_update_flat(p, m, jnp.zeros_like(gn), go, lrs, decays,
                                  block=block)
    np.testing.assert_array_equal(np.asarray(pn2, np.float32),
                                  np.asarray(pn0, np.float32))
    np.testing.assert_array_equal(np.asarray(mp), np.asarray(mn0))


@pytest.mark.parametrize("dtype", STORM_DTYPES)
def test_momsgd3_matches_ref_and_degenerates_to_sgd(dtype, rng):
    """Heavy-ball companion kernel: m' = β·m + g then p' = p − lr·m' (the
    *updated* momentum — FedAvg ordering); β = 0 is the plain SGD step."""
    from repro.kernels.storm.kernel import momsgd3_step_flat
    from repro.kernels.storm.ref import momsgd3_step_ref
    block, ntiles = 1024, 6
    n = block * ntiles
    ks = jax.random.split(rng, 3)
    p = jax.random.normal(ks[0], (n,)).astype(dtype)
    m, gv = (jax.random.normal(k, (n,)) for k in ks[1:])
    lrs = jnp.asarray([0.1, 0.1, 0.2, 0.2, 0.3, 0.3])
    betas = jnp.asarray([0.9, 0.9, 0.5, 0.5, 0.0, 0.0])
    pn, mn = momsgd3_step_flat(p, m, gv, lrs, betas, block=block)
    prn, mrn = momsgd3_step_ref(p, m, gv, lrs, betas, block)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(pn, np.float32),
                               np.asarray(prn, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(mrn),
                               rtol=1e-5, atol=1e-6)
    # β = 0 tiles: m operand irrelevant, p' = p − lr·g exactly
    pn0, mn0 = momsgd3_step_flat(p, jnp.zeros_like(m), gv, lrs, betas,
                                 block=block)
    sl = slice(4 * block, n)
    np.testing.assert_array_equal(np.asarray(pn[sl], np.float32),
                                  np.asarray(pn0[sl], np.float32))
    np.testing.assert_array_equal(np.asarray(mn0[sl]), np.asarray(gv[sl]))
    # the dedicated momentum-less kernel == heavy-ball at β = 0 everywhere
    from repro.kernels.storm.kernel import sgd3_step_flat
    from repro.kernels.storm.ref import sgd3_step_ref
    ps = sgd3_step_flat(p, gv, lrs, block=block)
    np.testing.assert_array_equal(np.asarray(ps[sl], np.float32),
                                  np.asarray(pn0[sl], np.float32))
    np.testing.assert_allclose(np.asarray(ps, np.float32),
                               np.asarray(sgd3_step_ref(p, gv, lrs, block),
                                          np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, S, H, D, causal, window, softcap)
    (2, 256, 4, 64, True, 0, 0.0),
    (1, 256, 2, 32, True, 64, 0.0),
    (2, 128, 2, 64, False, 0, 0.0),
    (1, 384, 2, 64, True, 128, 50.0),
    (1, 130, 1, 16, True, 32, 0.0),      # padding path
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(case, dtype, rng):
    B, S, H, D, causal, window, cap = case
    ks = jax.random.split(rng, 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D)).astype(dtype) for kk in ks)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap)

    def to_bh(x):
        return jnp.transpose(x, (0, 2, 1, 3)).reshape(B * H, S, D)

    ref = flash_attention_ref(to_bh(q), to_bh(k), to_bh(v), causal=causal,
                              window=window, softcap=cap)
    ref = jnp.transpose(ref.reshape(B, H, S, D), (0, 2, 1, 3))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_matches_model_attention(rng):
    """The kernel path must agree with the model's dense attention layer."""
    from repro.config import ModelConfig
    from repro.models.layers import attention, attn_init
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      window_size=32)
    params = attn_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 128, 64))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    dense, _ = attention(params, x, cfg, window=32, positions=pos)
    flash, _ = attention(params, x, cfg, window=32, positions=pos,
                         use_flash=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# lru scan
# ---------------------------------------------------------------------------

LRU_SHAPES = [(2, 256, 64), (1, 100, 33), (3, 128, 512), (1, 8, 1)]


@pytest.mark.parametrize("shape", LRU_SHAPES)
def test_lru_vs_ref(shape, rng):
    B, S, C = shape
    ks = jax.random.split(rng, 3)
    a = jax.random.uniform(ks[0], shape, minval=0.7, maxval=0.999)
    b = 0.1 * jax.random.normal(ks[1], shape)
    h0 = jax.random.normal(ks[2], (B, C))
    np.testing.assert_allclose(lru_scan(a, b, h0), lru_scan_ref(a, b, h0),
                               atol=2e-6, rtol=2e-5)
    np.testing.assert_allclose(lru_scan(a, b), lru_scan_ref(a, b),
                               atol=2e-6, rtol=2e-5)


if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(B=st.integers(1, 3), S=st.integers(1, 200), C=st.integers(1, 80),
           seed=st.integers(0, 2**30))
    def test_lru_property(B, S, C, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        a = jax.random.uniform(ks[0], (B, S, C), minval=0.0, maxval=1.0)
        b = jax.random.normal(ks[1], (B, S, C))
        got = lru_scan(a, b)
        # sequential reference
        h = np.zeros((B, C), np.float32)
        want = np.zeros((B, S, C), np.float32)
        an, bn = np.asarray(a), np.asarray(b)
        for t in range(S):
            h = an[:, t] * h + bn[:, t]
            want[:, t] = h
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)
else:
    @needs_hypothesis
    def test_lru_property():
        pass


def test_lru_matches_griffin_scan(rng):
    """Kernel path must agree with the model's associative-scan path."""
    from repro.models.griffin import linear_scan
    a = jax.random.uniform(rng, (2, 64, 32), minval=0.8, maxval=0.99)
    b = 0.1 * jax.random.normal(jax.random.fold_in(rng, 1), (2, 64, 32))
    np.testing.assert_allclose(
        np.asarray(linear_scan(a, b, use_kernel=True)),
        np.asarray(linear_scan(a, b, use_kernel=False)), atol=1e-5, rtol=1e-5)
