"""Convergence of Algorithms 1–4 and every baseline on the heterogeneous
stochastic quadratic bilevel problem, measured by the exact hyper-gradient
norm ‖∇h(x̄)‖ (closed form)."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import FederatedConfig
from repro.core import make_algorithm, quadratic_problem

ALGOS_GLOBAL = ["fedbio", "fedbioacc", "fednest"]
ALGOS_LOCAL = ["fedbio_local", "fedbioacc_local"]
# baselines whose hyper-gradient estimate averages *local* Neumann estimates:
# unbiased only in the (near-)homogeneous regime — exactly the paper's
# motivating observation (§1, §3).
ALGOS_HOMOG = ["commfedbio", "stocbio", "mrbo"]


def _run(prob, algo, rounds=120, return_g0=False, **kw):
    params = dict(algorithm=algo, num_clients=prob.num_clients,
                  local_steps=4, lr_x=0.03, lr_y=0.1, lr_u=0.1,
                  neumann_q=10, neumann_tau=0.15)
    params.update(kw)
    cfg = FederatedConfig(**params)
    alg = make_algorithm(prob, cfg)
    state = alg.init(jax.random.PRNGKey(1))
    x0 = alg.mean_x(state)
    rnd = jax.jit(alg.round)
    key = jax.random.PRNGKey(2)
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        state, _ = rnd(state, sub)
    if return_g0:
        return x0, alg.mean_x(state)
    return alg.mean_x(state)


@pytest.fixture(scope="module")
def prob():
    return quadratic_problem(jax.random.PRNGKey(0), num_clients=8, dx=10,
                             dy=10, noise=0.05)


@pytest.mark.parametrize("algo", ALGOS_GLOBAL)
def test_global_lower_converges(prob, algo):
    x0 = jnp.zeros((10,))
    g0 = float(jnp.linalg.norm(prob.exact_hypergrad(x0)))
    xT = _run(prob, algo)
    gT = float(jnp.linalg.norm(prob.exact_hypergrad(xT)))
    assert gT < 0.35 * g0, (algo, g0, gT)


@pytest.mark.parametrize("algo", ALGOS_HOMOG)
def test_local_hypergrad_baselines_converge_when_homogeneous(algo):
    prob_h = quadratic_problem(jax.random.PRNGKey(0), num_clients=8, dx=10,
                               dy=10, noise=0.05, hetero=0.1)
    kw = {"lr_x": 0.1, "rounds": 300} if algo == "mrbo" else {"rounds": 120}
    x0, xT = _run(prob_h, algo, return_g0=True, **kw)
    g0 = float(jnp.linalg.norm(prob_h.exact_hypergrad(x0)))
    gT = float(jnp.linalg.norm(prob_h.exact_hypergrad(xT)))
    assert gT < 0.35 * g0, (algo, g0, gT)


def test_local_hypergrad_bias_stalls_under_heterogeneity(prob):
    """The paper's motivating observation: averaging *local* hyper-gradients
    (Eq. 3) is biased when the lower problem is federated; the iterates stall
    at a heterogeneity floor where FedBiO keeps descending."""
    x0 = jnp.zeros((10,))
    g0 = float(jnp.linalg.norm(prob.exact_hypergrad(x0)))
    x_biased = _run(prob, "commfedbio")
    g_biased = float(jnp.linalg.norm(prob.exact_hypergrad(x_biased)))
    x_fed = _run(prob, "fedbio")
    g_fed = float(jnp.linalg.norm(prob.exact_hypergrad(x_fed)))
    assert g_biased > 2.0 * g_fed, (g_biased, g_fed)


@pytest.mark.parametrize("algo", ALGOS_LOCAL)
def test_local_lower_converges(prob, algo):
    x0 = jnp.zeros((10,))
    g0 = float(jnp.linalg.norm(prob.exact_hypergrad_local(x0)))
    xT = _run(prob, algo)
    gT = float(jnp.linalg.norm(prob.exact_hypergrad_local(xT)))
    assert gT < 0.35 * g0, (algo, g0, gT)


def test_deterministic_drift_floor_scales_with_lr():
    """Theorem 1 structure: with constant step sizes the deterministic case
    converges to a client-drift bias floor ∝ C'_γ·γ² (in ‖∇h‖²). Halving the
    learning rates must shrink the floor monotonically and substantially.

    The ∝ γ scaling of ‖∇h‖ only holds in the asymptotic (small-γ) regime —
    at γ_x ≥ 0.05 the floor is still dominated by the lower-level solve
    inexactness and barely moves — so probe γ_x ∈ {0.05, 0.025, 0.0125} and
    scale the round budget with 1/γ so every run actually reaches its floor
    (the floors are fixed points: more rounds do not change them)."""
    prob = quadratic_problem(jax.random.PRNGKey(5), num_clients=4, dx=8, dy=8,
                             noise=0.0)
    floors = []
    for lr in (0.05, 0.025, 0.0125):
        xT = _run(prob, "fedbio", rounds=int(60 / lr), lr_x=lr,
                  lr_y=3 * lr, lr_u=3 * lr)
        floors.append(float(jnp.linalg.norm(prob.exact_hypergrad(xT))))
    assert floors[0] > floors[1] > floors[2], floors
    assert floors[2] < 0.55 * floors[0], floors


def test_single_local_step_removes_drift_floor():
    """With I = 1 (communicate every step) there is no client drift, so the
    deterministic iterates reach a genuinely stationary point."""
    prob = quadratic_problem(jax.random.PRNGKey(5), num_clients=4, dx=8, dy=8,
                             noise=0.0)
    xT = _run(prob, "fedbio", rounds=1200, local_steps=1,
              lr_x=0.05, lr_y=0.15, lr_u=0.15)
    gT = float(jnp.linalg.norm(prob.exact_hypergrad(xT)))
    assert gT < 5e-2, gT


def test_lower_solution_tracked():
    """FedBiO's ȳ tracks y_{x̄} (the Lyapunov ‖ȳ − y_x̄‖² term shrinks)."""
    prob = quadratic_problem(jax.random.PRNGKey(7), num_clients=4, dx=8, dy=8,
                             noise=0.0)
    cfg = FederatedConfig(algorithm="fedbio", num_clients=4, local_steps=4,
                          lr_x=0.03, lr_y=0.1, lr_u=0.1)
    alg = make_algorithm(prob, cfg)
    state = alg.init(jax.random.PRNGKey(1))
    rnd = jax.jit(alg.round)
    key = jax.random.PRNGKey(2)
    for _ in range(300):
        key, sub = jax.random.split(key)
        state, _ = rnd(state, sub)
    xbar = alg.mean_x(state)
    ybar = jax.tree.map(lambda v: jnp.mean(v, 0), state.y)
    err = float(jnp.linalg.norm(ybar - prob.exact_lower_sol(xbar)))
    # tracks up to the constant-step drift floor
    assert err < 0.15, err


def test_heterogeneity_does_not_break_convergence():
    """Clients with very different objectives (large ζ) still converge —
    the paper's central robustness claim for local updates."""
    prob = quadratic_problem(jax.random.PRNGKey(9), num_clients=8, dx=8, dy=8,
                             noise=0.05, hetero=3.0)
    x0 = jnp.zeros((8,))
    g0 = float(jnp.linalg.norm(prob.exact_hypergrad(x0)))
    xT = _run(prob, "fedbioacc", rounds=150)
    gT = float(jnp.linalg.norm(prob.exact_hypergrad(xT)))
    assert gT < 0.4 * g0, (g0, gT)
