"""Participation engine (repro.federation.participation): sampler
determinism/resume, participation-weighted masked reductions (uniform(m=M)
bit-identical to full participation), bit-exact non-participant freezes on
the flat substrate, fused-vs-unfused trajectory equivalence under partial
participation for every algorithm, and the staleness / cadence knobs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FederatedConfig
from repro.federation.participation import (ParticipationSpec,
                                            expected_comm_fraction,
                                            make_participation)
from repro.optim import flat, sequences as seqs


# ---------------------------------------------------------------------------
# samplers: shape, budget, determinism, resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", ["full", "uniform", "weighted", "trace"])
def test_sampler_masks_deterministic_and_resumable(sampler):
    """Same (seed, round) ⇒ same mask, across independent engine instances
    and regardless of what rounds were evaluated before (resume safety)."""
    spec = ParticipationSpec(
        sampler=sampler, seed=7,
        clients_per_round=0 if sampler == "trace" else 3,
        client_weights=(1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0))
    p1 = make_participation(spec, 8)
    p2 = make_participation(spec, 8)
    # p1 walks rounds 0..9; p2 jumps straight to round 9 (the resumed run)
    masks1 = [np.asarray(p1.mask_fn(jnp.int32(r))) for r in range(10)]
    np.testing.assert_array_equal(masks1[9], np.asarray(p2.mask_fn(jnp.int32(9))))
    for r in (0, 5):
        np.testing.assert_array_equal(masks1[r],
                                      np.asarray(p2.mask_fn(jnp.int32(r))))
    # jit(traced round index) agrees with eager
    jm = jax.jit(p1.mask_fn)
    np.testing.assert_array_equal(np.asarray(jm(jnp.int32(3))), masks1[3])
    if sampler in ("uniform", "weighted"):
        assert all(m.sum() == 3 for m in masks1)      # exact budget, no repl.
        assert any(not np.array_equal(masks1[0], m) for m in masks1[1:])
    if sampler == "full":
        assert all(m.sum() == 8 for m in masks1)
    if sampler == "trace":
        assert all(m.sum() >= spec.min_clients for m in masks1)
        assert 0.0 < expected_comm_fraction(p1) <= 1.0


def test_sampler_seed_changes_trace():
    a = make_participation(ParticipationSpec("uniform", 2, seed=0), 8)
    b = make_participation(ParticipationSpec("uniform", 2, seed=1), 8)
    ma = np.stack([np.asarray(a.mask_fn(r)) for r in range(8)])
    mb = np.stack([np.asarray(b.mask_fn(r)) for r in range(8)])
    assert not np.array_equal(ma, mb)


def test_trace_file_replay(tmp_path):
    """A recorded availability log replays deterministically (and
    cyclically) through the trace sampler — including under jit, across
    independent engine instances, and in both accepted JSON forms."""
    import json
    rows = [[1, 0, 1, 1], [0, 1, 1, 0], [1, 1, 0, 0]]
    path = tmp_path / "avail.json"
    path.write_text(json.dumps(rows))
    spec = ParticipationSpec(sampler="trace", trace_path=str(path))
    p1 = make_participation(spec, 4)
    p2 = make_participation(spec, 4)       # a resumed run
    for r in range(8):
        want = np.asarray(rows[r % 3], np.float32)
        np.testing.assert_array_equal(np.asarray(p1.mask_fn(jnp.int32(r))),
                                      want)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(p2.mask_fn)(jnp.int32(r))), want)
    # the {"masks": ...} envelope is accepted too
    path.write_text(json.dumps({"masks": rows}))
    p3 = make_participation(spec, 4)
    np.testing.assert_array_equal(np.asarray(p3.mask_fn(jnp.int32(4))),
                                  np.asarray(rows[1], np.float32))
    # the replayed comm fraction reflects the log, not the nominal rate
    assert expected_comm_fraction(p3, num_rounds=6) == pytest.approx(7 / 12)


def test_trace_file_validation(tmp_path):
    import json
    path = tmp_path / "bad.json"
    for bad, err in [([[1, 0, 1]], "0/1 matrix"),
                     ([[2, 0, 1, 1]], "0/1"),
                     ([[1, 1, 1, 1], [0, 0, 0, 0]], "min_clients")]:
        path.write_text(json.dumps(bad))
        with pytest.raises(ValueError, match=err):
            make_participation(
                ParticipationSpec(sampler="trace", trace_path=str(path)), 4)
    # the documented floor applies to recorded logs too
    path.write_text(json.dumps([[1, 1, 0, 0], [1, 0, 0, 0]]))
    with pytest.raises(ValueError, match="min_clients=2"):
        make_participation(ParticipationSpec(
            sampler="trace", trace_path=str(path), min_clients=2), 4)
    path.write_text(json.dumps([[1, 0, 1, 1]]))
    with pytest.raises(ValueError, match="trace"):
        make_participation(ParticipationSpec(
            sampler="uniform", clients_per_round=2,
            trace_path=str(path)), 4)
    with pytest.raises(ValueError, match="clients_per_round"):
        make_participation(ParticipationSpec(
            sampler="trace", clients_per_round=2,
            trace_path=str(path)), 4)


def test_weighted_sampler_prefers_heavy_clients():
    spec = ParticipationSpec("weighted", 2, seed=3,
                             client_weights=(50.0, 50.0, 1e-3, 1e-3))
    p = make_participation(spec, 4)
    masks = np.stack([np.asarray(p.mask_fn(r)) for r in range(32)])
    assert masks[:, :2].mean() > masks[:, 2:].mean()


def test_trace_min_clients_floor_under_total_outage():
    """availability_rate=0 makes EVERY round an all-zero availability draw:
    the min_clients floor must still keep exactly that many clients (the
    most-available by the same draws), deterministically across engine
    instances and resume points."""
    spec = ParticipationSpec(sampler="trace", availability_rate=0.0,
                             min_clients=2, seed=11)
    p1 = make_participation(spec, 6)
    p2 = make_participation(spec, 6)       # a resumed run, fresh instance
    masks = []
    for r in range(8):
        m = np.asarray(p1.mask_fn(jnp.int32(r)))
        assert m.sum() == 2, (r, m)
        np.testing.assert_array_equal(
            m, np.asarray(p2.mask_fn(jnp.int32(r))))
        np.testing.assert_array_equal(
            m, np.asarray(jax.jit(p2.mask_fn)(jnp.int32(r))))
        masks.append(m)
    # the floor promotes by the per-round draws, not a fixed client subset
    assert any(not np.array_equal(masks[0], m) for m in masks[1:])
    # rate=1.0 keeps everyone — the floor never *removes* availability
    full = make_participation(spec._replace(availability_rate=1.0), 6)
    np.testing.assert_array_equal(np.asarray(full.mask_fn(jnp.int32(0))),
                                  np.ones(6))


def test_spec_validation():
    with pytest.raises(ValueError):
        make_participation(ParticipationSpec("uniform", 9), 4)
    with pytest.raises(ValueError):
        make_participation(ParticipationSpec("nope"), 4)
    with pytest.raises(ValueError):
        make_participation(ParticipationSpec("full",
                                             client_weights=(1.0, 2.0)), 4)
    with pytest.raises(ValueError):
        # weighted without weights would silently be uniform — refused
        make_participation(ParticipationSpec("weighted", 2), 4)
    with pytest.raises(ValueError):
        # trace ignores clients_per_round — refused rather than misleading
        make_participation(ParticipationSpec("trace", 4), 8)


# ---------------------------------------------------------------------------
# weighted masked reductions on the flat substrate
# ---------------------------------------------------------------------------

def _flat_setup(M=4, dtype=jnp.float32):
    tree = {"x": jnp.zeros((6,), dtype), "y": jnp.zeros((3,), dtype)}
    spec = flat.make_spec(jax.tree.map(
        lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), tree),
        sections=("x", "y"), block=8)
    key = jax.random.PRNGKey(0)
    btree = {s: jax.random.normal(jax.random.fold_in(key, i),
                                  (M,) + tree[s].shape).astype(dtype)
             for i, s in enumerate(tree)}
    return spec, flat.flatten_tree(spec, btree, batch_dims=1)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M", [3, 4])
def test_uniform_m_equals_full_bitwise(M, dtype):
    """uniform(m=M) weights (all ones) must reproduce the unweighted full
    client mean BIT-identically — partial participation is a strict
    generalisation, not a numerical fork."""
    spec, bufs = _flat_setup(M, dtype)
    part = make_participation(ParticipationSpec("uniform", M), M)
    _, w = part.round_weights(jnp.int32(0))
    full = flat.client_mean_masked(spec, bufs, ("mean", "mean"))
    wtd = flat.client_mean_masked(spec, bufs, ("mean", "mean"), weights=w)
    for a, b in zip(full, wtd):
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))


def test_partial_mean_over_participants_only():
    spec, bufs = _flat_setup(M=4)
    w = jnp.array([2.0, 0.0, 1.0, 0.0])
    out = flat.client_mean_masked(spec, bufs, ("mean", "none"), weights=w)
    # participants: weighted mean of rows 0, 2 only
    want = (2.0 * bufs[0][0] + 1.0 * bufs[0][2]) / 3.0
    np.testing.assert_allclose(np.asarray(out[0][0, :8]),
                               np.asarray(want[:8]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out[0][0, :8]),
                                  np.asarray(out[0][2, :8]))
    # non-participants: bit-identical pass-through ("skip the reduction")
    for m in (1, 3):
        np.testing.assert_array_equal(
            np.asarray(out[0][m]).view(np.uint8),
            np.asarray(bufs[0][m]).view(np.uint8))
    # private section untouched for everyone
    np.testing.assert_array_equal(np.asarray(out[0][..., 8:]),
                                  np.asarray(bufs[0][..., 8:]))


def test_partial_grouped_mean_and_empty_group():
    spec, bufs = _flat_setup(M=4)
    w = jnp.array([1.0, 1.0, 0.0, 0.0])     # pod {2,3} entirely absent
    out = flat.client_mean_masked(spec, bufs, ("group", "none"),
                                  num_groups=2, weights=w)
    want = (bufs[0][0] + bufs[0][1]) / 2.0
    np.testing.assert_allclose(np.asarray(out[0][0, :8]),
                               np.asarray(want[:8]), rtol=1e-6)
    for m in (2, 3):                          # empty pod passes through
        np.testing.assert_array_equal(np.asarray(out[0][m]),
                                      np.asarray(bufs[0][m]))


def test_per_section_weights_tuple():
    """Staleness-discounted sequences pass per-section weight arrays; each
    section must be reduced with its own weights."""
    spec, bufs = _flat_setup(M=4)
    wx = jnp.array([1.0, 1.0, 0.0, 0.0])
    wy = jnp.array([0.0, 0.0, 1.0, 1.0])
    out = flat.client_mean_masked(spec, bufs, ("mean", "mean"),
                                  weights=(wx, wy))
    np.testing.assert_allclose(
        np.asarray(out[0][0, :8]),
        np.asarray((bufs[0][0, :8] + bufs[0][1, :8]) / 2.0), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out[0][2, 8:]),
        np.asarray((bufs[0][2, 8:] + bufs[0][3, 8:]) / 2.0), rtol=1e-6)
    # each section's zero-weight rows pass through
    np.testing.assert_array_equal(np.asarray(out[0][3, :8]),
                                  np.asarray(bufs[0][3, :8]))
    np.testing.assert_array_equal(np.asarray(out[0][0, 8:]),
                                  np.asarray(bufs[0][0, 8:]))


# ---------------------------------------------------------------------------
# engine: bit-exact freezes, staleness counters, cadence
# ---------------------------------------------------------------------------

def _toy_engine(algo="fedbioacc", part=None, cfg=None, M=4, seq_overrides=None):
    cfg = cfg or FederatedConfig(num_clients=M, local_steps=2, lr_x=0.05,
                                 lr_y=0.1, lr_u=0.1)
    tmpl = {"x": jnp.zeros((6,)), "y": jnp.zeros((3,)), "u": jnp.zeros((3,))}
    aspec = seqs.SPECS[algo].without_hierarchy()
    if seq_overrides:
        aspec = aspec._replace(sequences=tuple(
            q._replace(**seq_overrides.get(q.section, {}))
            for q in aspec.sequences))
    tmpl = {s: tmpl[s] for s in aspec.sections}

    def oracle(v, batch):
        return {s: jax.tree.map(lambda a: 0.1 * a + batch, v[s])
                for s in aspec.sections}

    eng = seqs.make_engine(cfg, aspec, tmpl, oracle, block=8,
                           participation=part)
    key = jax.random.PRNGKey(0)
    vt = {s: jax.random.normal(jax.random.fold_in(key, i),
                               (M,) + tmpl[s].shape)
          for i, s in enumerate(aspec.sections)}
    return cfg, eng, eng.init_state(vt)


def test_nonparticipant_buffers_frozen_bit_exact_across_round():
    part = make_participation(ParticipationSpec("uniform", 2, seed=5), 4)
    cfg, eng, st = _toy_engine(part=part)
    mask0 = np.asarray(part.mask_fn(jnp.int32(0)))
    before = st
    jstep = jax.jit(eng.step)
    for t in range(cfg.local_steps):          # one full round incl. comm
        st = jstep(st, jnp.float32(0.3 + t))
    for b0, b1 in zip(before.vars + before.mom, st.vars + st.mom):
        for m in range(4):
            same = np.array_equal(np.asarray(b0[m]).view(np.uint8),
                                  np.asarray(b1[m]).view(np.uint8))
            assert same == (mask0[m] == 0.0), (m, mask0[m])
    # staleness counters: absentees aged by 1, participants reset
    np.testing.assert_array_equal(np.asarray(st.stale),
                                  (mask0 == 0.0).astype(np.int32))


def test_nonfinite_skipped_oracle_cannot_poison_frozen_buffers():
    """A non-participant whose (skipped) oracle blows up must stay frozen:
    mask_buffers is a where-select, so 0·inf NaNs can never reach the pinned
    momentum (the failure the multiply formulation would have had)."""
    bufs = (jnp.stack([jnp.full((8,), jnp.inf), jnp.ones((8,))]),)
    out = flat.mask_buffers(bufs, jnp.array([0.0, 1.0]))
    assert bool(jnp.all(out[0][0] == 0.0))
    np.testing.assert_array_equal(np.asarray(out[0][1]),
                                  np.asarray(bufs[0][1]))


def test_uniform_m_equals_no_participation_engine_bitwise():
    """uniform(m=M) through the whole fused engine — gated launches, masked
    gradients, weighted comm, stale counters — must be bit-identical to the
    engine with no participation at all."""
    part = make_participation(ParticipationSpec("uniform", 4), 4)
    cfg, eng_p, st_p = _toy_engine(part=part)
    _, eng_n, st_n = _toy_engine(part=None)
    jp, jn = jax.jit(eng_p.step), jax.jit(eng_n.step)
    for t in range(4):
        st_p = jp(st_p, jnp.float32(0.1 * t))
        st_n = jn(st_n, jnp.float32(0.1 * t))
    for a, b in zip(st_p.vars + st_p.mom, st_n.vars + st_n.mom):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(st_p.stale), np.zeros(4, np.int32))


def test_staleness_discount_fades_returning_client():
    """A client that missed rounds re-enters the average discounted by
    α^staleness: with α → 0 the returning client is averaged out entirely
    (the fresh participants dominate), with α = 1 it re-enters at full
    weight — so the two runs must differ and the α→0 limit must equal the
    mean over the never-absent clients."""
    M, I = 4, 1
    cfg = FederatedConfig(num_clients=M, local_steps=I, lr_x=0.0, lr_y=0.0,
                          lr_u=0.0)

    # scripted trace: client 0 absent at round 0, everyone in at round 1
    def run(alpha):
        part = make_participation(ParticipationSpec("full"), M)
        masks = jnp.asarray([[0.0, 1.0, 1.0, 1.0], [1.0, 1.0, 1.0, 1.0]])
        part = part._replace(mask_fn=lambda r: masks[jnp.minimum(r, 1)])
        ov = {"x": {"staleness": alpha}, "y": {"staleness": alpha},
              "u": {"staleness": alpha}}
        _, eng, st = _toy_engine(part=part, cfg=cfg, seq_overrides=ov)
        jstep = jax.jit(eng.step)
        for t in range(2):
            st = jstep(st, jnp.float32(0.0))
        return st

    st_full = run(1.0)
    st_faded = run(1e-4)
    # lr = 0 keeps client values at their init, so round 1's average is over
    # the init rows; with α ≈ 0 client 0's stale row is ~excluded
    a = np.asarray(st_full.vars[0][1])
    b = np.asarray(st_faded.vars[0][1])
    assert not np.allclose(a, b)
    _, eng0, st0 = _toy_engine(part=None, cfg=cfg)
    init_rows = np.asarray(st0.vars[0])
    want = init_rows[1:].mean(axis=0)         # mean over never-absent 1..3
    np.testing.assert_allclose(b, want, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(a, init_rows.mean(axis=0), rtol=1e-5)


def test_comm_every_decouples_sequence_cadence():
    """comm_every=2 on the u sequence: at the first comm round x is averaged
    while u still differs across clients; at the second both agree."""
    cfg = FederatedConfig(num_clients=4, local_steps=1, lr_x=0.01, lr_y=0.01,
                          lr_u=0.01)
    ov = {"u": {"comm_every": 2}}
    _, eng, st = _toy_engine(part=None, cfg=cfg, seq_overrides=ov)
    jstep = jax.jit(eng.step)
    st = jstep(st, jnp.float32(0.2))          # comm round 1: x,y yes; u no

    def spread(sec):
        vt = flat.unflatten_tree(eng.spec, st.vars)
        return float(jnp.max(jnp.std(vt[sec], axis=0)))

    assert spread("x") < 1e-7
    assert spread("u") > 1e-4
    st = jstep(st, jnp.float32(0.2))          # comm round 2: u too
    assert spread("u") < 1e-7


# ---------------------------------------------------------------------------
# trainer-level: fused vs unfused under partial participation, and
# uniform(m=M) == today's full-participation trajectories, all five algos
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    from repro.configs import ARCHS
    from repro.data import make_fed_batch_fn
    from repro.models import build_model

    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=2, lr_x=0.05,
                          lr_y=0.05, lr_u=0.05, neumann_q=2, neumann_tau=0.3)
    batch_fn = make_fed_batch_fn(cfg, num_clients=4, per_client=1, seq_len=16)
    return model, fed, batch_fn


_ALGOS = {
    "fedbio": ("x", "y", "u"),
    "fedbioacc": ("x", "y", "u", "omega", "nu", "q"),
    "fedbio_local": ("x", "y"),
    "fedbioacc_local": ("x", "y", "omega", "nu"),
    "fedavg": ("params", "mom"),
}


def _run_traj(maker, model, fed, batch_fn, steps=4, **kw):
    init, step = maker(model, fed, n_micro=1, remat=False, **kw)
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    key = jax.random.PRNGKey(1)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    return step.views(state) if hasattr(step, "views") else state


@pytest.mark.parametrize("algo", sorted(_ALGOS))
def test_fused_matches_unfused_under_partial_participation(setup, algo):
    """uniform(m = M//2): the fused engine (gated launches + weighted masked
    reductions) must reproduce the unfused tree path (where-freezes +
    weighted per-leaf means) across communication rounds."""
    from repro.federation import trainer as tr

    model, fed, batch_fn = setup
    maker = getattr(tr, f"make_{algo}_train_step")
    pspec = ParticipationSpec("uniform", 2, seed=11)
    v1 = _run_traj(maker, model, fed, batch_fn, participation=pspec)
    v2 = _run_traj(maker, model, fed, batch_fn, participation=pspec,
                   fuse_storm=True, storm_block=256)
    for n in _ALGOS[algo]:
        for a, b in zip(jax.tree.leaves(getattr(v1, n)),
                        jax.tree.leaves(getattr(v2, n))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{algo}.{n}")


@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("algo", sorted(_ALGOS))
def test_uniform_full_m_bit_identical_trajectories(setup, algo, fuse):
    """uniform(m=M) must reproduce today's full-participation trajectories
    BIT-identically — fused and unfused — for all five algorithms."""
    from repro.federation import trainer as tr

    model, fed, batch_fn = setup
    maker = getattr(tr, f"make_{algo}_train_step")
    kw = dict(fuse_storm=True, storm_block=256) if fuse else {}
    v1 = _run_traj(maker, model, fed, batch_fn, steps=3, **kw)
    v2 = _run_traj(maker, model, fed, batch_fn, steps=3,
                   participation=ParticipationSpec("uniform", 4), **kw)
    for n in _ALGOS[algo]:
        for a, b in zip(jax.tree.leaves(getattr(v1, n)),
                        jax.tree.leaves(getattr(v2, n))):
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8),
                err_msg=f"{algo}.{n}")


@pytest.mark.parametrize("local_steps", [1, 2])
@pytest.mark.parametrize("algo", ["fedbioacc", "fedbioacc_local"])
def test_unfused_staleness_matches_fused(setup, algo, local_steps):
    """stale_discount < 1 on the LEGACY tree path: the unfused states carry
    per-client staleness counters (bit-identical to the engine's
    ``FlatState.stale``) and the discounted α^staleness reductions reproduce
    the fused engine's trajectories — including the comm-round gating of
    the counter bumps (local_steps > 1)."""
    from repro.federation import trainer as tr

    import dataclasses

    model, fed, batch_fn = setup
    fed = dataclasses.replace(fed, local_steps=local_steps)
    maker = getattr(tr, f"make_{algo}_train_step")
    pspec = ParticipationSpec("uniform", 2, seed=11, stale_discount=0.3)

    def traj(**kw):
        init, step = maker(model, fed, n_micro=1, remat=False,
                           participation=pspec, **kw)
        state = init(jax.random.PRNGKey(0))
        jstep = jax.jit(step)
        key = jax.random.PRNGKey(1)
        for _ in range(3 * local_steps):
            key, sub = jax.random.split(key)
            state, _ = jstep(state, batch_fn(sub))
        return state, (step.views(state) if hasattr(step, "views")
                       else state)

    st_u, v_u = traj()
    st_f, v_f = traj(fuse_storm=True, storm_block=256)
    # counters advance bit-identically on both paths
    np.testing.assert_array_equal(np.asarray(st_u.stale),
                                  np.asarray(st_f.stale))
    assert int(np.asarray(st_u.stale).max()) > 0   # a client actually aged
    for n in _ALGOS[algo]:
        for a, b in zip(jax.tree.leaves(getattr(v_u, n)),
                        jax.tree.leaves(getattr(v_f, n))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{algo}.{n}")


def test_undiscounted_unfused_states_carry_no_counters(setup):
    """Without staleness discounting the legacy states keep their exact
    pre-participation structure (stale = the empty tuple, zero leaves)."""
    from repro.federation.trainer import make_fedbioacc_train_step

    model, fed, _ = setup
    init, _ = make_fedbioacc_train_step(
        model, fed, n_micro=1, remat=False,
        participation=ParticipationSpec("uniform", 2))
    assert init(jax.random.PRNGKey(0)).stale == ()


def test_participation_recorded_on_train_step(setup):
    from repro.federation.trainer import make_fedbioacc_train_step

    model, fed, _ = setup
    pspec = ParticipationSpec("uniform", 2)
    for kw in ({}, {"fuse_storm": True, "storm_block": 256}):
        _, step = make_fedbioacc_train_step(model, fed, n_micro=1,
                                            remat=False, participation=pspec,
                                            **kw)
        assert step.participation.spec == pspec
