"""Quantitative validation of the paper's headline claims (EXPERIMENTS.md
§Paper-claims):

* **Linear speed-up in M** (Thm 1/2): at fixed T, the stochastic error term
  scales ≈ 1/M, so more clients → lower final ‖∇h‖ under noise.
* **Communication efficiency** (Table 1): to reach a fixed ε, FedBiOAcc needs
  fewer communicated floats than FedBiO, which needs far fewer than FedNest
  (per-step averaging).
* **Local steps trade-off**: more local steps per round reduce rounds-to-ε.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.config import FederatedConfig
from repro.core import make_algorithm, quadratic_problem


def _grad_trajectory(prob, algo, rounds, *, local_steps=4, lr_x=0.03,
                     lr_y=0.1, lr_u=0.1, seed=2, **kw):
    cfg = FederatedConfig(algorithm=algo, num_clients=prob.num_clients,
                          local_steps=local_steps, lr_x=lr_x, lr_y=lr_y,
                          lr_u=lr_u, neumann_q=10, neumann_tau=0.15, **kw)
    alg = make_algorithm(prob, cfg)
    state = alg.init(jax.random.PRNGKey(1))
    rnd = jax.jit(alg.round)
    key = jax.random.PRNGKey(seed)
    traj = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        state, _ = rnd(state, sub)
        traj.append(float(jnp.linalg.norm(
            prob.exact_hypergrad(alg.mean_x(state)))))
    return traj, alg.comm_floats


def test_linear_speedup_in_clients():
    """Same per-client noise, same rounds: the M=16 run must end with a
    meaningfully lower tail-averaged gradient norm than M=2 (Theorem 1's
    σ²/(bM) term)."""
    tails = {}
    for M in (2, 16):
        prob = quadratic_problem(jax.random.PRNGKey(0), num_clients=M,
                                 dx=10, dy=10, noise=1.2, hetero=0.6)
        traj, _ = _grad_trajectory(prob, "fedbio", rounds=150)
        tails[M] = sum(traj[-30:]) / 30
    assert tails[16] < 0.75 * tails[2], tails


def test_fedbioacc_beats_fedbio_per_communication():
    """Rounds (≙ communicated floats) to reach ε: the STORM-accelerated
    variant must win at equal round budgets (communication complexity
    O(ε⁻¹) vs O(ε⁻¹·⁵))."""
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.6, hetero=1.0)
    # FedBiOAcc communicates 2x floats/round (momenta), so equal float
    # budget = fedbio at 2x the rounds. The STORM α_t-schedule has a slow
    # transient (acc is still at bio's noise floor at 150 rounds) while bio
    # has fully plateaued by 300 — compare past the transient: acc@300
    # (0.032) vs bio@600 (0.226); the gap then keeps widening with budget.
    traj_b, comm_b = _grad_trajectory(prob, "fedbio", rounds=600)
    traj_a, comm_a = _grad_trajectory(prob, "fedbioacc", rounds=300)
    assert comm_a == 2 * comm_b
    tail_b = sum(traj_b[-30:]) / 30
    tail_a = sum(traj_a[-30:]) / 30
    assert tail_a < tail_b, (tail_a, tail_b)


def test_fednest_needs_more_communication():
    """FedNest converges well but communicates ~(N_y+N_u+1)× more floats per
    round; at a fixed communication budget FedBiO reaches a lower error."""
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.3)
    traj_f, comm_f = _grad_trajectory(prob, "fednest", rounds=30)
    traj_b, comm_b = _grad_trajectory(prob, "fedbio", rounds=30 * comm_f // 30 // 1)
    # equal float budget: fedbio gets comm_f/comm_b times the rounds
    ratio = comm_f / comm_b
    assert ratio > 2.0, ratio
    traj_b, _ = _grad_trajectory(prob, "fedbio", rounds=int(30 * ratio))
    assert sum(traj_b[-10:]) / 10 < sum(traj_f[-10:]) / 10 * 1.1


def test_more_local_steps_fewer_rounds():
    """Increasing I reduces the number of communication rounds needed to
    reach a fixed accuracy (the whole point of local updates)."""
    prob = quadratic_problem(jax.random.PRNGKey(6), num_clients=8, dx=10,
                             dy=10, noise=0.3)
    target_rounds = {}
    for I in (1, 8):
        traj, _ = _grad_trajectory(prob, "fedbio", rounds=200, local_steps=I)
        g0 = traj[0]
        eps = 0.5 * g0
        hit = next((i for i, g in enumerate(traj) if g < eps), len(traj))
        target_rounds[I] = hit
    assert target_rounds[8] < target_rounds[1], target_rounds
