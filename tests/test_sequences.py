"""Sequence-spec engine (repro.optim.sequences): section-masked
communication (private sections bit-identical), policy-driven schedules,
fused-vs-unfused trajectory equivalence for the remaining four algorithms
(fedbioacc is covered by tests/test_flat_substrate.py), and the
hierarchical-schedule regression for the algorithms that previously ignored
``cfg.hierarchy_period`` (fedbio_local, fedbioacc_local, fedavg)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FederatedConfig
from repro.optim import flat, sequences as seqs


def _mixed_tree():
    return {
        "x": {"w": jnp.arange(24.0).reshape(4, 6),
              "b": (jnp.arange(7, dtype=jnp.bfloat16), jnp.float32(3.5))},
        "y": {"h": jnp.arange(5.0) * 2.0, "hb": jnp.full((3,), 2, jnp.bfloat16)},
        "u": {"h": jnp.ones((5,)), "hb": jnp.ones((3,), jnp.bfloat16)},
    }


def _clients(tree, m, key):
    """Distinct per-client copies (client i = tree + noise_i)."""
    leaves, treedef = jax.tree.flatten(tree)
    ks = jax.random.split(key, len(leaves))
    out = [jnp.stack([jnp.asarray(l) + jax.random.normal(
        jax.random.fold_in(k, i), jnp.shape(l)).astype(jnp.asarray(l).dtype)
        for i in range(m)]) for k, l in zip(ks, leaves)]
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# section-masked communication
# ---------------------------------------------------------------------------

def test_masked_client_mean_roundtrip_mixed_dtypes(rng):
    """Averaged sections equal the per-leaf client mean; private sections are
    BIT-identical; grouped sections equal the pod-local mean — across both
    dtype buffers of a mixed f32/bf16 tree."""
    tree = _mixed_tree()
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8)
    M = 4
    btree = _clients(tree, M, rng)
    bufs = flat.flatten_tree(spec, btree, batch_dims=1)
    out = flat.client_mean_masked(spec, bufs, ("mean", "none", "group"),
                                  num_groups=2)
    back = flat.unflatten_tree(spec, out)
    # x: full client mean, per leaf
    for a, b in zip(jax.tree.leaves(btree["x"]), jax.tree.leaves(back["x"])):
        want = jnp.broadcast_to(jnp.mean(a, axis=0, keepdims=True), a.shape)
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-6, atol=1e-6)
    # y: private — bit-identical pass-through (compare raw bit patterns)
    for a, b in zip(jax.tree.leaves(btree["y"]), jax.tree.leaves(back["y"])):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))
    # u: grouped mean (clients {0,1} and {2,3})
    for a, b in zip(jax.tree.leaves(btree["u"]), jax.tree.leaves(back["u"])):
        g = jnp.reshape(a, (2, 2) + a.shape[1:])
        want = jnp.broadcast_to(jnp.mean(g, axis=1, keepdims=True),
                                g.shape).reshape(a.shape)
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-2, atol=1e-2 if a.dtype == jnp.bfloat16
                                   else 1e-6)


def test_masked_mean_private_tiles_never_reduced(rng):
    """NaNs in a private section must not leak into the averaged sections
    (proof the private tiles are sliced around, not blended post-reduction)."""
    tree = {"x": jnp.ones((8,)), "y": jnp.ones((6,)), "u": jnp.ones((6,))}
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8)
    M = 3
    btree = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (M,) + v.shape), tree)
    btree = dict(btree, y=jnp.full((M, 6), jnp.nan))
    bufs = flat.flatten_tree(spec, btree, batch_dims=1)
    out = flat.client_mean_masked(spec, bufs, ("mean", "none", "mean"))
    back = flat.unflatten_tree(spec, out)
    assert bool(jnp.all(jnp.isfinite(back["x"])))
    assert bool(jnp.all(jnp.isfinite(back["u"])))
    assert bool(jnp.all(jnp.isnan(back["y"])))


def test_comm_buffers_policy_schedule(rng):
    """PRIVATE never communicates; HIERARCHICAL follows local_steps and the
    pod-local/global hierarchy; AVERAGED ignores the hierarchy."""
    tree = {"x": jnp.ones((8,)), "y": jnp.ones((8,)), "u": jnp.ones((8,))}
    spec = flat.make_spec(tree, sections=("x", "y", "u"), block=8)
    cfg = FederatedConfig(num_clients=4, local_steps=2, hierarchy_period=2,
                          hierarchy_groups=2)
    btree = _clients(tree, 4, rng)
    bufs = flat.flatten_tree(spec, btree, batch_dims=1)
    policies = (seqs.HIERARCHICAL, seqs.PRIVATE, seqs.AVERAGED)

    def spread(buf_seg, a, b):
        return float(jnp.max(jnp.abs(buf_seg[a] - buf_seg[b])))

    # step 0: (0+1) % 2 != 0 — no communication at all
    out = seqs.comm_buffers(spec, cfg, jnp.int32(0), bufs, policies)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(bufs[0]))
    # step 1: comm round 1 — pod-local for x (hierarchical), full for u
    out = seqs.comm_buffers(spec, cfg, jnp.int32(1), bufs, policies)
    back = flat.unflatten_tree(spec, out)
    orig = flat.unflatten_tree(spec, bufs)
    assert spread(back["x"], 0, 1) < 1e-6          # same pod agrees
    assert spread(back["x"], 0, 2) > 1e-6          # pods still differ
    assert spread(back["u"], 0, 2) < 1e-6          # AVERAGED crosses pods
    np.testing.assert_array_equal(np.asarray(back["y"]),
                                  np.asarray(orig["y"]))   # private untouched
    # step 3: comm round 2 — global round for the hierarchical section
    out = seqs.comm_buffers(spec, cfg, jnp.int32(3), bufs, policies)
    back = flat.unflatten_tree(spec, out)
    assert spread(back["x"], 0, 2) < 1e-6
    np.testing.assert_array_equal(np.asarray(back["y"]),
                                  np.asarray(orig["y"]))


# ---------------------------------------------------------------------------
# fused-vs-unfused trajectory equivalence (model scale), all remaining algos
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    from repro.configs import ARCHS
    from repro.data import make_fed_batch_fn
    from repro.models import build_model

    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=3, lr_x=0.05,
                          lr_y=0.05, lr_u=0.05, neumann_q=2, neumann_tau=0.3)
    batch_fn = make_fed_batch_fn(cfg, num_clients=4, per_client=1, seq_len=16)
    return model, fed, batch_fn


_MAKER_FIELDS = {
    "fedbio": ("x", "y", "u"),
    "fedbio_local": ("x", "y"),
    "fedbioacc_local": ("x", "y", "omega", "nu"),
    "fedavg": ("params", "mom"),
}


@pytest.mark.parametrize("algo", sorted(_MAKER_FIELDS))
def test_fuse_storm_matches_unfused_trajectory(setup, algo):
    """Every algorithm's fuse_storm=True path must reproduce its unfused
    trajectory across communication rounds (flat state end-to-end, legacy
    pytree state via train_step.views)."""
    from repro.federation import trainer as tr

    model, fed, batch_fn = setup
    maker = getattr(tr, f"make_{algo}_train_step")
    i1, s1 = maker(model, fed, n_micro=1, remat=False)
    i2, s2 = maker(model, fed, n_micro=1, remat=False, fuse_storm=True,
                   storm_block=256)
    st1 = i1(jax.random.PRNGKey(0))
    st2 = i2(jax.random.PRNGKey(0))
    assert isinstance(st2, seqs.FlatState)
    j1 = jax.jit(s1)
    j2 = jax.jit(s2, donate_argnums=(0,))
    key = jax.random.PRNGKey(1)
    for _ in range(4):                       # crosses a communication round
        key, sub = jax.random.split(key)
        b = batch_fn(sub)
        st1, _ = j1(st1, b)
        st2, _ = j2(st2, b)
    v2 = s2.views(st2)
    assert int(v2.step) == int(st1.step) == 4
    for n in _MAKER_FIELDS[algo]:
        for a, b in zip(jax.tree.leaves(getattr(st1, n)),
                        jax.tree.leaves(getattr(v2, n))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{algo}.{n}")


def test_local_fused_heads_stay_private(setup):
    """On the flat substrate the private y/ω sections must keep their
    per-client personalisation across communication rounds while x syncs."""
    from repro.federation.trainer import make_fedbioacc_local_train_step

    model, fed, batch_fn = setup
    init, step = make_fedbioacc_local_train_step(model, fed, n_micro=1,
                                                 remat=False, fuse_storm=True,
                                                 storm_block=256)
    state = init(jax.random.PRNGKey(0))
    jstep = jax.jit(step, donate_argnums=(0,))
    key = jax.random.PRNGKey(1)
    for _ in range(3):                       # step 3 == I -> comm round
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    v = step.views(state)

    def spread(tree):
        return max(float(jnp.max(jnp.std(l.astype(jnp.float32), axis=0)))
                   for l in jax.tree.leaves(tree))

    assert spread(v.x) < 1e-6               # body averaged
    assert spread(v.nu) < 1e-6              # ν averaged (Alg. 4)
    assert spread(v.y) > 1e-4               # heads remain personalised
    assert spread(v.omega) > 0.0            # ω private


# ---------------------------------------------------------------------------
# hierarchical-schedule regression: the algorithms that used to bypass _comm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["fedbio_local", "fedbioacc_local", "fedavg"])
def test_hierarchy_period_now_honored(setup, algo):
    """Regression for the hierarchical-communication inconsistency: a nonzero
    ``hierarchy_period`` must change these algorithms' trajectories (they
    previously called the flat mean directly and silently ignored it)."""
    import dataclasses
    from repro.federation import trainer as tr

    model, fed, batch_fn = setup
    maker = getattr(tr, f"make_{algo}_train_step")
    # period 3 keeps BOTH observed rounds pod-local (round_idx 1, 2);
    # two steps because fedbioacc_local's zero-momentum init makes step 1 a
    # warm-up no-op for x (x only moves once ν is non-zero)
    fed_flat = dataclasses.replace(fed, local_steps=1)
    fed_hier = dataclasses.replace(fed, local_steps=1, hierarchy_period=3,
                                   hierarchy_groups=2)
    comm_field = "params" if algo == "fedavg" else "x"

    def one_step(cfg_fed):
        init, step = maker(model, cfg_fed, n_micro=1, remat=False)
        state = init(jax.random.PRNGKey(0))
        jstep = jax.jit(step)
        state, _ = jstep(state, batch_fn(jax.random.PRNGKey(1)))
        state, _ = jstep(state, batch_fn(jax.random.PRNGKey(2)))
        return getattr(state, comm_field)

    x_flat = one_step(fed_flat)
    x_hier = one_step(fed_hier)

    def pair_spread(tree, a, b):
        return max(float(jnp.max(jnp.abs(
            l[a].astype(jnp.float32) - l[b].astype(jnp.float32))))
            for l in jax.tree.leaves(tree))

    # flat schedule: round 1 averages everyone; hierarchical: round 1 is
    # pod-local (round_idx 1 % 2 != 0), so pods must still differ
    assert pair_spread(x_flat, 0, 2) < 1e-6
    assert pair_spread(x_hier, 0, 1) < 1e-6        # same pod agrees
    assert pair_spread(x_hier, 0, 2) > 1e-6        # pods diverged -> honored


# ---------------------------------------------------------------------------
# core algorithms through the same engine
# ---------------------------------------------------------------------------

def test_fedavg_zero_momentum_fused_views(setup):
    """momentum=0.0 + fuse_storm must still carry the mom state (the legacy
    FedAvgTrainState has a mom field; m' = 0·m + g = the raw gradient)."""
    from repro.federation.trainer import make_fedavg_train_step

    model, fed, batch_fn = setup
    init, step = make_fedavg_train_step(model, fed, n_micro=1, remat=False,
                                        momentum=0.0, fuse_storm=True,
                                        storm_block=256)
    state = init(jax.random.PRNGKey(0))
    state, _ = jax.jit(step)(state, batch_fn(jax.random.PRNGKey(1)))
    v = step.views(state)
    assert v.mom is not None
    # β = 0 ⇒ mom is exactly the last gradient (non-zero after one step)
    assert any(float(jnp.max(jnp.abs(l))) > 0 for l in jax.tree.leaves(v.mom))


@pytest.mark.parametrize("algo", ["fedbio_local", "fedbioacc_local"])
def test_core_local_fuse_storm_matches(algo):
    from repro.core import make_algorithm, quadratic_problem
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.3, hetero=1.0)

    def run(**kw):
        cfg = FederatedConfig(algorithm=algo, num_clients=8, local_steps=4,
                              lr_x=0.03, lr_y=0.1, neumann_q=4,
                              neumann_tau=0.2, **kw)
        alg = make_algorithm(prob, cfg)
        state = alg.init(jax.random.PRNGKey(1))
        rnd = jax.jit(alg.round)
        key = jax.random.PRNGKey(2)
        for _ in range(3):
            key, sub = jax.random.split(key)
            state, _ = rnd(state, sub)
        return state

    a, b = run(), run(fuse_storm=True, fuse_storm_block=64)
    # the core reference loops always use the paper's flat averaging —
    # fuse_storm must stay a pure perf switch even with hierarchy_period set
    c = run(hierarchy_period=2, hierarchy_groups=2)
    d = run(hierarchy_period=2, hierarchy_groups=2, fuse_storm=True,
            fuse_storm_block=64)
    for n in a._fields:
        for other in (b, c, d):
            np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                       np.asarray(getattr(other, n)),
                                       rtol=1e-5, atol=1e-5, err_msg=n)


def test_core_fedbio_fuse_storm_matches():
    """cfg.fuse_storm on core fedbio (the last tree-map-only reference loop)
    must be a pure perf switch: same 5-batch sampling, same trajectory."""
    from repro.core import make_algorithm, quadratic_problem
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.3, hetero=1.0)

    def run(**kw):
        cfg = FederatedConfig(algorithm="fedbio", num_clients=8,
                              local_steps=4, lr_x=0.03, lr_y=0.1, lr_u=0.1,
                              **kw)
        alg = make_algorithm(prob, cfg)
        state = alg.init(jax.random.PRNGKey(1))
        rnd = jax.jit(alg.round)
        key = jax.random.PRNGKey(2)
        for _ in range(3):
            key, sub = jax.random.split(key)
            state, _ = rnd(state, sub)
        return state

    a, b = run(), run(fuse_storm=True, fuse_storm_block=64)
    for n in a._fields:
        np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                   np.asarray(getattr(b, n)),
                                   rtol=1e-5, atol=1e-5, err_msg=n)


def test_core_fedbio_fuse_oracles_matches_in_deterministic_limit():
    """With noise=0 every oracle draw is identical, so the shared
    fused_oracles linearization (1 batch/step instead of 5) must reproduce
    the unfused fedbio trajectory — alone and on the engine."""
    from repro.core import make_algorithm, quadratic_problem
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.0, hetero=1.0)

    def run(**kw):
        cfg = FederatedConfig(algorithm="fedbio", num_clients=8,
                              local_steps=4, lr_x=0.03, lr_y=0.1, lr_u=0.1,
                              **kw)
        alg = make_algorithm(prob, cfg)
        state = alg.init(jax.random.PRNGKey(1))
        state, _ = jax.jit(alg.round)(state, jax.random.PRNGKey(2))
        return state

    a = run()
    b = run(fuse_oracles=True)
    c = run(fuse_oracles=True, fuse_storm=True, fuse_storm_block=64)
    for n in a._fields:
        for other in (b, c):
            np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                       np.asarray(getattr(other, n)),
                                       rtol=1e-5, atol=1e-5, err_msg=n)


def test_core_local_fuse_oracles_matches_in_deterministic_limit():
    """With noise=0 every oracle draw is identical, so sharing one batch
    across (ω, Φ) must reproduce the unfused local trajectory."""
    from repro.core import make_algorithm, quadratic_problem
    prob = quadratic_problem(jax.random.PRNGKey(4), num_clients=8, dx=10,
                             dy=10, noise=0.0, hetero=1.0)

    def run(**kw):
        cfg = FederatedConfig(algorithm="fedbioacc_local", num_clients=8,
                              local_steps=4, lr_x=0.03, lr_y=0.1,
                              neumann_q=4, neumann_tau=0.2, **kw)
        alg = make_algorithm(prob, cfg)
        state = alg.init(jax.random.PRNGKey(1))
        state, _ = jax.jit(alg.round)(state, jax.random.PRNGKey(2))
        return state

    a = run()
    b = run(fuse_oracles=True)
    c = run(fuse_oracles=True, fuse_storm=True, fuse_storm_block=64)
    for n in a._fields:
        np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                   np.asarray(getattr(b, n)),
                                   rtol=1e-5, atol=1e-5, err_msg=n)
        np.testing.assert_allclose(np.asarray(getattr(a, n)),
                                   np.asarray(getattr(c, n)),
                                   rtol=1e-5, atol=1e-5, err_msg=n)
