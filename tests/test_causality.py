"""Causality property (hypothesis): for every decoder family, perturbing
tokens after position t must not change logits at or before t; for the
encoder (bidirectional) it must."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCHS
from repro.models import build_model

_DECODERS = ["granite-8b", "gemma2-2b", "mamba2-130m", "recurrentgemma-9b",
             "olmoe-1b-7b"]

_CACHE = {}


def _model(arch):
    if arch not in _CACHE:
        cfg = ARCHS[arch].reduced()
        m = build_model(cfg, dtype=jnp.float32)
        _CACHE[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _CACHE[arch]


@pytest.mark.parametrize("arch", _DECODERS)
@settings(max_examples=5, deadline=None)
@given(t=st.integers(4, 28), seed=st.integers(0, 2**30))
def test_future_tokens_do_not_leak(arch, t, seed):
    cfg, model, params = _model(arch)
    key = jax.random.PRNGKey(seed)
    S = 32
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    toks2 = toks.at[0, t:].set(
        jax.random.randint(jax.random.fold_in(key, 1), (S - t,), 0,
                           cfg.vocab_size))
    l1, _ = model.forward(params, {"tokens": toks, "labels": toks})
    l2, _ = model.forward(params, {"tokens": toks2, "labels": toks2})
    np.testing.assert_allclose(np.asarray(l1[:, :t]), np.asarray(l2[:, :t]),
                               atol=1e-5)


def test_encoder_is_bidirectional(rng):
    cfg = ARCHS["hubert-xlarge"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(rng)
    B, S = 1, 32
    frames = 0.3 * jax.random.normal(rng, (B, S, cfg.frontend_dim))
    frames2 = frames.at[0, 20:].add(1.0)
    l1, _ = model.forward(params, {"frames": frames,
                                   "labels": jnp.zeros((B, S), jnp.int32)})
    l2, _ = model.forward(params, {"frames": frames2,
                                   "labels": jnp.zeros((B, S), jnp.int32)})
    # early positions MUST change (bidirectional attention sees the future)
    assert float(jnp.max(jnp.abs(l1[:, :10] - l2[:, :10]))) > 1e-4
