"""Hierarchical (pod-local / global) averaging schedule — beyond-paper
multi-pod extension: cross-pod traffic ÷ hierarchy_period."""
import jax
import jax.numpy as jnp

from repro.config import FederatedConfig
from repro.configs import ARCHS
from repro.core.tree_util import client_mean_grouped
from repro.data import make_fed_batch_fn
from repro.federation.trainer import make_fedbio_train_step
from repro.models import build_model


def _pair_spread(tree, a, b):
    return max(float(jnp.max(jnp.abs(
        v[a].astype(jnp.float32) - v[b].astype(jnp.float32))))
        for v in jax.tree.leaves(tree))


def test_grouped_mean():
    t = {"w": jnp.arange(8.0).reshape(4, 2)}
    out = client_mean_grouped(t, 2)
    # group 0 = clients {0,1}, group 1 = clients {2,3}
    assert float(out["w"][0, 0]) == float(out["w"][1, 0]) == 1.0
    assert float(out["w"][2, 0]) == float(out["w"][3, 0]) == 5.0


def test_pod_local_then_global_sync(rng):
    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=4, local_steps=1, hierarchy_period=3,
                          hierarchy_groups=2, lr_x=0.05, lr_y=0.05, lr_u=0.05)
    init, step = make_fedbio_train_step(model, fed, n_micro=1, remat=False)
    state = init(rng)
    bf = make_fed_batch_fn(cfg, num_clients=4, per_client=1, seq_len=16,
                           hetero_alpha=0.1)
    jstep = jax.jit(step)
    key = rng
    # round 1 (pod-local): clients 0,1 agree; pods differ
    key, s = jax.random.split(key)
    state, _ = jstep(state, bf(s))
    assert _pair_spread(state.x, 0, 1) < 1e-6
    assert _pair_spread(state.x, 0, 2) > 1e-6
    # round 2 (pod-local again): still diverged across pods
    key, s = jax.random.split(key)
    state, _ = jstep(state, bf(s))
    assert _pair_spread(state.x, 0, 2) > 1e-6
    # round 3 (global): everyone agrees
    key, s = jax.random.split(key)
    state, _ = jstep(state, bf(s))
    assert _pair_spread(state.x, 0, 2) < 1e-6
    assert _pair_spread(state.x, 1, 3) < 1e-6


def test_flat_schedule_unchanged(rng):
    """hierarchy_period=0 must reproduce the paper's flat averaging."""
    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed0 = FederatedConfig(num_clients=2, local_steps=2, lr_x=0.05,
                           lr_y=0.05, lr_u=0.05)
    init, step0 = make_fedbio_train_step(model, fed0, n_micro=1, remat=False)
    state = init(rng)
    bf = make_fed_batch_fn(cfg, num_clients=2, per_client=1, seq_len=16)
    b1, b2 = bf(rng), bf(jax.random.fold_in(rng, 1))
    s_a, _ = jax.jit(step0)(state, b1)
    s_a, _ = jax.jit(step0)(s_a, b2)
    fed1 = FederatedConfig(num_clients=2, local_steps=2, lr_x=0.05,
                           lr_y=0.05, lr_u=0.05, hierarchy_period=1,
                           hierarchy_groups=1)
    _, step1 = make_fedbio_train_step(model, fed1, n_micro=1, remat=False)
    s_b, _ = jax.jit(step1)(state, b1)
    s_b, _ = jax.jit(step1)(s_b, b2)
    for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_b)):
        assert jnp.allclose(a, b, atol=1e-6), "schedules diverged"
