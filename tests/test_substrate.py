"""Substrate tests: tree utils, optimizers, checkpoint roundtrip, data
pipeline heterogeneity, dry-run unit pieces (HLO collective parser,
input_specs shapes, applicability matrix)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import INPUT_SHAPES, MeshConfig
from repro.configs import ARCHS
from repro.core.tree_util import (client_mean, tree_axpy, tree_bytes,
                                  tree_sqnorm, tree_vdot, tree_size)


# ---------------------------------------------------------------------------
# tree utils
# ---------------------------------------------------------------------------

def test_tree_ops(rng):
    t1 = {"a": jnp.ones((3, 2)), "b": {"c": jnp.arange(4.0)}}
    t2 = jax.tree.map(lambda x: 2.0 * x, t1)
    assert float(tree_vdot(t1, t2)) == pytest.approx(
        2 * (6 + float(jnp.sum(jnp.arange(4.0) ** 2))))
    s = tree_axpy(-1.0, t1, t1)
    assert float(tree_sqnorm(s)) == 0.0
    assert tree_size(t1) == 10
    assert tree_bytes(t1) == 40


def test_client_mean_broadcasts():
    t = {"w": jnp.stack([jnp.zeros((4,)), jnp.ones((4,)) * 2])}
    out = client_mean(t)
    np.testing.assert_allclose(out["w"], jnp.ones((2, 4)))


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "momentum", "adam"])
def test_optimizers_minimize_quadratic(name):
    from repro import optim
    opt_init, opt_update = getattr(optim, name)()
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda v: 2 * v, params)
        params, state = opt_update(params, grads, state, 0.05)
    assert float(tree_sqnorm(params)) < 1e-3


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, rng):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    tree = {"a": jax.random.normal(rng, (4, 5)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32),
                  "d": jax.random.normal(rng, (3,)).astype(jnp.bfloat16)}}
    save_checkpoint(str(tmp_path / "ck"), tree, {"step": 42})
    loaded = load_checkpoint(str(tmp_path / "ck"), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    from repro.checkpoint.io import checkpoint_metadata
    assert checkpoint_metadata(str(tmp_path / "ck"))["step"] == 42


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_fed_batches_are_heterogeneous(rng):
    from repro.data import make_fed_batch_fn
    cfg = ARCHS["gemma2-2b"].reduced()
    fn = make_fed_batch_fn(cfg, num_clients=4, per_client=8, seq_len=64,
                           hetero_alpha=0.1)
    b = fn(rng)
    toks = b["train"]["tokens"]
    assert toks.shape == (4, 8, 64)
    assert toks.dtype == jnp.int32
    assert int(toks.max()) < cfg.vocab_size
    # client unigram histograms must differ substantially (non-iid)
    hists = [np.bincount(np.asarray(toks[m]).ravel() // 8, minlength=64)
             for m in range(4)]
    dists = [np.abs(hists[0] / hists[0].sum() - h / h.sum()).sum()
             for h in hists[1:]]
    assert max(dists) > 0.3, dists


def test_dirichlet_partition_covers_all():
    from repro.data import dirichlet_partition
    labels = np.repeat(np.arange(5), 100)
    parts = dirichlet_partition(jax.random.PRNGKey(0), labels, 8, alpha=0.3)
    allidx = np.concatenate(parts)
    assert len(allidx) == 500
    assert len(np.unique(allidx)) == 500


# ---------------------------------------------------------------------------
# dry-run units
# ---------------------------------------------------------------------------

def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = bf16[16,1024]{1,0} all-reduce(bf16[16,1024] %p0), replica_groups={}
  %ag.1 = f32[8,256]{1,0} all-gather(f32[8,128] %p1), dimensions={1}
  %a2a = (s32[4,8]{1,0}, s32[4,8]{1,0}) all-to-all(s32[4,8] %x, s32[4,8] %y)
  %cp = u8[100]{0} collective-permute(u8[100] %z), source_target_pairs={{0,1}}
  %ars = bf16[64]{0} all-reduce-start(bf16[64] %w)
  %other = f32[2,2]{1,0} add(f32[2,2] %a, f32[2,2] %b)
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-reduce"] == 16 * 1024 * 2 + 64 * 2
    assert out["bytes"]["all-gather"] == 8 * 256 * 4
    assert out["bytes"]["all-to-all"] == 2 * 4 * 8 * 4
    assert out["bytes"]["collective-permute"] == 100
    assert out["counts"]["all-reduce"] == 2


def test_applicability_matrix():
    from repro.launch.archspec import all_combos
    combos = all_combos()
    assert len(combos) == 40
    skips = [(a, s) for a, s, ok, _ in combos if not ok]
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("llama3-405b", "long_500k") in skips
    assert ("mamba2-130m", "long_500k") not in skips
    assert ("recurrentgemma-9b", "long_500k") not in skips
    assert ("gemma2-2b", "long_500k") not in skips
    assert len(skips) == 8


@pytest.mark.parametrize("shape_name", sorted(INPUT_SHAPES))
def test_input_specs_shapes(shape_name):
    from repro.launch.dryrun import input_specs
    mesh = MeshConfig()
    spec = input_specs("gemma2-2b", shape_name, mesh)
    sh = INPUT_SHAPES[shape_name]
    if sh.kind == "train":
        t = spec["train"]["tokens"]
        assert t.shape[0] * t.shape[1] == sh.global_batch
        assert t.shape[2] == sh.seq_len
    elif sh.kind == "prefill":
        assert spec["tokens"].shape == (sh.global_batch, sh.seq_len)
    else:
        assert spec["tokens"].shape == (sh.global_batch, 1)


def test_dryrun_records_complete():
    """The committed single-pod sweep must cover all 40 combos, no FAILs."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_single.jsonl")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep not yet produced")
    recs = [json.loads(l) for l in open(path)]
    assert len(recs) >= 40
    by = {(r["arch"], r["shape"]): r["status"] for r in recs}
    assert len(by) == 40
    assert all(v in ("OK", "SKIP") for v in by.values())
    assert sum(v == "OK" for v in by.values()) == 32
