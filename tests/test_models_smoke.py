"""Per-architecture smoke tests (deliverable f): each assigned architecture's
REDUCED same-family variant runs one forward + one federated train step on
CPU with correct output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import FederatedConfig
from repro.configs import ARCHS
from repro.federation.trainer import make_fedbioacc_train_step
from repro.models import build_model
from repro.models.registry import param_count


def _batch(cfg, key, B=2, S=32):
    if cfg.family == "audio":
        return {"frames": 0.1 * jax.random.normal(key, (B, S, cfg.frontend_dim)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_patches, cfg.frontend_dim))
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward(arch, rng):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(rng)
    assert param_count(params) > 0
    batch = _batch(cfg, rng)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, parts = model.loss(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch, rng):
    """One FedBiOAcc train step on the reduced config: shapes preserved, no
    NaNs, step counter advances."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=2, local_steps=2, lr_x=0.01, lr_y=0.01,
                          lr_u=0.01)
    init, step = make_fedbioacc_train_step(model, fed, n_micro=1, remat=False)
    state = init(rng)
    b = _batch(cfg, rng, B=2, S=32)
    batch = {"train": jax.tree.map(lambda v: jnp.stack([v, v]), b),
             "val": jax.tree.map(lambda v: jnp.stack([v, v]), b)}
    new, metrics = jax.jit(step)(state, batch)
    assert int(new.step) == 1
    for a, b2 in zip(jax.tree.leaves(state), jax.tree.leaves(new)):
        assert a.shape == b2.shape
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(new.x))
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(new.nu))


def test_loss_label_masking(rng):
    """labels < 0 are excluded from the CE: masking half the positions must
    equal computing CE only over the kept positions."""
    cfg = ARCHS["granite-8b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(rng)
    B, S = 2, 32
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (B, S), 0,
                                cfg.vocab_size)
    masked = labels.at[:, S // 2:].set(-1)
    logits, _ = model.forward(params, {"tokens": toks, "labels": labels})
    lp = jax.nn.log_softmax(logits, -1)
    ll = jnp.take_along_axis(lp, labels[..., None], -1)[..., 0]
    want = float(-jnp.mean(ll[:, : S // 2]))
    got, _ = model.loss(params, {"tokens": toks, "labels": masked})
    assert abs(float(got) - want) < 1e-5
    all_masked, _ = model.loss(params, {"tokens": toks,
                                        "labels": jnp.full((B, S), -1)})
    assert float(all_masked) == 0.0
