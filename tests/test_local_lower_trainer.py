"""Model-scale Algorithm 3 (local lower level, Eq. 5): private per-client
heads are never synchronised; only the body is averaged."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FederatedConfig
from repro.configs import ARCHS
from repro.data import make_fed_batch_fn
from repro.federation.trainer import (make_fedbio_local_train_step,
                                      make_fedbioacc_local_train_step)
from repro.models import build_model


def _spread(tree):
    return max(float(jnp.max(jnp.std(v.astype(jnp.float32), axis=0)))
               for v in jax.tree.leaves(tree))


def test_heads_stay_private_body_syncs(rng):
    cfg = ARCHS["granite-8b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=3, local_steps=2, lr_x=0.05, lr_y=0.05,
                          neumann_q=4, neumann_tau=0.3)
    init, step = make_fedbio_local_train_step(model, fed, n_micro=1,
                                              remat=False)
    state = init(rng)
    assert _spread(state.y) > 0.0          # per-client head inits differ
    batch_fn = make_fed_batch_fn(cfg, num_clients=3, per_client=2, seq_len=32)
    jstep = jax.jit(step)
    key = rng
    for t in range(2):                      # step 2 == I -> body averaged
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    assert _spread(state.x) < 1e-6          # body synced at the round
    assert _spread(state.y) > 1e-4          # heads remain personalised


def test_local_lower_loss_descends(rng):
    cfg = ARCHS["mamba2-130m"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=2, local_steps=2, lr_x=0.02, lr_y=0.3,
                          neumann_q=4, neumann_tau=0.3)
    init, step = make_fedbio_local_train_step(model, fed, n_micro=1,
                                              remat=False)
    state = init(rng)
    batch_fn = make_fed_batch_fn(cfg, num_clients=2, per_client=2, seq_len=32)

    def val(state, m=0):
        p = {"body": jax.tree.map(lambda v: v[m], state.x),
             "head": jax.tree.map(lambda v: v[m], state.y)}
        b = jax.tree.map(lambda v: v[m], batch_fn(jax.random.PRNGKey(7)))
        return float(model.loss(p, b["val"])[0])

    l0 = val(state)
    jstep = jax.jit(step, donate_argnums=(0,))
    key = rng
    for _ in range(20):
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    lT = val(state)
    assert lT < l0 and not np.isnan(lT), (l0, lT)


def test_acc_local_private_heads_and_momenta(rng):
    """Algorithm 4 at model scale: x and ν averaged; y and ω private."""
    cfg = ARCHS["gemma2-2b"].reduced()
    model = build_model(cfg, dtype=jnp.float32)
    fed = FederatedConfig(num_clients=3, local_steps=2, lr_x=0.02, lr_y=0.05,
                          neumann_q=3, neumann_tau=0.3)
    init, step = make_fedbioacc_local_train_step(model, fed, n_micro=1,
                                                 remat=False)
    state = init(rng)
    batch_fn = make_fed_batch_fn(cfg, num_clients=3, per_client=2, seq_len=32)
    jstep = jax.jit(step)
    key = rng
    for _ in range(2):                      # step 2 == I -> comm round
        key, sub = jax.random.split(key)
        state, _ = jstep(state, batch_fn(sub))
    assert _spread(state.x) < 1e-6          # body averaged
    assert _spread(state.nu) < 1e-6         # ν averaged (Alg. 4)
    assert _spread(state.y) > 1e-4          # heads private
    assert not any(bool(jnp.isnan(v).any()) for v in jax.tree.leaves(state.x))
