"""Hyper-gradient machinery: matrix-free HVP/JVP vs explicit matrices, the
Eq. (4) u-fixed-point, and the Neumann-series bias decay (Proposition 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hypergrad as hg
from repro.core.problems import quadratic_problem
from repro.core.tree_util import tree_sub, tree_sqnorm


@pytest.fixture(scope="module")
def prob():
    return quadratic_problem(jax.random.PRNGKey(3), num_clients=4, dx=6, dy=5,
                             noise=0.0)


def _client_batch(prob, m):
    b = prob.sample_batches(jax.random.PRNGKey(0))
    return jax.tree.map(lambda v: v[m], b)


def test_hvp_yy_matches_matrix(prob):
    b = _client_batch(prob, 1)
    x = jnp.ones((6,))
    y = jnp.ones((5,)) * 0.5
    u = jnp.arange(5.0)
    got = hg.hvp_yy(prob.g, x, y, b, u)
    want = b["Ag"] @ u           # ∇²_yy g = Ag for the quadratic
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_jvp_xy_matches_matrix(prob):
    b = _client_batch(prob, 2)
    x = jnp.ones((6,))
    y = jnp.ones((5,))
    u = jnp.arange(5.0)
    got = hg.jvp_xy(prob.g, x, y, b, u)
    want = b["B"] @ u            # ∇²_xy g = B
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_u_step_fixed_point(prob):
    """u* = [∇²_yy g]⁻¹ ∇_y f is a fixed point of the Eq. (4) iteration, and
    the iteration converges to it linearly."""
    b = _client_batch(prob, 0)
    x = jnp.ones((6,))
    y = jnp.ones((5,))
    gy = jax.grad(prob.f, argnums=1)(x, y, b)
    u_star = jnp.linalg.solve(b["Ag"], gy)
    u_fix = hg.u_step(prob.g, prob.f, x, y, u_star, b, b, tau=0.1)
    np.testing.assert_allclose(u_fix, u_star, rtol=1e-4, atol=1e-5)
    u = jnp.zeros((5,))
    errs = []
    # contraction factor is 1 − τ·λ_min(Ag) ≈ 0.895 → 60 iterations land
    # right AT the 1e-3 ratio (0.895⁶⁰ ≈ 1.3e-3); 90 give real margin
    for _ in range(90):
        u = hg.u_step(prob.g, prob.f, x, y, u, b, b, tau=0.1)
        errs.append(float(jnp.linalg.norm(u - u_star)))
    assert errs[-1] < 1e-3 * errs[0]


def test_neumann_bias_decreases_with_q(prob):
    """‖E[Φ_Q] − Φ‖ ≤ κ(1−τμ)^{Q+1} C_f (Prop. 2a): bias decays geometrically."""
    b = _client_batch(prob, 0)
    x = 0.3 * jnp.ones((6,))
    y = 0.2 * jnp.ones((5,))
    gx = jax.grad(prob.f, argnums=0)(x, y, b)
    gy = jax.grad(prob.f, argnums=1)(x, y, b)
    phi_exact = gx - b["B"] @ jnp.linalg.solve(b["Ag"], gy)
    errs = []
    for q in (2, 8, 32):
        phi = hg.neumann_hypergrad(prob.g, prob.f, x, y, b, b, q_terms=q,
                                   tau=0.15)
        errs.append(float(jnp.linalg.norm(phi - phi_exact)))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 1e-2


def test_nu_direction_is_hypergradient_at_solution(prob):
    """With y = y_x and u = u*, ν equals the exact hyper-gradient (Eq. 2/3
    agreement for a single client)."""
    b = _client_batch(prob, 3)
    x = jnp.ones((6,)) * 0.1
    y_x = -jnp.linalg.solve(b["Ag"], b["B"].T @ x + b["c"])
    gy = jax.grad(prob.f, argnums=1)(x, y_x, b)
    u_star = jnp.linalg.solve(b["Ag"], gy)
    nu = hg.nu_direction(prob.g, prob.f, x, y_x, u_star, b, b)
    gx = jax.grad(prob.f, argnums=0)(x, y_x, b)
    phi = gx - b["B"] @ u_star
    np.testing.assert_allclose(nu, phi, rtol=1e-5, atol=1e-6)
