"""Sequence-spec engine: every federated algorithm on the flat substrate.

The five federated algorithms (FedBiO, FedBiOAcc, their local-lower-level
variants, FedAvg) are all "advance K named optimizer sequences, then
communicate some of them".  What differs between them is *declarative*, not
structural:

* which **variable sections** exist (x body, y head, u auxiliary — or a
  single ``params`` section for FedAvg);
* whether each section carries a **STORM momentum** (FedBiOAcc family), a
  heavy-ball momentum (FedAvg) or none (FedBiO family);
* which cfg fields hold the **lr / STORM-constant** for each sequence; and
* the **communication policy** of each sequence.

This module captures that as a small datatype — :class:`Sequence` /
:class:`AlgoSpec` — plus a generic fused step engine (:func:`make_engine`)
that compiles a spec into the flat-substrate loop of ``repro.optim.flat``:

    old-iterate oracle  →  fused Pallas partial step  →
    policy-driven communication  →  correction add  (+ momentum comm)

for the STORM kind, and ``oracle → fused heavy-ball/SGD launch →
communication`` for the non-STORM kind.  The per-section (lr, decay|β)
scalars ride the kernels' per-tile SMEM tables, so a *dual*-sequence spec
(Alg. 4: x/ν averaged, y/ω private) runs on the same triple-sequence kernels
as the full FedBiOAcc spec — sections are just tile runs.

Communication policies (per sequence)
-------------------------------------

``PRIVATE``
    Never communicated.  The paper's local-lower-level regime (Eq. 5):
    per-client lower variables y^(m) and their momenta stay on-client.  On
    the flat substrate the section's tiles are sliced *around* the reduction
    (``flat.client_mean_masked``) — bit-identical pass-through, no traffic.
``HIERARCHICAL``
    Averaged every ``cfg.local_steps`` steps, honoring the beyond-paper
    hierarchical multi-pod schedule: with ``cfg.hierarchy_period = k > 0``
    only every k-th round crosses pod groups (pod-local grouped mean
    otherwise, cross-pod traffic ÷ k).  With ``hierarchy_period = 0`` this
    is exactly the paper's flat averaging.  This is the default policy for
    every communicated sequence — all five algorithms now honor the
    hierarchical schedule (previously only fedbio/fedbioacc did).
``AVERAGED``
    Full client mean every ``cfg.local_steps`` steps, *ignoring* the
    hierarchical schedule — for state that must stay globally consistent
    even during pod-local rounds (e.g. a future server-side control state).

The same policies drive the unfused tree paths through :func:`comm_tree`,
so fused and unfused trajectories see identical communication events.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp
from jax import lax

from repro.core.tree_util import client_mean, client_mean_grouped
from repro.optim import flat

AVERAGED = "averaged"
HIERARCHICAL = "hierarchical"
PRIVATE = "private"
POLICIES = (AVERAGED, HIERARCHICAL, PRIVATE)


class Sequence(NamedTuple):
    """One named optimizer sequence: a variable section and its momentum."""
    section: str            # variable section name ("x", "y", "u", "params")
    momentum: str           # momentum sequence name ("nu", "omega", "q", ...)
    lr: str                 # FederatedConfig field holding the learning rate
    decay: str | None = None  # cfg field of the STORM constant (storm kind)
    comm: str = HIERARCHICAL  # communication policy


class AlgoSpec(NamedTuple):
    """Declarative algorithm description the engine compiles."""
    name: str
    kind: str               # "storm" (two-oracle STORM) | "sgd" (heavy-ball)
    sequences: tuple        # of Sequence, in buffer section order
    beta: float = 0.0       # heavy-ball momentum ("sgd" kind; 0 = plain SGD)
    carry_momentum: bool = False  # keep momentum state even at beta == 0
    #   (fedavg's state always carries mom; fedbio's never does)

    @property
    def sections(self):
        return tuple(s.section for s in self.sequences)

    @property
    def policies(self):
        return tuple(s.comm for s in self.sequences)

    @property
    def has_momentum(self) -> bool:
        return self.kind == "storm" or self.beta != 0.0 or self.carry_momentum

    def without_hierarchy(self) -> "AlgoSpec":
        """HIERARCHICAL → AVERAGED: the paper's flat averaging regardless of
        ``cfg.hierarchy_period`` (the core reference loops use this so
        ``fuse_storm`` stays a pure perf switch there — the hierarchical
        schedule is a model-scale trainer feature)."""
        return self._replace(sequences=tuple(
            q._replace(comm=AVERAGED) if q.comm == HIERARCHICAL else q
            for q in self.sequences))


# The five federated algorithms as specs.  FedAvg's β is a caller knob, not
# a cfg field — makers use ``SPECS["fedavg"]._replace(beta=...)``.
SPECS = {
    "fedbio": AlgoSpec("fedbio", "sgd", (
        Sequence("x", "nu", "lr_x"),
        Sequence("y", "omega", "lr_y"),
        Sequence("u", "q", "lr_u"),
    )),
    "fedbioacc": AlgoSpec("fedbioacc", "storm", (
        Sequence("x", "nu", "lr_x", "c_nu"),
        Sequence("y", "omega", "lr_y", "c_omega"),
        Sequence("u", "q", "lr_u", "c_u"),
    )),
    "fedbio_local": AlgoSpec("fedbio_local", "sgd", (
        Sequence("x", "nu", "lr_x"),
        Sequence("y", "omega", "lr_y", comm=PRIVATE),
    )),
    "fedbioacc_local": AlgoSpec("fedbioacc_local", "storm", (
        Sequence("x", "nu", "lr_x", "c_nu"),
        Sequence("y", "omega", "lr_y", "c_omega", comm=PRIVATE),
    )),
    "fedavg": AlgoSpec("fedavg", "sgd", (
        Sequence("params", "mom", "lr_x"),
    ), beta=0.9, carry_momentum=True),
}


def alpha_schedule(cfg, t):
    """The paper's α_t = δ/(u0 + t)^{1/3} STORM schedule."""
    return cfg.alpha_delta / (cfg.alpha_u0 + t.astype(jnp.float32)) ** (1.0 / 3.0)


# ---------------------------------------------------------------------------
# Policy-driven communication
# ---------------------------------------------------------------------------

def _round_preds(cfg, step):
    is_comm = (step + 1) % cfg.local_steps == 0
    round_idx = (step + 1) // cfg.local_steps
    is_global = round_idx % max(cfg.hierarchy_period, 1) == 0
    return is_comm, is_global


def comm_tree(cfg, step, tree, policy: str):
    """Apply one sequence's communication policy to a pytree with a leading
    client axis (the unfused train-step paths)."""
    assert policy in POLICIES, policy
    if policy == PRIVATE:
        return tree
    is_comm, is_global = _round_preds(cfg, step)
    if policy == AVERAGED or cfg.hierarchy_period <= 0:
        return lax.cond(is_comm, client_mean, lambda t: t, tree)

    def do_comm(t):
        return lax.cond(is_global, client_mean,
                        lambda tt: client_mean_grouped(tt, cfg.hierarchy_groups),
                        t)

    return lax.cond(is_comm, do_comm, lambda t: t, tree)


def comm_buffers(spec: flat.FlatSpec, cfg, step, bufs, policies):
    """Apply per-section policies to flat [M, N] buffers — one masked
    (sliced) reduction per communicated section run, private sections
    bit-identical (``flat.client_mean_masked``)."""
    assert all(p in POLICIES for p in policies), policies
    modes_comm = tuple("mean" if p != PRIVATE else "none" for p in policies)
    if all(m == "none" for m in modes_comm):
        return bufs
    is_comm, is_global = _round_preds(cfg, step)
    groups = cfg.hierarchy_groups
    if cfg.hierarchy_period <= 0 or HIERARCHICAL not in policies:
        return lax.cond(
            is_comm,
            lambda b: flat.client_mean_masked(spec, b, modes_comm),
            lambda b: b, bufs)
    # pod-local rounds: HIERARCHICAL sections take the grouped mean while
    # AVERAGED sections still take the full mean
    modes_local = tuple(
        "group" if p == HIERARCHICAL else ("mean" if p == AVERAGED else "none")
        for p in policies)

    def do_comm(b):
        return lax.cond(
            is_global,
            lambda bb: flat.client_mean_masked(spec, bb, modes_comm),
            lambda bb: flat.client_mean_masked(spec, bb, modes_local,
                                               num_groups=groups),
            b)

    return lax.cond(is_comm, do_comm, lambda b: b, bufs)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class FlatState(NamedTuple):
    """Any algorithm's train state on the flat substrate.

    ``vars``/``mom`` are tuples of per-dtype [M, N] buffers holding the
    variable (resp. momentum) sections, tile-padded per ``repro.optim.flat``
    (``mom`` is the empty tuple for momentum-less specs).
    """
    vars: Any
    mom: Any
    step: jnp.ndarray


class Engine(NamedTuple):
    """A compiled sequence spec.  All members close over (cfg, aspec, spec).

    * ``init_state(var_trees, mom_trees=None, step=None)`` — flatten section
      trees (each [M, ...]) into a :class:`FlatState`; momenta default to
      zeros in f32 buffers (``mom_trees`` is keyed by momentum name).
    * ``step(state, batch) -> state`` — one fused local step including
      policy-driven communication (jit/scan it; donate the buffers).
    * ``views(state) -> (var_dict, mom_dict | None)`` — pytree views keyed
      by section (resp. momentum) names, for eval/checkpoint.
    """
    aspec: AlgoSpec
    spec: flat.FlatSpec
    init_state: Any
    step: Any
    views: Any


def make_engine(cfg, aspec: AlgoSpec, templates: dict, oracle, *,
                block: int | None = None) -> Engine:
    """Compile ``aspec`` into the fused flat-substrate step.

    ``templates``: section name → leaf template tree (arrays or
    ShapeDtypeStructs, WITHOUT the client axis) — the buffer layout.

    ``oracle(views, batch) -> {section: grad tree}``: the already-vmapped
    oracle; ``views`` is a dict of section → [M, ...] pytree.  For the storm
    kind the returned trees are the momentum *targets* of each sequence
    (e.g. μ for x/ν), evaluated twice per step (old/new iterate) with the
    same batch — the STORM correction.  For the sgd kind it is called once.
    """
    sections = aspec.sections
    spec = flat.make_spec({s: templates[s] for s in sections},
                          sections=sections,
                          block=block if block else flat.BLOCK)
    policies = aspec.policies
    has_mom = aspec.has_momentum

    def _flatten_grads(gdict):
        return flat.flatten_tree(spec, {s: gdict[s] for s in sections},
                                 batch_dims=1, dtype=jnp.float32)

    def init_state(var_trees, mom_trees=None, step=None):
        vars_b = flat.flatten_tree(spec, {s: var_trees[s] for s in sections},
                                   batch_dims=1)
        if not has_mom:
            mom_b = ()
        elif mom_trees is None:
            # momenta live in f32 buffers regardless of the variable dtype —
            # the unfused arithmetic promotes them the same way, and the
            # STORM correction g_new − g_old is a small difference bf16
            # would largely destroy
            mom_b = tuple(jnp.zeros(b.shape, jnp.float32) for b in vars_b)
        else:
            mom_b = flat.flatten_tree(
                spec, {q.section: mom_trees[q.momentum]
                       for q in aspec.sequences},
                batch_dims=1, dtype=jnp.float32)
        return FlatState(vars_b, mom_b,
                         jnp.zeros((), jnp.int32) if step is None else step)

    def _storm_step(state: FlatState, batch) -> FlatState:
        t = state.step
        a = alpha_schedule(cfg, t)
        lrs = tuple(getattr(cfg, q.lr) * a for q in aspec.sequences)
        decays = tuple(1.0 - getattr(cfg, q.decay) * a * a
                       for q in aspec.sequences)
        # 1) old-iterate oracle on transient pytree views (reads only the
        #    entering iterate — lets the variable step and the partial
        #    momentum share a single fused launch)
        g_old = _flatten_grads(oracle(flat.unflatten_tree(spec, state.vars),
                                      batch))
        # 2+3) partial momentum + variable step: ONE launch per dtype
        vars_b, mom_b = flat.storm_partial_step(spec, state.vars, state.mom,
                                                g_old, lrs, decays)
        vars_b = comm_buffers(spec, cfg, t, vars_b, policies)
        # 4) new-iterate oracle, same batch; STORM correction is one add
        g_new = _flatten_grads(oracle(flat.unflatten_tree(spec, vars_b),
                                      batch))
        mom_b = flat.buffers_add(mom_b, g_new)
        mom_b = comm_buffers(spec, cfg, t, mom_b, policies)
        return FlatState(vars_b, mom_b, t + 1)

    def _sgd_step(state: FlatState, batch) -> FlatState:
        t = state.step
        lrs = tuple(getattr(cfg, q.lr) for q in aspec.sequences)
        g = _flatten_grads(oracle(flat.unflatten_tree(spec, state.vars),
                                  batch))
        if has_mom:
            betas = (aspec.beta,) * len(aspec.sequences)
            vars_b, mom_b = flat.momentum_sgd_step(spec, state.vars,
                                                   state.mom, g, lrs, betas)
            mom_b = comm_buffers(spec, cfg, t, mom_b, policies)
        else:
            # momentum-less: the plain-SGD launch (no dead momentum stream)
            vars_b, mom_b = flat.sgd_step(spec, state.vars, g, lrs), ()
        vars_b = comm_buffers(spec, cfg, t, vars_b, policies)
        return FlatState(vars_b, mom_b, t + 1)

    step = _storm_step if aspec.kind == "storm" else _sgd_step

    def views(state: FlatState):
        vt = flat.unflatten_tree(spec, state.vars)
        if not state.mom:
            return vt, None
        mt = flat.unflatten_tree(spec, state.mom)
        return vt, {q.momentum: mt[q.section] for q in aspec.sequences}

    return Engine(aspec, spec, init_state, step, views)
