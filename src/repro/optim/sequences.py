"""Sequence-spec engine: every federated algorithm on the flat substrate.

The five federated algorithms (FedBiO, FedBiOAcc, their local-lower-level
variants, FedAvg) are all "advance K named optimizer sequences, then
communicate some of them".  What differs between them is *declarative*, not
structural:

* which **variable sections** exist (x body, y head, u auxiliary — or a
  single ``params`` section for FedAvg);
* whether each section carries a **STORM momentum** (FedBiOAcc family), a
  heavy-ball momentum (FedAvg) or none (FedBiO family);
* which cfg fields hold the **lr / STORM-constant** for each sequence; and
* the **communication policy** of each sequence.

This module captures that as a small datatype — :class:`Sequence` /
:class:`AlgoSpec` — plus a generic fused step engine (:func:`make_engine`)
that compiles a spec into the flat-substrate loop of ``repro.optim.flat``:

    old-iterate oracle  →  fused Pallas partial step  →
    policy-driven communication  →  correction add  (+ momentum comm)

for the STORM kind, and ``oracle → fused heavy-ball/SGD launch →
communication`` for the non-STORM kind.  The per-section (lr, decay|β)
scalars ride the kernels' per-tile SMEM tables, so a *dual*-sequence spec
(Alg. 4: x/ν averaged, y/ω private) runs on the same triple-sequence kernels
as the full FedBiOAcc spec — sections are just tile runs.

Communication policies (per sequence)
-------------------------------------

``PRIVATE``
    Never communicated.  The paper's local-lower-level regime (Eq. 5):
    per-client lower variables y^(m) and their momenta stay on-client.  On
    the flat substrate the section's tiles are sliced *around* the reduction
    (``flat.client_mean_masked``) — bit-identical pass-through, no traffic.
``HIERARCHICAL``
    Averaged every ``cfg.local_steps`` steps, honoring the beyond-paper
    hierarchical multi-pod schedule: with ``cfg.hierarchy_period = k > 0``
    only every k-th round crosses pod groups (pod-local grouped mean
    otherwise, cross-pod traffic ÷ k).  With ``hierarchy_period = 0`` this
    is exactly the paper's flat averaging.  This is the default policy for
    every communicated sequence — all five algorithms now honor the
    hierarchical schedule (previously only fedbio/fedbioacc did).
``AVERAGED``
    Full client mean every ``cfg.local_steps`` steps, *ignoring* the
    hierarchical schedule — for state that must stay globally consistent
    even during pod-local rounds (e.g. a future server-side control state).

The same policies drive the unfused tree paths through :func:`comm_tree`,
so fused and unfused trajectories see identical communication events.

Participation & staleness (``repro.federation.participation``)
--------------------------------------------------------------

:func:`make_engine` takes ``participation=``: a compiled
:class:`~repro.federation.participation.Participation` whose per-round client
mask [M] gates the whole step — non-participants' oracle contributions are
zeroed, their buffers are frozen bit-exact inside the fused launches
(masked lr = 0, decay/β pinned to 1), and the reductions average
*participants only* (``flat.client_mean_masked(..., weights=)``) while
non-participant rows pass through bit-identical.  Per-client staleness
counters (rounds missed since last participation) ride
:class:`FlatState` ``.stale`` and advance at every communication step.

Two per-sequence async knobs decouple the communication cadence:

* ``Sequence.comm_every = k`` — the sequence enters a reduction only every
  k-th communication round (its correction term simply ages in between);
* ``Sequence.staleness = α`` — a returning client's contribution to THIS
  sequence's reduction is discounted by α^staleness (α = 1: no discount;
  ``None`` inherits ``ParticipationSpec.stale_discount``), so stale local
  corrections fade instead of polluting the fresh average.

Mesh sharding & comm/compute overlap
------------------------------------

:func:`make_engine` takes ``shard=`` (a :class:`repro.optim.flat.ShardCtx`):
the spec is built with ``shards =`` the mesh "model"-axis size (tile-aligned
shard-major layout), :class:`FlatState` buffers carry ``NamedSharding``s
built from ``repro.sharding.rules.flat_state_specs`` (client axis M over
"data", packed parameter axis N over "model"), and every fused launch and
masked reduction runs under ``shard_map`` — the participant mean lowers to
per-shard partial sums + true ``lax.psum``/``psum_scatter`` over "data"
instead of the single-device broadcast mean.

``overlap=True`` re-schedules the STORM round as *issue the
variable-section reduction → run the new-iterate oracle → consume the
correction add*: the new-iterate oracle reads the LOCAL (pre-reduction)
iterate, so its output g_new feeds only the correction add and the issued
"data"-axis collective has no consumer until the step returns — XLA's async
collectives can then overlap the all-reduce with oracle compute, at
unchanged communication volume.  Documented deviation (mirroring the
existing "old point" deviation): at communication steps the STORM
correction is evaluated at the pre-averaging local iterate.  Off by
default; ``overlap=False`` is bit-identical to the sequential schedule, and
at non-communication steps the two schedules coincide exactly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.tree_util import (client_mean, client_mean_grouped,
                                  client_mean_grouped_weighted,
                                  client_mean_weighted)
from repro.optim import flat

AVERAGED = "averaged"
HIERARCHICAL = "hierarchical"
PRIVATE = "private"
POLICIES = (AVERAGED, HIERARCHICAL, PRIVATE)


class Sequence(NamedTuple):
    """One named optimizer sequence: a variable section and its momentum."""
    section: str            # variable section name ("x", "y", "u", "params")
    momentum: str           # momentum sequence name ("nu", "omega", "q", ...)
    lr: str                 # FederatedConfig field holding the learning rate
    decay: str | None = None  # cfg field of the STORM constant (storm kind)
    comm: str = HIERARCHICAL  # communication policy
    comm_every: int = 1     # reduce only every k-th comm round (async cadence)
    staleness: float | None = None  # α stale-client discount (None → inherit
    #   ParticipationSpec.stale_discount; 1.0 → no discounting)


class AlgoSpec(NamedTuple):
    """Declarative algorithm description the engine compiles."""
    name: str
    kind: str               # "storm" (two-oracle STORM) | "sgd" (heavy-ball)
    sequences: tuple        # of Sequence, in buffer section order
    beta: float = 0.0       # heavy-ball momentum ("sgd" kind; 0 = plain SGD)
    carry_momentum: bool = False  # keep momentum state even at beta == 0
    #   (fedavg's state always carries mom; fedbio's never does)

    @property
    def sections(self):
        return tuple(s.section for s in self.sequences)

    @property
    def policies(self):
        return tuple(s.comm for s in self.sequences)

    @property
    def has_momentum(self) -> bool:
        return self.kind == "storm" or self.beta != 0.0 or self.carry_momentum

    def without_hierarchy(self) -> "AlgoSpec":
        """HIERARCHICAL → AVERAGED: the paper's flat averaging regardless of
        ``cfg.hierarchy_period`` (the core reference loops use this so
        ``fuse_storm`` stays a pure perf switch there — the hierarchical
        schedule is a model-scale trainer feature)."""
        return self._replace(sequences=tuple(
            q._replace(comm=AVERAGED) if q.comm == HIERARCHICAL else q
            for q in self.sequences))


# The five federated algorithms as specs.  FedAvg's β is a caller knob, not
# a cfg field — makers use ``SPECS["fedavg"]._replace(beta=...)``.
SPECS = {
    "fedbio": AlgoSpec("fedbio", "sgd", (
        Sequence("x", "nu", "lr_x"),
        Sequence("y", "omega", "lr_y"),
        Sequence("u", "q", "lr_u"),
    )),
    "fedbioacc": AlgoSpec("fedbioacc", "storm", (
        Sequence("x", "nu", "lr_x", "c_nu"),
        Sequence("y", "omega", "lr_y", "c_omega"),
        Sequence("u", "q", "lr_u", "c_u"),
    )),
    "fedbio_local": AlgoSpec("fedbio_local", "sgd", (
        Sequence("x", "nu", "lr_x"),
        Sequence("y", "omega", "lr_y", comm=PRIVATE),
    )),
    "fedbioacc_local": AlgoSpec("fedbioacc_local", "storm", (
        Sequence("x", "nu", "lr_x", "c_nu"),
        Sequence("y", "omega", "lr_y", "c_omega", comm=PRIVATE),
    )),
    "fedavg": AlgoSpec("fedavg", "sgd", (
        Sequence("params", "mom", "lr_x"),
    ), beta=0.9, carry_momentum=True),
}


def alpha_schedule(cfg, t):
    """The paper's α_t = δ/(u0 + t)^{1/3} STORM schedule."""
    return cfg.alpha_delta / (cfg.alpha_u0 + t.astype(jnp.float32)) ** (1.0 / 3.0)


def with_comm_every(aspec: AlgoSpec, cadences: dict) -> AlgoSpec:
    """Override per-sequence communication cadences by SECTION name (the
    ``Experiment.schedule.comm_every`` knob): ``{"u": 2}`` makes the u
    sequence enter a reduction only every 2nd comm round."""
    unknown = set(cadences) - set(aspec.sections)
    if unknown:
        raise ValueError(f"comm_every names unknown sections "
                         f"{sorted(unknown)} (spec {aspec.name!r} has "
                         f"{aspec.sections})")
    if any(int(k) < 1 for k in cadences.values()):
        raise ValueError(f"comm_every cadences must be >= 1: {cadences}")
    return aspec._replace(sequences=tuple(
        q._replace(comm_every=int(cadences[q.section]))
        if q.section in cadences else q
        for q in aspec.sequences))


# ---------------------------------------------------------------------------
# Policy-driven communication
# ---------------------------------------------------------------------------

def _round_preds(cfg, step):
    is_comm = (step + 1) % cfg.local_steps == 0
    round_idx = (step + 1) // cfg.local_steps
    is_global = round_idx % max(cfg.hierarchy_period, 1) == 0
    return is_comm, is_global


def comm_tree(cfg, step, tree, policy: str, *, weights=None,
              comm_every: int = 1):
    """Apply one sequence's communication policy to a pytree with a leading
    client axis (the unfused train-step paths).

    ``weights``: optional per-client participation weights [M] (zero =
    non-participant — averaged around, passed through bit-identical).
    ``comm_every``: reduce only every k-th communication round (the async
    cadence knob; 1 = every round, the paper's schedule).
    """
    assert policy in POLICIES, policy
    if policy == PRIVATE:
        return tree
    is_comm, is_global = _round_preds(cfg, step)
    if comm_every > 1:
        round_idx = (step + 1) // cfg.local_steps
        is_comm = is_comm & (round_idx % comm_every == 0)
    if weights is None:
        mean = client_mean
        grouped = lambda t: client_mean_grouped(t, cfg.hierarchy_groups)
    else:
        mean = lambda t: client_mean_weighted(t, weights)
        grouped = lambda t: client_mean_grouped_weighted(
            t, cfg.hierarchy_groups, weights)
    if policy == AVERAGED or cfg.hierarchy_period <= 0:
        return lax.cond(is_comm, mean, lambda t: t, tree)

    def do_comm(t):
        return lax.cond(is_global, mean, grouped, t)

    return lax.cond(is_comm, do_comm, lambda t: t, tree)


def comm_buffers(spec: flat.FlatSpec, cfg, step, bufs, policies, *,
                 weights=None, comm_every=None, shard=None,
                 corrupt=None, robust=None, compress=None, ef=()):
    """Apply per-section policies to flat [M, N] buffers — one masked
    (sliced) reduction per communicated section run, private sections
    bit-identical (``flat.client_mean_masked``).

    ``weights``: participation weights — a single [M] array shared by every
    section or a per-section tuple (staleness-discounted sequences).
    ``comm_every``: per-section cadence tuple — sections reduce only every
    k-th comm round; sections sharing a cadence share one guarded reduction.
    ``shard``: a :class:`flat.ShardCtx` — the reductions run under
    ``shard_map`` as true ``psum``/``psum_scatter`` collectives over "data".
    ``corrupt`` / ``robust``: the round's fault transform ((nan, byz, scale)
    masks) and the :class:`flat.RobustCfg` guard policy, forwarded into
    every communicated reduction — faults touch only what is actually sent
    (cadence-skipped and private sections stay clean by construction).
    ``compress`` / ``ef``: a :class:`flat.CompressCfg` and the current
    error-feedback buffers — with ``compress`` set the reductions move
    quantized/top-k sends and the call returns ``(bufs, ef)`` (the pair is
    threaded through every cadence cond); ``compress=None`` keeps the
    original single-value return and a bit-identical trajectory.
    """
    assert all(p in POLICIES for p in policies), policies
    n = len(policies)
    ce = tuple(comm_every) if comm_every is not None else (1,) * n
    assert len(ce) == n and all(c >= 1 for c in ce), ce
    if compress is not None:
        assert corrupt is None and robust is None, (
            "compress does not compose with corrupt/robust — enforced by "
            "make_engine")
    if isinstance(weights, (tuple, list)):
        assert len(weights) == n, (len(weights), n)
        w_of_sec = tuple(weights)
    else:
        w_of_sec = (weights,) * n
    is_comm, is_global = _round_preds(cfg, step)
    round_idx = (step + 1) // cfg.local_steps
    groups = cfg.hierarchy_groups
    if compress is None:
        for c in sorted(set(ce)):
            live = tuple(i for i in range(n)
                         if ce[i] == c and policies[i] != PRIVATE)
            if not live:
                continue
            due = is_comm if c == 1 else is_comm & (round_idx % c == 0)
            modes_comm = tuple("mean" if i in live else "none"
                               for i in range(n))
            w_c = tuple(w_of_sec[i] if i in live else None for i in range(n))
            if cfg.hierarchy_period <= 0 or not any(
                    policies[i] == HIERARCHICAL for i in live):
                bufs = lax.cond(
                    due,
                    lambda b, mc=modes_comm, wc=w_c:
                        flat.client_mean_masked(spec, b, mc, weights=wc,
                                                shard=shard, corrupt=corrupt,
                                                robust=robust),
                    lambda b: b, bufs)
                continue
            assert corrupt is None and robust is None, (
                "corrupt/robust do not compose with the hierarchical grouped "
                "mean (hierarchy_period > 0) — enforced by make_engine")
            # pod-local rounds: HIERARCHICAL sections take the grouped mean
            # while AVERAGED sections still take the full mean
            modes_local = tuple(
                ("group" if policies[i] == HIERARCHICAL else "mean")
                if i in live else "none" for i in range(n))

            def do_comm(b, mc=modes_comm, ml=modes_local, wc=w_c):
                return lax.cond(
                    is_global,
                    lambda bb: flat.client_mean_masked(spec, bb, mc,
                                                       weights=wc,
                                                       shard=shard),
                    lambda bb: flat.client_mean_masked(spec, bb, ml,
                                                       num_groups=groups,
                                                       weights=wc,
                                                       shard=shard),
                    b)

            bufs = lax.cond(due, do_comm, lambda b: b, bufs)
        return bufs
    # compressed variant: the (buffers, error-feedback) pair rides every
    # cadence cond together, so skipped rounds leave EF bit-identical too
    carry = (tuple(bufs), tuple(ef))
    for c in sorted(set(ce)):
        live = tuple(i for i in range(n)
                     if ce[i] == c and policies[i] != PRIVATE)
        if not live:
            continue
        due = is_comm if c == 1 else is_comm & (round_idx % c == 0)
        modes_comm = tuple("mean" if i in live else "none" for i in range(n))
        w_c = tuple(w_of_sec[i] if i in live else None for i in range(n))
        if cfg.hierarchy_period <= 0 or not any(
                policies[i] == HIERARCHICAL for i in live):
            carry = lax.cond(
                due,
                lambda be, mc=modes_comm, wc=w_c:
                    flat.client_mean_masked(spec, be[0], mc, weights=wc,
                                            shard=shard, compress=compress,
                                            ef=be[1]),
                lambda be: be, carry)
            continue
        modes_local = tuple(
            ("group" if policies[i] == HIERARCHICAL else "mean")
            if i in live else "none" for i in range(n))

        def do_comm_c(be, mc=modes_comm, ml=modes_local, wc=w_c):
            return lax.cond(
                is_global,
                lambda bb: flat.client_mean_masked(spec, bb[0], mc,
                                                   weights=wc, shard=shard,
                                                   compress=compress,
                                                   ef=bb[1]),
                lambda bb: flat.client_mean_masked(spec, bb[0], ml,
                                                   num_groups=groups,
                                                   weights=wc, shard=shard,
                                                   compress=compress,
                                                   ef=bb[1]),
                be)

        carry = lax.cond(due, do_comm_c, lambda be: be, carry)
    return carry


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class FlatState(NamedTuple):
    """Any algorithm's train state on the flat substrate.

    ``vars``/``mom`` are tuples of per-dtype [M, N] buffers holding the
    variable (resp. momentum) sections, tile-padded per ``repro.optim.flat``
    (``mom`` is the empty tuple for momentum-less specs).  ``stale`` carries
    the per-client staleness counters [M] int32 (rounds missed since last
    participation) when a participation engine is attached — the empty tuple
    otherwise (full participation).  ``retry`` carries the rollback retry
    counter (scalar int32) when a fault engine is attached — folded into the
    fault draws so a rolled-back round re-samples its failures — and the
    empty tuple otherwise (zero pytree leaves: pre-fault checkpoints and jit
    caches keep their exact structure).  ``ef`` carries the per-client
    error-feedback buffers of top-k compressed communication — a
    ``(vars_ef, mom_ef)`` pair of f32 buffer tuples shaped exactly like
    ``vars``/``mom`` (so sharding rules, masking and checkpointing inherit
    it, and compressed runs stay resume-bit-exact) — and the empty tuple
    whenever compression is off or feedback-free (same zero-leaf
    convention as ``stale``/``retry``).  ``deadline`` carries the adaptive
    round-deadline scalar (f32) of the straggler engine when one is
    attached — updated at round boundaries by the EMA controller, so
    checkpoints carry it and resume is bit-exact — and the empty tuple
    otherwise (same zero-leaf convention).
    """
    vars: Any
    mom: Any
    step: jnp.ndarray
    stale: Any = ()
    retry: Any = ()
    ef: Any = ()
    deadline: Any = ()


class Engine(NamedTuple):
    """A compiled sequence spec.  All members close over (cfg, aspec, spec).

    * ``init_state(var_trees, mom_trees=None, step=None, stale=None)`` —
      flatten section trees (each [M, ...]) into a :class:`FlatState`;
      momenta default to zeros in f32 buffers (``mom_trees`` is keyed by
      momentum name); staleness counters default to zeros [M] int32 when a
      participation engine is attached (the empty tuple otherwise).
    * ``step(state, batch) -> state`` — one fused local step including
      policy-driven communication (jit/scan it; donate the buffers).
    * ``views(state) -> (var_dict, mom_dict | None)`` — pytree views keyed
      by section (resp. momentum) names, for eval/checkpoint.
    * ``shardings(state) -> NamedSharding pytree | None`` — the mesh
      placement of a :class:`FlatState` (None without a shard context);
      ``init_state`` already applies it to concrete states via
      ``jax.device_put``.
    * ``comm_fn(state) -> state`` — the communication-only subprogram of
      ``step``: the round context plus the policy reductions (vars, then
      momentum), with no oracle and no fused update launch.  Never called
      by training — it is the static audit surface ``repro.analysis``
      lowers alone, so its compiled HLO contains exactly the wire
      collectives and nothing else.
    """
    aspec: AlgoSpec
    spec: flat.FlatSpec
    init_state: Any
    step: Any
    views: Any
    shardings: Any = None
    comm_fn: Any = None


def effective_staleness(aspec: AlgoSpec, participation) -> tuple:
    """Per-sequence staleness discount α (``Sequence.staleness`` overriding
    the participation spec's default; all 1.0 without participation)."""
    base = (participation.spec.stale_discount
            if participation is not None else 1.0)
    return tuple(q.staleness if q.staleness is not None else base
                 for q in aspec.sequences)


def staleness_weights(w, stale, stale_alpha: tuple) -> tuple:
    """Per-sequence α^staleness-aged participation weights — ONE aged array
    per distinct α, shared by the sequences using it (so the flat path's
    ``client_mean_masked`` still merges their tile runs).  The single
    source of the discount arithmetic: the fused engine and the unfused
    tree paths both call this, keeping their trajectories bit-consistent."""
    s = stale.astype(jnp.float32)
    by_alpha = {a: (w if a == 1.0 else w * jnp.float32(a) ** s)
                for a in set(stale_alpha)}
    return tuple(by_alpha[a] for a in stale_alpha)


def advance_stale(cfg, step, mask, stale):
    """Advance per-client staleness counters at communication steps:
    participants reset to 0, absentees age by 1 (shared by the fused
    engine and the unfused tree paths)."""
    is_comm = (step + 1) % cfg.local_steps == 0
    bumped = jnp.where(mask > 0, 0, stale + 1)
    return jnp.where(is_comm, bumped, stale)


def make_engine(cfg, aspec: AlgoSpec, templates: dict, oracle, *,
                block: int | None = None, participation=None,
                shard: flat.ShardCtx | None = None,
                overlap: bool = False, faults=None,
                robustness=None, compression=None,
                telemetry=None, stragglers=None) -> Engine:
    """Compile ``aspec`` into the fused flat-substrate step.

    ``templates``: section name → leaf template tree (arrays or
    ShapeDtypeStructs, WITHOUT the client axis) — the buffer layout.

    ``oracle(views, batch) -> {section: grad tree}``: the already-vmapped
    oracle; ``views`` is a dict of section → [M, ...] pytree.  For the storm
    kind the returned trees are the momentum *targets* of each sequence
    (e.g. μ for x/ν), evaluated twice per step (old/new iterate) with the
    same batch — the STORM correction.  For the sgd kind it is called once.

    ``participation``: a compiled
    :class:`~repro.federation.participation.Participation` — every step
    derives the round's client mask from the step counter (resumable), gates
    the fused launches with it, zeroes non-participants' oracle
    contributions, weights the reductions by participants only, and advances
    the staleness counters on :class:`FlatState` ``.stale``.

    ``shard`` / ``overlap``: mesh partitioning of the substrate and the
    comm/compute overlap schedule — see the module docstring.

    ``faults``: a compiled :class:`~repro.federation.faults.Faults` — every
    step derives the round's (keep, nan, byz) client masks from the step
    counter and the :class:`FlatState` retry slot (resume- and retry-exact):
    dropped clients compose into the participation mask/weights (frozen
    bit-exact, averaged around), and the corruption transform applies to
    what surviving clients *send* into each reduction.  ``robustness``: any
    object carrying the :class:`flat.RobustCfg` fields (e.g. a
    ``federation.faults.RobustnessSpec``) — health-screens senders and
    selects the robust aggregator inside those reductions.  Both are duck-
    typed so this module stays import-free of the federation layer; both
    ``None`` (the default) leaves every trajectory bit-identical.

    ``compression``: any object carrying the :class:`flat.CompressCfg`
    fields (e.g. a ``federation.compression.CompressionSpec``) — the named
    sections' reductions move quantized and/or top-k-sparsified sends
    (``sections=None`` compresses every communicated section).  Top-k
    carries per-client error-feedback buffers on ``FlatState.ef``.
    Duck-typed like ``faults``/``robustness``; ``None`` (the default)
    leaves every trajectory bit-identical.  Rejected combinations (clear
    errors, no silent fallback): compression with faults/robustness, and
    top-k with the hierarchical grouped mean (``cfg.hierarchy_period > 0``)
    — plain quantization DOES compose with the grouped mean.

    ``telemetry``: any object carrying a ``.metrics`` field (e.g. a
    ``telemetry.TelemetrySpec``) — the step additionally returns an
    in-band metrics dict computed from the already-materialized flat
    buffers (per-section update/momentum norms, client-drift dispersion,
    compression error, health verdicts — see ``repro.telemetry.spec``).
    ``None`` (the default) keeps ``step(state, batch) -> state`` with the
    LITERAL pre-telemetry code path: trajectories, jit cache keys and
    state structures are bit-identical to a telemetry-free build.

    ``stragglers``: a compiled
    :class:`~repro.federation.stragglers.Stragglers` — every round derives
    its client compute times and the elastic-round decision (arrival mask,
    effective deadline after quorum extensions, adaptive next deadline)
    from the step counter and the deadline scalar riding
    :class:`FlatState` ``.deadline``.  The reduction averages arrivals
    only (the arrival mask multiplies into the participation weights,
    exactly how fault dropout composes); the late-arrival policy picks the
    launch mask (``"carry"`` lets stragglers keep computing locally) and
    the staleness aging (``"cancel"`` skips it).  Composes with
    participation, faults/robustness and compression.  ``None`` (the
    default) is the literal pre-straggler path — zero deadline leaf,
    bit-identical trajectories.
    """
    rcfg = None
    if robustness is not None:
        rcfg = flat.RobustCfg(
            aggregator=robustness.aggregator, screen=robustness.screen,
            z_thresh=robustness.z_thresh, clip_factor=robustness.clip_factor,
            trim_frac=robustness.trim_frac)
        if rcfg.aggregator not in ("mean", "clip", "trim"):
            raise ValueError(f"unknown robust aggregator "
                             f"{rcfg.aggregator!r} (mean|clip|trim)")
    if (faults is not None or rcfg is not None) and cfg.hierarchy_period > 0:
        raise ValueError(
            "faults=/robustness= do not compose with the hierarchical "
            "grouped mean (cfg.hierarchy_period > 0) — the robust "
            "reductions and the fault model are global; set "
            "hierarchy_period=0")
    if stragglers is not None and cfg.hierarchy_period > 0:
        raise ValueError(
            "stragglers= does not compose with the hierarchical grouped "
            "mean (cfg.hierarchy_period > 0) — the deadline/quorum "
            "decision is global; set hierarchy_period=0")
    ccfg = None
    if compression is not None:
        if faults is not None or rcfg is not None:
            raise ValueError(
                "compression= does not compose with faults=/robustness= — "
                "the guarded reductions consume raw client rows; drop one "
                "layer")
        quant = compression.quant
        if quant not in (None, "bf16", "int8"):
            raise ValueError(f"unknown compression quant {quant!r} "
                             f"(None | 'bf16' | 'int8')")
        frac = float(compression.topk_frac)
        if not 0.0 <= frac < 1.0:
            raise ValueError(
                f"compression topk_frac={frac} must be in [0, 1)")
        if quant is None and frac == 0.0:
            raise ValueError(
                "compression enabled but no compressor selected — set "
                "quant ('bf16' | 'int8') and/or topk_frac > 0")
        comm_secs = tuple(q.section for q in aspec.sequences
                          if q.comm != PRIVATE)
        csecs = (tuple(compression.sections) if compression.sections
                 else comm_secs)
        unknown = set(csecs) - set(aspec.sections)
        if unknown:
            raise ValueError(
                f"compression.sections names unknown sections "
                f"{sorted(unknown)} (spec {aspec.name!r} has "
                f"{aspec.sections})")
        private = [s for s in csecs if s not in comm_secs]
        if private:
            raise ValueError(
                f"compression.sections names private sections {private} — "
                f"private state is never communicated, so it cannot be "
                f"compressed")
        if frac > 0 and cfg.hierarchy_period > 0:
            raise ValueError(
                "top-k compression (topk_frac > 0) does not compose with "
                "the hierarchical grouped mean (cfg.hierarchy_period > 0) "
                "— error feedback against two different means is "
                "ill-defined; use quant-only compression or set "
                "hierarchy_period=0")
        ccfg = flat.CompressCfg(
            quant=quant, topk_frac=frac,
            error_feedback=bool(compression.error_feedback),
            sections=csecs)
    has_ef = ccfg is not None and ccfg.has_ef
    sections = aspec.sections
    spec = flat.make_spec({s: templates[s] for s in sections},
                          sections=sections,
                          block=block if block else flat.BLOCK,
                          shards=shard.model_size if shard else 1)
    policies = aspec.policies
    has_mom = aspec.has_momentum
    part = participation
    strag = stragglers
    # staleness counters exist for absence of either kind: a round missed
    # by the sampler OR a deadline missed by a straggler
    need_stale = part is not None or strag is not None
    late_carry = strag is not None and strag.spec.late_policy == "carry"
    late_cancel = strag is not None and strag.spec.late_policy == "cancel"
    if strag is not None:
        from repro.federation.stragglers import arrival_histogram
    cadence = tuple(q.comm_every for q in aspec.sequences)
    stale_alpha = effective_staleness(aspec, part)
    discounted = any(a != 1.0 for a in stale_alpha)

    tel_groups = ()
    if telemetry is not None:
        from repro.telemetry.spec import resolve_metric_groups
        tel_groups = resolve_metric_groups(
            getattr(telemetry, "metrics", None),
            compressed=ccfg is not None,
            guarded=faults is not None or rcfg is not None,
            sampled=part is not None,
            straggled=strag is not None)
        if "stragglers" in tel_groups and strag is None:
            raise ValueError(
                "telemetry metrics group 'stragglers' needs stragglers= — "
                "there is no deadline or arrival set to report")
        if "compression" in tel_groups and ccfg is None:
            raise ValueError(
                "telemetry metrics group 'compression' needs compression= "
                "— there is no EF residual or quantization error to report")
        if "health" in tel_groups and (part is None and faults is None
                                       and rcfg is None):
            raise ValueError(
                "telemetry metrics group 'health' needs participation "
                "sampling, faults= or robustness= — there is nothing to "
                "screen")

    def _flatten_grads(gdict):
        return flat.flatten_tree(spec, {s: gdict[s] for s in sections},
                                 batch_dims=1, dtype=jnp.float32)

    def _round_ctx(state: FlatState):
        """(mask, per-section comm weights, corrupt transform, staleness
        mask, straggler info) of the round ``state.step`` belongs to — pure
        in the step counter (plus the retry counter for the fault draws and
        the deadline scalar for the arrival set), so resume and
        rollback-retry are bit-exact."""
        if part is None:
            mask, w = None, None
        else:
            mask, w = part.round_weights(state.step // cfg.local_steps)
        s_info, stale_base = None, None
        if strag is not None:
            sampled = (jnp.ones((strag.num_clients,), jnp.float32)
                       if mask is None else mask)
            arrivals, eff, ext, next_dl = strag.round_decision(
                state.step // cfg.local_steps, sampled, state.deadline)
            # launch mask: "carry" keeps stragglers computing locally;
            # "drop"/"cancel" freeze them bit-exact like non-participants
            mask = sampled if late_carry else arrivals
            # the round's mean always averages ARRIVALS only
            w = arrivals if w is None else w * arrivals
            # staleness: "cancel" treats the straggler as served (no
            # aging); "drop"/"carry" age it so a stale_discount < 1
            # re-weights its return by α^staleness
            stale_base = sampled if late_cancel else arrivals
            s_info = (arrivals, eff, ext, next_dl, sampled)
        corrupt = None
        if faults is not None:
            keep, nan, byz = faults.round_masks(
                state.step // cfg.local_steps, state.retry)
            # a dropped client behaves exactly like a non-participant:
            # frozen bit-exact in the launches, averaged around in comm
            mask = keep if mask is None else mask * keep
            w = keep if w is None else w * keep
            if stale_base is not None:
                stale_base = stale_base * keep
            corrupt = (nan, byz, faults.spec.byzantine_scale)
        stale_mask = mask if stale_base is None else stale_base
        if w is not None and discounted:
            w = staleness_weights(w, state.stale, stale_alpha)
        return mask, w, corrupt, stale_mask, s_info

    def _next_stale(state: FlatState, stale_mask):
        if not need_stale:
            return state.stale
        return advance_stale(cfg, state.step, stale_mask, state.stale)

    def _next_deadline(state: FlatState, s_info):
        """The adaptive deadline advances ONCE per round, at the comm step
        — every local step inside a round sees the same scalar, so the
        arrival set is constant within the round."""
        if strag is None:
            return state.deadline
        is_comm = (state.step + 1) % cfg.local_steps == 0
        return jnp.where(is_comm, s_info[3], state.deadline)

    def _tel_metrics(state: FlatState, new: FlatState, mask, corrupt,
                     local_vars, s_info=None) -> dict:
        """In-band metrics of one step, read off the already-materialized
        flat buffers (``tel_groups`` is a static Python value, so with
        telemetry off this is never traced and the step's jaxpr is the
        pre-telemetry one).  ``local_vars`` are the round's LOCAL
        (pre-reduction) iterates — what drift measures."""
        m = {}
        if "norms" in tel_groups:
            diff = tuple(n - o for n, o in zip(new.vars, state.vars))
            m.update(flat.section_norms(spec, diff, mask=mask,
                                        prefix="upd_norm"))
            if new.mom:
                m.update(flat.section_norms(spec, new.mom, mask=mask,
                                            prefix="mom_norm"))
        if "drift" in tel_groups:
            m.update(flat.section_drift(spec, local_vars, mask=mask))
        if "compression" in tel_groups:
            if new.ef:
                efv, _ = new.ef
                m.update(flat.section_norms(spec, efv, prefix="ef_norm"))
            if ccfg.quant is not None:
                m["quant_err"] = flat.quant_roundtrip_err(
                    local_vars, spec.groups[0].block, ccfg.quant)
        if "health" in tel_groups:
            if mask is not None:
                m["participants"] = jnp.sum((mask > 0).astype(jnp.float32))
            if need_stale:
                m["stale_hist"] = jnp.sum(
                    jax.nn.one_hot(jnp.clip(new.stale, 0, 7), 8), axis=0)
            if corrupt is not None:
                nan, byz, _ = corrupt
                m["injected_nan"] = jnp.sum(
                    jnp.asarray(nan, jnp.float32))
                m["injected_byz"] = jnp.sum(
                    jnp.asarray(byz, jnp.float32))
            if rcfg is not None:
                m["screened"] = flat.health_screen(spec, local_vars, mask,
                                                   corrupt, rcfg)
        if "stragglers" in tel_groups and s_info is not None:
            arrivals, eff, ext, next_dl, sampled = s_info
            rt = strag.round_times(state.step // cfg.local_steps)
            m["deadline"] = eff
            m["deadline_next"] = next_dl
            m["arrivals"] = jnp.sum((arrivals > 0).astype(jnp.float32))
            m["quorum"] = strag.quorum_count(sampled).astype(jnp.float32)
            m["extensions"] = ext.astype(jnp.float32)
            m["arrival_hist"] = arrival_histogram(rt, eff, sampled)
        return m

    def state_shardings(state: FlatState):
        """NamedSharding pytree for ``state`` (None without a mesh): [M, N]
        buffers client-sharded over "data" and packed-axis-sharded over
        "model", stale counters over "data" (``rules.flat_state_specs``)."""
        if shard is None:
            return None
        from repro.sharding.rules import flat_state_specs
        pspecs = flat_state_specs(state, data_axis=shard.data_axis,
                                  model_axis=shard.model_axis)
        return jax.tree.map(
            lambda p: jax.sharding.NamedSharding(shard.mesh, p), pspecs,
            is_leaf=lambda p: isinstance(p, jax.sharding.PartitionSpec))

    def _placed(state: FlatState) -> FlatState:
        if shard is None or any(isinstance(l, jax.core.Tracer)
                                for l in jax.tree.leaves(state)):
            return state        # abstract init (eval_shape) — caller places
        return jax.device_put(state, state_shardings(state))

    def init_state(var_trees, mom_trees=None, step=None, stale=None,
                   retry=None, ef=None, deadline=None):
        vars_b = flat.flatten_tree(spec, {s: var_trees[s] for s in sections},
                                   batch_dims=1)
        if not has_mom:
            mom_b = ()
        elif mom_trees is None:
            # momenta live in f32 buffers regardless of the variable dtype —
            # the unfused arithmetic promotes them the same way, and the
            # STORM correction g_new − g_old is a small difference bf16
            # would largely destroy
            mom_b = tuple(jnp.zeros(b.shape, jnp.float32) for b in vars_b)
        else:
            mom_b = flat.flatten_tree(
                spec, {q.section: mom_trees[q.momentum]
                       for q in aspec.sequences},
                batch_dims=1, dtype=jnp.float32)
        if not need_stale:
            stale_b = ()
        elif stale is None:
            n_stale = part.num_clients if part is not None else strag.num_clients
            stale_b = jnp.zeros((n_stale,), jnp.int32)
        else:
            stale_b = stale
        if faults is None:
            retry_b = ()
        elif retry is None:
            retry_b = jnp.zeros((), jnp.int32)
        else:
            retry_b = jnp.asarray(retry, jnp.int32)
        if not has_ef:
            ef_b = ()
        elif ef is None:
            # one f32 zero buffer per communicated buffer — the dropped
            # top-k mass accumulates here between rounds
            ef_b = (tuple(jnp.zeros(b.shape, jnp.float32) for b in vars_b),
                    tuple(jnp.zeros(b.shape, jnp.float32) for b in mom_b))
        else:
            ef_b = ef
        if strag is None:
            dl_b = ()
        elif deadline is None:
            dl_b = jnp.asarray(strag.spec.deadline, jnp.float32)
        else:
            dl_b = jnp.asarray(deadline, jnp.float32)
        return _placed(FlatState(
            vars_b, mom_b,
            jnp.zeros((), jnp.int32) if step is None else step,
            stale_b, retry_b, ef_b, dl_b))

    def _storm_step(state: FlatState, batch) -> FlatState:
        t = state.step
        mask, wts, corrupt, stale_mask, s_info = _round_ctx(state)
        a = alpha_schedule(cfg, t)
        lrs = tuple(getattr(cfg, q.lr) * a for q in aspec.sequences)
        decays = tuple(1.0 - getattr(cfg, q.decay) * a * a
                       for q in aspec.sequences)
        # 1) old-iterate oracle on transient pytree views (reads only the
        #    entering iterate — lets the variable step and the partial
        #    momentum share a single fused launch); non-participants'
        #    contributions are zeroed (their oracle is "skipped")
        g_old = flat.mask_buffers(
            _flatten_grads(oracle(flat.unflatten_tree(spec, state.vars),
                                  batch)), mask)
        # 2+3) partial momentum + variable step: ONE gated launch per dtype
        vars_b, mom_b = flat.storm_partial_step(spec, state.vars, state.mom,
                                                g_old, lrs, decays, mask=mask,
                                                shard=shard)
        efv, efm = state.ef if state.ef else ((), ())
        # issue the variable-section reduction ...
        if ccfg is None:
            vars_c = comm_buffers(spec, cfg, t, vars_b, policies,
                                  weights=wts, comm_every=cadence,
                                  shard=shard, corrupt=corrupt, robust=rcfg)
        else:
            vars_c, efv = comm_buffers(spec, cfg, t, vars_b, policies,
                                       weights=wts, comm_every=cadence,
                                       shard=shard, compress=ccfg, ef=efv)
        # 4) ... run the new-iterate oracle, same batch; the STORM correction
        #    is one add.  overlap=True evaluates the oracle at the LOCAL
        #    (pre-reduction) iterate: g_new then feeds only the correction
        #    add and the issued "data"-axis collective has no consumer until
        #    the step returns, so XLA can overlap it with oracle compute
        #    (documented deviation at comm rounds; identical elsewhere).
        g_new = flat.mask_buffers(
            _flatten_grads(oracle(flat.unflatten_tree(
                spec, vars_b if overlap else vars_c), batch)), mask)
        mom_b = flat.buffers_add(mom_b, g_new)
        if ccfg is None:
            mom_b = comm_buffers(spec, cfg, t, mom_b, policies,
                                 weights=wts, comm_every=cadence, shard=shard,
                                 corrupt=corrupt, robust=rcfg)
        else:
            mom_b, efm = comm_buffers(spec, cfg, t, mom_b, policies,
                                      weights=wts, comm_every=cadence,
                                      shard=shard, compress=ccfg, ef=efm)
        new = state._replace(vars=vars_c, mom=mom_b, step=t + 1,
                             stale=_next_stale(state, stale_mask),
                             ef=(efv, efm) if state.ef else (),
                             deadline=_next_deadline(state, s_info))
        if not tel_groups:
            return new
        return new, _tel_metrics(state, new, mask, corrupt, vars_b, s_info)

    def _sgd_step(state: FlatState, batch) -> FlatState:
        t = state.step
        mask, wts, corrupt, stale_mask, s_info = _round_ctx(state)
        lrs = tuple(getattr(cfg, q.lr) for q in aspec.sequences)
        g = flat.mask_buffers(
            _flatten_grads(oracle(flat.unflatten_tree(spec, state.vars),
                                  batch)), mask)
        efv, efm = state.ef if state.ef else ((), ())
        if has_mom:
            betas = (aspec.beta,) * len(aspec.sequences)
            vars_b, mom_b = flat.momentum_sgd_step(spec, state.vars,
                                                   state.mom, g, lrs, betas,
                                                   mask=mask, shard=shard)
            if ccfg is None:
                mom_b = comm_buffers(spec, cfg, t, mom_b, policies,
                                     weights=wts, comm_every=cadence,
                                     shard=shard, corrupt=corrupt,
                                     robust=rcfg)
            else:
                mom_b, efm = comm_buffers(spec, cfg, t, mom_b, policies,
                                          weights=wts, comm_every=cadence,
                                          shard=shard, compress=ccfg, ef=efm)
        else:
            # momentum-less: the plain-SGD launch (no dead momentum stream)
            vars_b = flat.sgd_step(spec, state.vars, g, lrs, mask=mask,
                                   shard=shard)
            mom_b = ()
        vars_local = vars_b         # pre-reduction local iterates (drift)
        if ccfg is None:
            vars_b = comm_buffers(spec, cfg, t, vars_b, policies,
                                  weights=wts, comm_every=cadence,
                                  shard=shard, corrupt=corrupt, robust=rcfg)
        else:
            vars_b, efv = comm_buffers(spec, cfg, t, vars_b, policies,
                                       weights=wts, comm_every=cadence,
                                       shard=shard, compress=ccfg, ef=efv)
        new = state._replace(vars=vars_b, mom=mom_b, step=t + 1,
                             stale=_next_stale(state, stale_mask),
                             ef=(efv, efm) if state.ef else (),
                             deadline=_next_deadline(state, s_info))
        if not tel_groups:
            return new
        return new, _tel_metrics(state, new, mask, corrupt, vars_local,
                                 s_info)

    def comm_fn(state: FlatState) -> FlatState:
        """Communication-only subprogram of one step — the exact
        ``comm_buffers`` calls of ``_storm_step``/``_sgd_step`` (vars
        reduction, then the momentum reduction when the spec carries one)
        driven by the same ``_round_ctx``, with no oracle and no fused
        update launch.  Training never calls this; ``repro.analysis``
        compiles it alone so the lowered HLO holds exactly the wire
        collectives of one step."""
        t = state.step
        _, wts, corrupt, _, _ = _round_ctx(state)
        efv, efm = state.ef if state.ef else ((), ())
        if ccfg is None:
            vars_c = comm_buffers(spec, cfg, t, state.vars, policies,
                                  weights=wts, comm_every=cadence,
                                  shard=shard, corrupt=corrupt, robust=rcfg)
        else:
            vars_c, efv = comm_buffers(spec, cfg, t, state.vars, policies,
                                       weights=wts, comm_every=cadence,
                                       shard=shard, compress=ccfg, ef=efv)
        mom_c = state.mom
        if has_mom:
            if ccfg is None:
                mom_c = comm_buffers(spec, cfg, t, state.mom, policies,
                                     weights=wts, comm_every=cadence,
                                     shard=shard, corrupt=corrupt,
                                     robust=rcfg)
            else:
                mom_c, efm = comm_buffers(spec, cfg, t, state.mom, policies,
                                          weights=wts, comm_every=cadence,
                                          shard=shard, compress=ccfg, ef=efm)
        return state._replace(vars=vars_c, mom=mom_c, step=t + 1,
                              ef=(efv, efm) if state.ef else ())

    step = _storm_step if aspec.kind == "storm" else _sgd_step
    # what the step actually computes in-band (() = bare-state contract) —
    # the trainer wrapper branches on this, not on telemetry's presence
    step.telemetry_groups = tel_groups

    def views(state: FlatState):
        vt = flat.unflatten_tree(spec, state.vars)
        if not state.mom:
            return vt, None
        mt = flat.unflatten_tree(spec, state.mom)
        return vt, {q.momentum: mt[q.section] for q in aspec.sequences}

    return Engine(aspec, spec, init_state, step, views, state_shardings,
                  comm_fn)
