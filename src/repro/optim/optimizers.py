"""Minimal optimizer library (no optax offline) — pytree-generic.

Each optimizer is ``(init_fn, update_fn)``:
    opt_state = init_fn(params)
    params, opt_state = update_fn(params, grads, opt_state, lr)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tree_util import tree_zeros_like


def sgd():
    def init(params):
        return ()

    def update(params, grads, state, lr):
        new = jax.tree.map(lambda p, g: p - (lr * g).astype(p.dtype), params, grads)
        return new, state

    return init, update


def momentum(beta: float = 0.9, nesterov: bool = False):
    def init(params):
        return tree_zeros_like(params)

    def update(params, grads, m, lr):
        m = jax.tree.map(lambda mm, g: beta * mm + g.astype(mm.dtype), m, grads)
        step = (jax.tree.map(lambda mm, g: beta * mm + g.astype(mm.dtype), m, grads)
                if nesterov else m)
        new = jax.tree.map(lambda p, s: p - (lr * s).astype(p.dtype), params, step)
        return new, m

    return init, update


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    def init(params):
        return {"m": tree_zeros_like(params), "v": tree_zeros_like(params),
                "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g).astype(v_.dtype),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, m_, v_: p - (lr * (m_ / bc1) /
                                   (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return init, update
