# Optimizer substrate:
#   optimizers — minimal pytree sgd/momentum/adam (no optax offline)
#   flat       — flat-buffer STORM substrate: the (x, y, u) trees and their
#                momenta are flattened once at init into contiguous per-dtype,
#                tile-padded buffers; the triple-sequence Pallas kernel then
#                advances all three FedBiOAcc momentum sequences in one launch
#                (enabled via make_fedbioacc_train_step(..., fuse_storm=True)
#                and FederatedConfig.fuse_storm for the core algorithms).
from repro.optim.optimizers import adam, momentum, sgd  # noqa: F401
from repro.optim.flat import (FlatSpec, buffers_add, flatten_tree,  # noqa: F401
                              make_spec, storm_full_update,
                              storm_partial_step, unflatten_tree,
                              zeros_buffers)
