# Optimizer substrate:
#   optimizers — minimal pytree sgd/momentum/adam (no optax offline)
#   flat       — flat-buffer STORM substrate: the (x, y, u) trees and their
#                momenta are flattened once at init into contiguous per-dtype,
#                tile-padded buffers; the triple-sequence Pallas kernels then
#                advance every momentum sequence in one launch, and
#                client_mean_masked communicates only the averaged sections
#                (private sections pass through bit-identical).
#   sequences  — the declarative sequence-spec engine: every federated
#                algorithm (fedbio, fedbioacc, the local variants, fedavg)
#                as a tuple of (section, momentum, lr, decay, comm-policy,
#                comm-cadence, staleness) declarations compiled onto the
#                flat substrate (enabled via fuse_storm=True on the trainer
#                factories and FederatedConfig.fuse_storm for the core
#                algorithms).  make_engine(..., participation=) threads a
#                per-round client mask (repro.federation.participation)
#                through the gated launches and participants-only
#                reductions.
from repro.optim.optimizers import adam, momentum, sgd  # noqa: F401
from repro.optim.flat import (CompressCfg, FlatSpec, buffers_add,  # noqa: F401
                              client_mean_masked, flatten_tree, make_spec,
                              momentum_sgd_step, sgd_step, storm_full_update,
                              storm_partial_step, unflatten_tree,
                              zeros_buffers)
from repro.optim.sequences import (AVERAGED, HIERARCHICAL, PRIVATE,  # noqa: F401
                                   AlgoSpec, Engine, FlatState, Sequence,
                                   SPECS, comm_buffers, comm_tree,
                                   make_engine)
