from repro.optim.optimizers import adam, momentum, sgd  # noqa: F401
