"""Flat-buffer optimizer substrate for the STORM-accelerated train steps.

FedBiOAcc runs STORM variance reduction on *three* parallel sequences — the
body/ν, head/ω and auxiliary/q pairs — over the full parameter tree every
local step. Expressed as per-leaf ``jax.tree.map`` chains that is ~9
elementwise passes per step, each dispatched once per leaf; on accelerators
it is pure HBM-bandwidth traffic and the dominant "memory term" of the step.

This module flattens the (x, y, u) trees and their momenta **once at init**
into contiguous per-dtype buffers and keeps all training state flat across
steps (callers jit with buffer donation). Pytree views are materialized only
at oracle / eval / checkpoint boundaries via ``unflatten_tree``.

Layout (``FlatSpec``):

* Leaves are grouped by dtype → one 1-D buffer per dtype.
* Within a buffer, leaves are ordered by **section** (e.g. ``("x","y","u")``)
  and each section is zero-padded up to the kernel tile size ``block``, so
  every tile belongs to exactly one section. ``_Group.section_ids`` maps
  tile → section; at step time the per-section (lr, decay) scalars are
  gathered into per-tile SMEM tables for the triple-sequence Pallas kernel
  (``storm3_step_flat`` / ``storm3_update_flat``).
* Leaf offset metadata (``_Leaf``) makes flatten/unflatten pure
  reshape+concat / slice+reshape — no data-dependent work.
* Buffers may carry leading batch dims (``batch_dims=1`` for the federated
  client axis M → buffers are [M, N]); ``client_mean`` on such a buffer is
  ONE reduction per dtype instead of one per leaf.
* ``client_mean_masked`` supports *partial* communication (the local-lower
  algorithms: average x/ν, keep y/ω private): the per-group **section
  extents are precomputed at spec-build time** (``_Group.extents``) and
  adjacent same-mode/same-weight sections coalesce into contiguous element
  runs, so the communicated sections cost one sliced reduction each while
  private sections pass through bit-identical and never enter a reduction.
  Each communicated run is written back **in place**
  (``lax.dynamic_update_slice`` — under buffer donation the private tiles
  are never copied; on CPU large runs are additionally chunked so the
  reduce + broadcast stay cache-resident, which is what makes the sliced
  reduction beat the per-leaf tree-map path off-TPU too).
* **Participation** (``repro.federation.participation``): the same reductions
  take per-client ``weights`` ([M], zero = non-participant) — the mean is
  over participants only (weighted by data size / staleness discounts), and
  non-participant rows pass through bit-identical, exactly like private
  sections do along the section axis.  The weighted mean is computed as
  ``mean(x · w · (M/Σw))`` so that all-ones weights reproduce the unweighted
  path bit-for-bit.  The fused updates take the matching per-client ``mask``:
  a non-participant's tiles get lr = 0 (and STORM decay / heavy-ball β pinned
  to 1), which — together with the engine zeroing its oracle contributions —
  freezes its variable AND momentum buffers bit-exact through the round.

Mesh sharding (``shards`` / ``ShardCtx``)
-----------------------------------------

``make_spec(..., shards=k)`` builds a layout partitionable over a mesh
"model" axis of size k: every section is padded to a multiple of
``block · shards`` and the buffer is laid out **shard-major** — shard j's
contiguous chunk holds the j-th ``1/shards`` slice of *every* section, in
section order.  Consequences:

* a plain contiguous ``NamedSharding(mesh, P("data", "model"))`` on the
  [M, N] buffer gives every model shard the SAME tile-aligned section
  pattern (``_Group.extents`` describes one shard chunk; the global
  ``section_ids`` is that pattern tiled ``shards`` times), so section
  boundaries are tile-aligned to shard boundaries by construction;
* the fused launches and the masked reductions run under ``jax.shard_map``
  (pass ``shard=``, a :class:`ShardCtx`): each device launches the kernel on
  its local [M/d, N/k] chunk with its slice of the per-tile SMEM tables, and
  ``client_mean_masked`` lowers the participant mean to **per-shard partial
  sums + one ``lax.psum`` over "data" per communicated run** (or the
  ``psum_scatter`` + ``all_gather`` decomposition with
  ``ShardCtx.use_scatter`` — the overlap-friendly all-reduce).  Private and
  non-participant tiles never enter the collective.

``shards=1`` (the default) reproduces the original single-chip layout
bit-for-bit.  The padding tiles are zero and stay zero under every substrate
op (the update is elementwise and 0 − lr·0 = 0), so round-trips are exact.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.kernels.storm.kernel import (BLOCK, momsgd3_step_flat,
                                        momsgd3_step_flat_jnp,
                                        sgd3_step_flat, sgd3_step_flat_jnp,
                                        storm3_step_flat,
                                        storm3_step_flat_jnp,
                                        storm3_update_flat,
                                        storm3_update_flat_jnp)
from repro.kernels.storm.quantpack import (quantpack_flat, quantpack_flat_jnp,
                                           quantunpack_flat,
                                           quantunpack_flat_jnp)


class _Leaf(NamedTuple):
    index: int          # position in the spec treedef's leaf order
    shape: tuple        # original leaf shape (without batch dims)
    size: int
    offset: int         # element offset inside the SECTION-CONTIGUOUS layout


class _Group(NamedTuple):
    dtype: Any                  # np.dtype of the buffer
    leaves: tuple               # of _Leaf, ascending offset
    padded: int                 # buffer length — multiple of block·shards
    block: int
    section_ids: np.ndarray     # [padded // block] int32 — tile → section
    extents: tuple = ()         # static per-SHARD-CHUNK section extents:
    #   ((section, start_elem, stop_elem), ...) covering [0, padded/shards)
    #   — the section-run slices every reduction is built from


class FlatSpec(NamedTuple):
    treedef: Any
    num_leaves: int
    sections: tuple             # section names, () when unsectioned
    groups: tuple               # of _Group
    shards: int = 1             # model-axis partition count of the layout


class ShardCtx(NamedTuple):
    """How the flat substrate is partitioned over a device mesh: the client
    axis M over ``data_axis``, the packed parameter axis N over
    ``model_axis`` (which must equal ``FlatSpec.shards``).  ``use_scatter``
    lowers the participant mean to ``psum_scatter`` + ``all_gather`` instead
    of one ``psum`` (the decomposed all-reduce XLA can software-pipeline)."""
    mesh: Any
    data_axis: str = "data"
    model_axis: str = "model"
    use_scatter: bool = False

    @property
    def data_size(self) -> int:
        return self.mesh.shape[self.data_axis]

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def buffer_spec(self) -> PartitionSpec:
        return PartitionSpec(self.data_axis, self.model_axis)


def make_shard_ctx(mesh, *, data_axis: str = "data",
                   model_axis: str = "model",
                   use_scatter: bool = False) -> ShardCtx:
    axes = dict(mesh.shape)
    for a in (data_axis, model_axis):
        if a not in axes:
            raise ValueError(f"mesh axes {tuple(axes)} carry no {a!r} axis")
    return ShardCtx(mesh, data_axis, model_axis, use_scatter)


def _round_up(n: int, block: int) -> int:
    return n + (-n) % block


def make_spec(tree, *, sections: Sequence[str] | None = None,
              block: int = BLOCK, shards: int = 1) -> FlatSpec:
    """Build the flat layout for ``tree`` (arrays or ShapeDtypeStructs).

    ``sections``: top-level dict keys of ``tree`` whose subtrees must occupy
    contiguous, tile-aligned runs of each dtype buffer (the x|y|u segments of
    the triple-sequence kernel). Buffer order follows ``sections``, not the
    treedef's internal key order.

    ``shards``: model-axis partition count — every section is padded to a
    multiple of ``block · shards`` and the buffer is laid out shard-major
    (see the module docstring), so a contiguous 1/shards chunk of the buffer
    holds 1/shards of every section with tile-aligned section boundaries.
    """
    assert shards >= 1, shards
    leaves, treedef = jax.tree.flatten(tree)
    if sections is None:
        sec_names = ()
        sec_of_leaf = [0] * len(leaves)
        n_sections = 1
    else:
        sec_names = tuple(sections)
        # label every leaf with its section index, in treedef leaf order
        # (dict flattening sorts keys the same way for tree and labels)
        sec_of_leaf = jax.tree.leaves(
            {k: jax.tree.map(lambda _, s=i: s, tree[k])
             for i, k in enumerate(sec_names)})
        assert len(sec_of_leaf) == len(leaves), "sections must cover the tree"
        n_sections = len(sec_names)

    # dtype groups, ordered by first appearance in (section, leaf) order
    order = sorted(range(len(leaves)), key=lambda i: (sec_of_leaf[i], i))
    dtypes: list = []
    for i in order:
        dt = np.dtype(leaves[i].dtype)
        if dt not in dtypes:
            dtypes.append(dt)

    quantum = block * shards
    groups = []
    for dt in dtypes:
        lfs, offset = [], 0
        pattern: list = []      # per-shard-chunk tile → section
        extents: list = []      # per-shard-chunk (section, start, stop)
        for s in range(n_sections):
            start = offset
            for i in order:
                if sec_of_leaf[i] != s or np.dtype(leaves[i].dtype) != dt:
                    continue
                shape = tuple(leaves[i].shape)
                size = int(np.prod(shape, dtype=np.int64)) if shape else 1
                lfs.append(_Leaf(i, shape, size, offset))
                offset += size
            if offset > start:     # section present in this dtype group
                offset = _round_up(offset, quantum)
                k = (offset - start) // quantum    # tiles per shard chunk
                a = extents[-1][2] if extents else 0
                extents.append((s, a, a + k * block))
                pattern += [s] * k
        if not lfs:
            continue
        groups.append(_Group(dt, tuple(lfs), offset, block,
                             np.tile(np.asarray(pattern, np.int32), shards),  # analysis: ignore[L303] spec build
                             tuple(extents)))
    return FlatSpec(treedef, len(leaves), sec_names, tuple(groups), shards)


def _interleave(spec: FlatSpec, grp: _Group, cont):
    """Section-contiguous buffer → shard-major layout (reshape/concat only).
    Identity when ``shards == 1``."""
    if spec.shards == 1:
        return cont
    bs = cont.shape[:-1]
    pieces, off = [], 0
    for _, a, b in grp.extents:
        per_shard = b - a
        full = per_shard * spec.shards
        pieces.append(cont[..., off:off + full]
                      .reshape(bs + (spec.shards, per_shard)))
        off += full
    return jnp.concatenate(pieces, axis=-1).reshape(bs + (grp.padded,))


def _deinterleave(spec: FlatSpec, grp: _Group, buf):
    """Shard-major layout → section-contiguous buffer (the inverse)."""
    if spec.shards == 1:
        return buf
    bs = buf.shape[:-1]
    chunked = buf.reshape(bs + (spec.shards, grp.padded // spec.shards))
    pieces = [chunked[..., :, a:b].reshape(bs + ((b - a) * spec.shards,))
              for _, a, b in grp.extents]
    return jnp.concatenate(pieces, axis=-1)


def flatten_tree(spec: FlatSpec, tree, *, batch_dims: int = 0, dtype=None):
    """Pack ``tree`` into the spec's flat buffers (tuple, one per dtype).

    Leaves may carry ``batch_dims`` leading axes (shared across the tree);
    buffers then have shape ``batch_shape + (padded,)``. ``dtype`` overrides
    every buffer's dtype (same layout) — used to keep momenta and oracle
    gradients in f32 buffers alongside low-precision variable buffers.
    """
    leaves = spec.treedef.flatten_up_to(tree)
    bufs = []
    for grp in spec.groups:
        out_dt = dtype if dtype is not None else grp.dtype
        batch_shape = tuple(
            jnp.shape(leaves[grp.leaves[0].index])[:batch_dims])
        parts, cursor = [], 0
        for lf in grp.leaves:
            if lf.offset > cursor:
                parts.append(jnp.zeros(batch_shape + (lf.offset - cursor,),
                                       out_dt))
            x = jnp.asarray(leaves[lf.index], out_dt)
            parts.append(x.reshape(batch_shape + (-1,)))
            cursor = lf.offset + lf.size
        if cursor < grp.padded:
            parts.append(jnp.zeros(batch_shape + (grp.padded - cursor,),
                                   out_dt))
        cont = (parts[0] if len(parts) == 1
                else jnp.concatenate(parts, axis=-1))
        bufs.append(_interleave(spec, grp, cont))
    return tuple(bufs)


def unflatten_tree(spec: FlatSpec, bufs):
    """Materialize the pytree view of flat buffers (slice + reshape only)."""
    leaves = [None] * spec.num_leaves
    for grp, buf in zip(spec.groups, bufs):
        cont = _deinterleave(spec, grp, buf)
        batch_shape = tuple(cont.shape[:-1])
        for lf in grp.leaves:
            seg = cont[..., lf.offset:lf.offset + lf.size]
            leaves[lf.index] = seg.reshape(batch_shape + lf.shape)
    return spec.treedef.unflatten(leaves)


def zeros_buffers(spec: FlatSpec, *, batch_shape: tuple = ()):
    return tuple(jnp.zeros(batch_shape + (g.padded,), g.dtype)
                 for g in spec.groups)


# ---------------------------------------------------------------------------
# Per-tile hyper-parameter tables and fused launches
# ---------------------------------------------------------------------------

def _tile_table(grp: _Group, buf, table):
    """Per-section scalar table → per-tile array [reps, T] for ``buf`` (the
    section pattern repeats over any leading batch dims; ``reps`` is their
    product).  Row-major flattening reproduces the kernel's client-major
    SMEM layout; a 2-D P(data, model) sharding slices it consistently with
    the buffer."""
    reps = int(np.prod(buf.shape[:-1], dtype=np.int64)) if buf.ndim > 1 else 1
    row = jnp.stack(table)[grp.section_ids]
    return jnp.broadcast_to(row[None], (reps, row.shape[0]))


def _gate(lr_tiles, decay_tiles, mask, frozen_decay: float):
    """Gate per-tile (lr, decay|β) tables [M, T] with the participation mask
    [M]: non-participants get lr = 0 and decay pinned to ``frozen_decay``
    (1.0 freezes STORM/heavy-ball momenta bit-exact once their oracle
    contributions are zeroed)."""
    if mask is None:
        return lr_tiles, decay_tiles
    assert mask.shape == (lr_tiles.shape[0],), (mask.shape, lr_tiles.shape)
    col = mask.astype(jnp.float32)[:, None]
    lr_tiles = lr_tiles * col
    if decay_tiles is not None:
        decay_tiles = jnp.where(col > 0, decay_tiles,
                                jnp.float32(frozen_decay))
    return lr_tiles, decay_tiles


def mask_buffers(bufs, mask):
    """Zero non-participant rows of [M, N] buffers (the "oracle skipped"
    half of the freeze: under vmap/SPMD every client computes, but a
    non-participant's gradients must not reach its momentum).  A ``where``
    select, not a multiply: participants pass through bit-identical, and a
    non-finite gradient on a skipped client's batch still zeroes out
    (0 · inf would poison the frozen momentum with NaN)."""
    if mask is None:
        return bufs
    return tuple(jnp.where(mask[:, None] > 0, b, jnp.zeros((), b.dtype))
                 for b in bufs)


def _dispatch(interpret):
    """Pick the lowering for the triple-sequence update.

    ``interpret=None`` (the default) → the Pallas kernel, compiled, on TPU;
    the bit-identical jnp lowering elsewhere (the interpreter validates the
    kernel but is far slower than XLA's fused loops). An explicit True/False
    forces the Pallas kernel with that interpret flag.
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return "pallas", False
        return "jnp", None
    return "pallas", interpret


def _check_shard(spec: FlatSpec, shard: ShardCtx, buf):
    assert buf.ndim == 2, \
        "the sharded substrate needs [M, N] buffers (batch_dims=1)"
    if spec.shards != shard.model_size:
        raise ValueError(
            f"spec was built for shards={spec.shards} but the mesh "
            f"{shard.model_axis} axis has size {shard.model_size}; rebuild "
            f"the spec with make_spec(..., shards={shard.model_size})")
    if buf.shape[0] % shard.data_size:
        raise ValueError(
            f"client axis M={buf.shape[0]} is not divisible by the mesh "
            f"{shard.data_axis} axis size {shard.data_size}")


def _launch(mode, flag, shard, spec, grp, kern_pallas, kern_jnp,
            bufs, tables, n_out: int):
    """One fused kernel launch on one dtype buffer — flattened globally, or
    per device chunk under ``shard_map`` when ``shard`` is given (every
    device then streams its local [M/d, N/k] chunk with its slice of the
    per-tile tables; the launch itself is collective-free)."""
    if mode == "pallas":
        fn = functools.partial(kern_pallas, block=grp.block, interpret=flag)
    else:
        fn = functools.partial(kern_jnp, block=grp.block)
    shape = bufs[0].shape
    if shard is None:
        outs = fn(*[b.reshape(-1) for b in bufs],
                  *[t.reshape(-1) for t in tables])
        outs = outs if n_out > 1 else (outs,)
        return tuple(o.reshape(shape) for o in outs)

    _check_shard(spec, shard, bufs[0])
    pb = shard.buffer_spec

    def body(*ops):
        bs, ts = ops[:len(bufs)], ops[len(bufs):]
        lshape = bs[0].shape
        outs = fn(*[b.reshape(-1) for b in bs],
                  *[t.reshape(-1) for t in ts])
        outs = outs if n_out > 1 else (outs,)
        return tuple(o.reshape(lshape) for o in outs)

    return shard_map(body, mesh=shard.mesh,
                     in_specs=(pb,) * (len(bufs) + len(tables)),
                     out_specs=(pb,) * n_out,
                     check_rep=False)(*bufs, *tables)


def storm_partial_step(spec: FlatSpec, var_bufs, mom_bufs, g_old_bufs,
                       lrs, decays, *, mask=None,
                       interpret: bool | None = None,
                       shard: ShardCtx | None = None):
    """One fused triple-sequence launch per dtype buffer:

        v_new  = v − lr_sec·m            (variable step, entering momentum)
        m_part = decay_sec·(m − g_old)   (partial STORM momentum)

    ``lrs``/``decays``: one scalar per section (traced OK). The correction
    ``m_part + g_new`` is a single elementwise add once the new-iterate
    oracle exists (after communication).

    ``mask``: optional per-client participation mask [M] — non-participants'
    tiles run with lr = 0 and decay = 1, so (with ``g_old`` zeroed via
    :func:`mask_buffers`) their variable and momentum rows are frozen
    bit-exact inside the same fused launch.

    ``shard``: run each launch under ``shard_map`` on the mesh (elementwise —
    no collective; the spec must have been built with matching ``shards``).
    """
    mode, flag = _dispatch(interpret)
    out_v, out_m = [], []
    for grp, v, m, go in zip(spec.groups, var_bufs, mom_bufs, g_old_bufs):
        lr_t, dc_t = _gate(_tile_table(grp, v, lrs),
                           _tile_table(grp, v, decays), mask, 1.0)
        vn, mn = _launch(mode, flag, shard, spec, grp,
                         storm3_step_flat, storm3_step_flat_jnp,
                         (v, m, go), (lr_t, dc_t), 2)
        out_v.append(vn)
        out_m.append(mn)
    return tuple(out_v), tuple(out_m)


def storm_full_update(spec: FlatSpec, var_bufs, mom_bufs, g_new_bufs,
                      g_old_bufs, lrs, decays, *,
                      interpret: bool | None = None,
                      shard: ShardCtx | None = None):
    """Full fused update (v − lr·m, g_new + decay·(m − g_old)) — usable when
    both oracle values are already in hand (benchmarks, single-shot tests)."""
    mode, flag = _dispatch(interpret)
    out_v, out_m = [], []
    for grp, v, m, gn, go in zip(spec.groups, var_bufs, mom_bufs,
                                 g_new_bufs, g_old_bufs):
        vn, mn = _launch(mode, flag, shard, spec, grp,
                         storm3_update_flat, storm3_update_flat_jnp,
                         (v, m, gn, go),
                         (_tile_table(grp, v, lrs),
                          _tile_table(grp, v, decays)), 2)
        out_v.append(vn)
        out_m.append(mn)
    return tuple(out_v), tuple(out_m)


def momentum_sgd_step(spec: FlatSpec, var_bufs, mom_bufs, g_bufs,
                      lrs, betas, *, mask=None,
                      interpret: bool | None = None,
                      shard: ShardCtx | None = None):
    """One fused heavy-ball launch per dtype buffer:

        m_new = β_sec·m + g        (momentum update — FedAvg ordering)
        v_new = v − lr_sec·m_new   (variable step with the *updated* momentum)

    Momentum-less specs (β = 0 everywhere, no momentum state) should use
    :func:`sgd_step` instead — same variable result without the dead
    momentum stream.

    ``mask``: optional per-client participation mask [M] — non-participants
    run with lr = 0 and β = 1 (identity momentum; pair with zeroed ``g`` via
    :func:`mask_buffers` for a bit-exact freeze).  ``shard``: as in
    :func:`storm_partial_step`.
    """
    mode, flag = _dispatch(interpret)
    out_v, out_m = [], []
    for grp, v, m, gb in zip(spec.groups, var_bufs, mom_bufs, g_bufs):
        lr_t, bt_t = _gate(_tile_table(grp, v, lrs),
                           _tile_table(grp, v, betas), mask, 1.0)
        vn, mn = _launch(mode, flag, shard, spec, grp,
                         momsgd3_step_flat, momsgd3_step_flat_jnp,
                         (v, m, gb), (lr_t, bt_t), 2)
        out_v.append(vn)
        out_m.append(mn)
    return tuple(out_v), tuple(out_m)


def sgd_step(spec: FlatSpec, var_bufs, g_bufs, lrs, *, mask=None,
             interpret: bool | None = None,
             shard: ShardCtx | None = None):
    """One fused plain-SGD launch per dtype buffer: v_new = v − lr_sec·g.

    The β = 0 fast path for momentum-less specs (FedBiO / FedBiO-Local):
    2 reads + 1 write per element — a pallas_call's outputs are opaque to
    XLA DCE, so the heavy-ball kernel would pay a full dead momentum write.

    ``mask``: optional per-client participation mask [M] — non-participants'
    tiles run with lr = 0 (v − 0·g = v, bit-exact freeze).  ``shard``: as in
    :func:`storm_partial_step`.
    """
    mode, flag = _dispatch(interpret)
    out_v = []
    for grp, v, gb in zip(spec.groups, var_bufs, g_bufs):
        lr_t, _ = _gate(_tile_table(grp, v, lrs), None, mask, 1.0)
        (vn,) = _launch(mode, flag, shard, spec, grp,
                        sgd3_step_flat, sgd3_step_flat_jnp,
                        (v, gb), (lr_t,), 1)
        out_v.append(vn)
    return tuple(out_v)


def buffers_add(a, b):
    """Elementwise a + b over buffer tuples (the STORM correction add)."""
    return tuple(x + y for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# Section-masked communication
# ---------------------------------------------------------------------------

def _weight_col(x, w):
    """Per-client weights → a broadcastable [M, 1, ...] column, rescaled so
    the *plain mean* of ``x · col`` is the participation-weighted mean:

        col_m = w_m · (M / Σ w)   ⇒   mean_m(x_m · col_m) = Σ w_m x_m / Σ w

    The rescale-into-the-mean form is what makes all-ones weights a
    bit-identical no-op (col = 1.0 exactly, x · 1.0 = x, then the same
    ``jnp.mean`` as the unweighted path).  Empty groups (Σw = 0) scale to 0;
    callers pass non-participants through with a ``where`` on ``col > 0``.
    """
    wsum = jnp.sum(w, axis=-1, keepdims=True)
    scale = jnp.where(wsum > 0, w.shape[-1] / wsum, 0.0)
    col = (w * scale).astype(x.dtype)
    return col.reshape(col.shape + (1,) * (x.ndim - col.ndim))


def _bcast_mean(x, w=None):
    """Full client mean over the leading axis, broadcast back (the paper's
    communication round — one all-reduce under pjit).  Mirrors
    ``core.tree_util.client_mean`` at array level; importing tree_util here
    would close an import cycle (optim.flat ← core ← optim.sequences).

    ``w``: optional per-client weights [M] (zero = non-participant): the mean
    is over participants only and non-participant rows pass through
    bit-identical (selected *around* the reduction, like private sections).
    """
    if w is None:
        return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)
    col = _weight_col(x, w)
    m = jnp.broadcast_to(jnp.mean(x * col, axis=0, keepdims=True), x.shape)
    return jnp.where(col > 0, m, x)


def _bcast_mean_grouped(x, num_groups: int, w=None):
    """Pod-local grouped mean over contiguous client groups (hierarchical
    multi-pod schedule — the all-reduce stays on the intra-pod ICI).
    ``w`` as in :func:`_bcast_mean`, applied within each group."""
    M = x.shape[0]
    g = x.reshape((num_groups, M // num_groups) + x.shape[1:])
    if w is None:
        m = jnp.mean(g, axis=1, keepdims=True)
        return jnp.broadcast_to(m, g.shape).reshape(x.shape)
    col = _weight_col(g, w.reshape(num_groups, M // num_groups))
    m = jnp.broadcast_to(jnp.mean(g * col, axis=1, keepdims=True), g.shape)
    return jnp.where(col > 0, m, g).reshape(x.shape)


class RobustCfg(NamedTuple):
    """Robust-reduction policy of :func:`client_mean_masked` (the substrate
    half of ``repro.federation.faults.RobustnessSpec`` — defined here so the
    substrate stays import-free of the federation layer).

    ``screen`` enables the per-client health mask: a participant is healthy
    iff its (as-sent) row is all-finite and its update norm sits within
    ``z_thresh`` standard deviations of the healthy participants' mean norm
    (``z_thresh <= 0`` keeps the finite check only).  ``aggregator``:

    * ``"mean"`` — participants-only weighted mean over healthy rows (the
      same ``_weight_col`` arithmetic as the unguarded path, so an
      all-healthy round reproduces it bit-for-bit);
    * ``"clip"`` — per-client norm clipping to ``clip_factor`` x the healthy
      participants' weighted mean norm before the mean (row-local scaling —
      shard-local under ``shard_map``);
    * ``"trim"`` — coordinate-wise ``trim_frac``-trimmed mean over healthy
      participants (an order statistic: weight-agnostic, and gather-based on
      the sharded path — the one robust reduction that needs whole rows).
    """
    aggregator: str = "mean"
    screen: bool = True
    z_thresh: float = 3.0
    clip_factor: float = 2.0
    trim_frac: float = 0.2


class CompressCfg(NamedTuple):
    """Compressed-reduction policy of :func:`client_mean_masked` (the
    substrate half of ``repro.federation.compression.CompressionSpec`` —
    defined here, like :class:`RobustCfg`, so the substrate stays
    import-free of the federation layer).

    ``quant``: ``None`` | ``"bf16"`` | ``"int8"`` — the dtype the reduction
    moves.  bf16 is a cast; int8 is symmetric per-TILE quantization (one f32
    scale per ``block`` elements, the ``quantpack_flat`` kernel).  On the
    sharded path the per-device partial sums enter the collective in this
    dtype (int8 with a psum-shared per-tile scale), so the ``psum`` /
    ``psum_scatter`` wire itself narrows.

    ``topk_frac``: per-tile top-k sparsification of what each client sends —
    every client keeps the ``ceil(topk_frac · block)`` largest-magnitude
    entries of each tile of (row + error feedback).  Per-tile selection is
    identical on the unsharded buffer and on every ``shard_map`` chunk
    (tiles are the shard quantum), which is what keeps the two paths'
    compression decisions aligned.

    ``error_feedback``: carry the dropped mass per client (f32 buffers
    shaped like the communicated buffers — ``FlatState.ef``) and add it back
    into the next send.  Active only with ``topk_frac > 0``.

    ``sections``: section names to compress; () compresses every
    communicated run.  Composition limits (enforced upstream by
    ``sequences.make_engine`` / ``Experiment.validate``, asserted here):
    no ``corrupt=``/``robust=``, and ``topk_frac > 0`` excludes ``"group"``
    runs — error feedback against two different means is ill-defined, while
    plain quantization composes with the grouped mean.
    """
    quant: str | None = None
    topk_frac: float = 0.0
    error_feedback: bool = True
    sections: tuple = ()

    @property
    def has_ef(self) -> bool:
        return self.topk_frac > 0 and self.error_feedback


def _topk_tiles(x, block: int, frac: float):
    """Per-tile top-k sparsification (in f32): keep the k =
    ceil(frac · block) largest-magnitude entries of every ``block``-sized
    tile, zero the rest.  Threshold-based (``lax.top_k`` on |tile| gives the
    k-th magnitude): ties keep >= k entries — deterministic, and a zero tile
    "keeps" everything (all zeros — nothing is actually sent)."""
    t = x.reshape(x.shape[:-1] + (-1, block))
    k = max(1, int(np.ceil(frac * block)))
    thr = lax.top_k(jnp.abs(t), k)[0][..., -1:]
    return jnp.where(jnp.abs(t) >= thr, t, 0.0).reshape(x.shape)


def _quant_dequant(x, block: int, quant, mode, flag):
    """Round-trip ``x`` (f32) through the reduction dtype: the value error
    every client's send incurs.  int8 dispatches the ``quantpack_flat``
    pack/unpack kernel pair (bit-identical jnp lowering off-TPU)."""
    if quant is None:
        return x
    if quant == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    assert quant == "int8", quant
    shape = x.shape
    v = x.reshape(-1)
    if mode == "pallas":
        q, s = quantpack_flat(v, block=block, interpret=flag)
        return quantunpack_flat(q, s, block=block, interpret=flag).reshape(shape)
    q, s = quantpack_flat_jnp(v, block=block)
    return quantunpack_flat_jnp(q, s, block=block).reshape(shape)


def _compress_sent(x, block: int, ccfg: CompressCfg, mode, flag):
    """What a client sends into one compressed reduction: per-tile top-k of
    (row + error feedback), then the quantization round-trip — all in f32."""
    if ccfg.topk_frac > 0:
        x = _topk_tiles(x, block, ccfg.topk_frac)
    return _quant_dequant(x, block, ccfg.quant, mode, flag)


def _compressed_mean(seg, eseg, w, ccfg: CompressCfg, block: int, mode, flag):
    """Compressed participant mean of one run (f32): every client sends
    ``_compress_sent(row + EF)``, the server broadcasts the participants'
    weighted mean of the sends, and the residual ``(row + EF) − sent`` goes
    back into the client's EF buffer.  Non-participants (w = 0) pass their
    row through bit-identical AND their EF rows freeze bit-exact — the
    residual is a property of a send, and they sent nothing."""
    acc = seg.astype(jnp.float32)
    if eseg is not None:
        acc = acc + eseg
    sent = _compress_sent(acc, block, ccfg, mode, flag)
    if w is None:
        out = jnp.broadcast_to(jnp.mean(sent, axis=0, keepdims=True),
                               seg.shape)
    else:
        col = _weight_col(sent, w)
        m = jnp.broadcast_to(jnp.mean(sent * col, axis=0, keepdims=True),
                             seg.shape)
        out = jnp.where(col > 0, m, seg.astype(jnp.float32))
    new_e = None
    if eseg is not None:
        new_e = acc - sent
        if w is not None:
            pc = (w > 0).reshape(w.shape + (1,) * (seg.ndim - 1))
            new_e = jnp.where(pc, new_e, eseg)
    return out, new_e


def _compressed_mean_grouped(seg, w, ccfg: CompressCfg, block: int,
                             num_groups: int, mode, flag):
    """Grouped (hierarchical) mean over quantized sends — quantization
    COMPOSES with the pod-local mean (each client's send is quantized once;
    the group mean over the sends is exact), the satellite fix for
    ``_bcast_mean_grouped``.  Top-k/EF is rejected upstream."""
    assert ccfg.topk_frac == 0, \
        "top-k compression does not compose with grouped means"
    sent = _quant_dequant(seg.astype(jnp.float32), block, ccfg.quant,
                          mode, flag)
    M = seg.shape[0]
    g = sent.reshape((num_groups, M // num_groups) + seg.shape[1:])
    if w is None:
        return jnp.broadcast_to(jnp.mean(g, axis=1, keepdims=True),
                                g.shape).reshape(seg.shape)
    col = _weight_col(g, w.reshape(num_groups, M // num_groups))
    m = jnp.broadcast_to(jnp.mean(g * col, axis=1, keepdims=True), g.shape)
    g0 = seg.astype(jnp.float32).reshape(g.shape)
    return jnp.where(col > 0, m, g0).reshape(seg.shape)


def _corrupt_rows(x, corrupt):
    """Apply the round's fault transform to what clients *send* into one
    reduction: ``corrupt = (nan, byz, scale)`` with [M] {0,1} masks — byz
    rows are scaled, nan rows replaced wholesale.  ``where`` selects, so
    unfaulted rows pass through bit-identical (corrupt=None is identity)."""
    if corrupt is None:
        return x
    nan, byz, scale = corrupt
    trail = (1,) * (x.ndim - 1)
    bc = byz.reshape(byz.shape + trail)
    nc = nan.reshape(nan.shape + trail)
    x = jnp.where(bc > 0, x * jnp.asarray(scale, x.dtype), x)
    return jnp.where(nc > 0, jnp.asarray(jnp.nan, x.dtype), x)


def _health_mask(x, p, robust: RobustCfg):
    """Per-client health [M] f32 of one run: participant ∧ all-finite row ∧
    update-norm z-score within ``z_thresh`` of the finite participants'
    stats.  Excluded rows are removed with ``where`` BEFORE the norm sums —
    never by zero weights (0 · NaN = NaN would poison the stats)."""
    red = tuple(range(1, x.ndim))
    h = p & jnp.all(jnp.isfinite(x), axis=red)
    if robust.z_thresh <= 0:
        return h.astype(jnp.float32)
    hc = h.reshape(h.shape + (1,) * (x.ndim - 1))
    sq = jnp.sum(jnp.square(jnp.where(hc, x, 0).astype(jnp.float32)),
                 axis=red)
    n = jnp.sqrt(sq)
    hf = h.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(hf), 1.0)
    mu = jnp.sum(n * hf) / cnt
    sd = jnp.sqrt(jnp.sum(jnp.square(n - mu) * hf) / cnt)
    # relative tolerance: an all-equal-norm round has sd = 0 and must not
    # screen everyone out over float rounding in |n − mu|
    tol = robust.z_thresh * sd + 1e-4 * mu + 1e-12
    return (h & (jnp.abs(n - mu) <= tol)).astype(jnp.float32)


def _clip_rows(xh, n, w_eff, clip_factor: float):
    """Per-client norm clipping of healthy rows to ``clip_factor`` x the
    healthy participants' weighted mean norm (row-local — no cross-client
    mixing, so the sharded path applies it before its collective)."""
    wsum = jnp.maximum(jnp.sum(w_eff), 1e-12)
    tau = clip_factor * (jnp.sum(n * w_eff) / wsum)
    scale = jnp.minimum(1.0, tau / jnp.maximum(n, 1e-12))
    return xh * scale.reshape(scale.shape + (1,) * (xh.ndim - 1)).astype(
        xh.dtype)


def _trim_keep(M: int, nh, trim_frac: float, ndim: int):
    """(keep mask over sorted positions, trim count k) for a trimmed mean
    over ``nh`` (traced) healthy rows sorted to the front: positions
    [k, nh − k) survive, with k clamped so at least one row always does."""
    k = jnp.minimum(jnp.floor(trim_frac * nh),
                    jnp.maximum(jnp.floor((nh - 1.0) / 2.0), 0.0))
    idx = jnp.arange(M, dtype=jnp.float32).reshape((M,) + (1,) * (ndim - 1))
    return (idx >= k) & (idx < nh - k), k


def _trimmed_mean(xh, hf, trim_frac: float):
    """Coordinate-wise trimmed mean over healthy rows (keepdims row [1, ...]):
    excluded rows sort to the top as +inf and are never selected."""
    M = xh.shape[0]
    hc = hf.reshape(hf.shape + (1,) * (xh.ndim - 1))
    xs = jnp.sort(jnp.where(hc > 0, xh.astype(jnp.float32), jnp.inf), axis=0)
    nh = jnp.sum(hf)
    keep, k = _trim_keep(M, nh, trim_frac, xh.ndim)
    return (jnp.sum(jnp.where(keep, xs, 0.0), axis=0, keepdims=True)
            / jnp.maximum(nh - 2.0 * k, 1.0))


def _robust_bcast_mean(x0, w, corrupt, robust: RobustCfg | None):
    """Fault/robustness-aware participant mean of one communicated run.

    The fault transform applies to the reduction *input* — it models what a
    client sends, so private sections, cadence-skipped rounds and the
    client's own stored row are never corrupted.  With ``robust=None`` this
    is the UNGUARDED faulty mean: corrupted rows enter the sum and poison
    every participant (the failure mode the guards exist for).  With a
    :class:`RobustCfg`, unhealthy senders are screened out of the chosen
    aggregate and then *recovered* — they receive the aggregate instead of
    keeping a corrupted row — while non-participants always pass through
    their original row; if NO healthy weight remains, every row passes
    through unchanged (the round is retried by the rollback guard, not
    zeroed).
    """
    x = _corrupt_rows(x0, corrupt)
    if w is not None:
        # a zero-weight client SENDS nothing: its faults never reach the
        # round (0 x NaN would otherwise poison the unguarded sum)
        sc = (w > 0).reshape(w.shape + (1,) * (x.ndim - 1))
        x = jnp.where(sc, x, x0)
    if robust is None:
        if w is None:
            return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                    x.shape)
        col = _weight_col(x, w)
        m = jnp.broadcast_to(jnp.mean(x * col, axis=0, keepdims=True),
                             x.shape)
        return jnp.where(col > 0, m, x0)
    M = x.shape[0]
    wv = jnp.ones((M,), jnp.float32) if w is None else w
    p = wv > 0
    hf = _health_mask(x, p, robust) if robust.screen \
        else p.astype(jnp.float32)
    hc = hf.reshape(hf.shape + (1,) * (x.ndim - 1))
    xh = jnp.where(hc > 0, x, jnp.zeros((), x.dtype))
    w_eff = wv * hf
    if robust.aggregator == "trim":
        m = _trimmed_mean(xh, hf, robust.trim_frac)
    else:
        if robust.aggregator == "clip":
            red = tuple(range(1, x.ndim))
            n = jnp.sqrt(jnp.sum(jnp.square(xh.astype(jnp.float32)),
                                 axis=red))
            xh = _clip_rows(xh, n, w_eff, robust.clip_factor)
        # same _weight_col + jnp.mean arithmetic as the unguarded path: an
        # all-healthy round (hf = 1, w_eff = wv bitwise) reproduces it
        # bit-for-bit
        m = jnp.mean(xh * _weight_col(x, w_eff), axis=0, keepdims=True)
    m = jnp.broadcast_to(m.astype(x0.dtype), x0.shape)
    pc = p.reshape(p.shape + (1,) * (x.ndim - 1))
    return jnp.where(pc & (jnp.sum(w_eff) > 0), m, x0)


def _normalize_weights(spec: FlatSpec, weights):
    n_sections = max(len(spec.sections), 1)
    if isinstance(weights, (tuple, list)):
        assert len(weights) == n_sections, (len(weights), n_sections)
        return tuple(weights)
    return (weights,) * n_sections


def _section_runs(grp: _Group, shards: int, modes, w_of_sec,
                  comp_of_sec=None):
    """Static (mode, weight, start, stop, compressed) element runs covering
    the whole buffer, built from the spec-time section extents; adjacent
    runs merge when the mode, the weight array AND the compression flag all
    coincide (``"none"`` runs merge unconditionally — private tiles are
    never reduced, so neither weight nor compression applies to them),
    including across shard-chunk boundaries."""
    S = grp.padded // shards
    runs: list = []
    for j in range(shards):
        for s, a, b in grp.extents:
            mode, w = modes[int(s)], w_of_sec[int(s)]
            comp = bool(comp_of_sec[int(s)]) if comp_of_sec else False
            start, stop = j * S + a, j * S + b
            if runs and runs[-1][0] == mode and runs[-1][3] == start and (
                    mode == "none" or (runs[-1][1] is w
                                       and runs[-1][4] == comp)):
                runs[-1][3] = stop
            else:
                runs.append([mode, w, start, stop, comp])
    return runs


_CHUNK = 1 << 12    # elements per in-cache chunk of a reduced run (CPU path)


def _chunk_len(n: int) -> int:
    c = n
    while c > _CHUNK and c % 2 == 0:
        c //= 2
    return c


def _update_run(buf, start: int, stop: int, upd, *, chunk: bool = True):
    """Write ``upd(segment)`` back into ``buf`` over the element run
    [start, stop) — a ``dynamic_update_slice``, so under buffer donation the
    reduction happens in place and the tiles outside the run are never
    copied.  On CPU large runs are chunked so each reduce + broadcast stays
    cache-resident (the broadcast re-reads the mean row once per client —
    from L1/L2 instead of RAM), which is what lets the sliced reduction beat
    the per-leaf tree-map path off-TPU.  ``chunk=False`` forces one whole-run
    call — required when ``upd`` computes cross-column row statistics (the
    robust reductions' health norms would otherwise be chunk-local)."""
    nd = buf.ndim
    length = stop - start
    c = (_chunk_len(length)
         if chunk and jax.default_backend() == "cpu" else length)
    if c == length:
        seg = buf[..., start:stop]
        return lax.dynamic_update_slice(buf, upd(seg).astype(buf.dtype),
                                        (0,) * (nd - 1) + (start,))

    def body(j, acc):
        o = start + j * c
        seg = lax.dynamic_slice(acc, (0,) * (nd - 1) + (o,),
                                acc.shape[:-1] + (c,))
        return lax.dynamic_update_slice(acc, upd(seg).astype(acc.dtype),
                                        (0,) * (nd - 1) + (o,))

    return lax.fori_loop(0, length // c, body, buf)


def client_mean_masked(spec: FlatSpec, bufs, modes, *, num_groups: int = 2,
                       weights=None, shard: ShardCtx | None = None,
                       corrupt=None, robust: RobustCfg | None = None,
                       compress: CompressCfg | None = None, ef=None):
    """Section-masked client communication over flat [M, N] buffers.

    ``modes``: one entry per section (aligned with ``spec.sections``; a
    single entry for unsectioned specs), each ``"none"`` (private — the
    section must not be communicated), ``"mean"`` (full client mean) or
    ``"group"`` (pod-local grouped mean over ``num_groups`` groups).

    ``weights``: optional participation weights — one [M] array shared by
    every section, or a tuple of per-section [M] arrays (staleness-discounted
    sequences).  Zero-weight clients are non-participants: the mean is taken
    over participants only and their rows pass through bit-identical.
    Masks compose multiplicatively into the weights upstream — the
    straggler engine's arrival mask and the fault layer's dropout mask
    both multiply in (``repro.optim.sequences._round_ctx``), so a
    deadline-missing or faulted client is just another ``w = 0`` row here
    and no straggler-specific reduction path exists.

    Sections are contiguous tile-aligned element runs of each dtype buffer,
    precomputed at spec-build time (``_Group.extents``) and coalesced across
    adjacent same-mode/same-weight sections: each communicated run is ONE
    sliced reduction written back in place, and ``"none"`` runs are simply
    never touched — private sections are bit-identical by construction and
    never enter a reduction (no wasted cross-client traffic).

    ``shard``: run under ``shard_map`` on the mesh — every device reduces
    its local columns with per-shard partial sums and ONE ``lax.psum`` (or
    ``psum_scatter`` + ``all_gather``) over the data axis per communicated
    run; private and non-participant tiles never enter the collective.

    ``corrupt``: optional ``(nan, byz, scale)`` round fault masks ([M] {0,1}
    arrays + scalar) applied to what clients *send* into each ``"mean"`` run
    (:func:`_corrupt_rows`).  ``robust``: optional :class:`RobustCfg` —
    health-screen the senders and reduce with the robust aggregator
    (:func:`_robust_bcast_mean`).  Both compose with ``"none"`` sections
    (private state is never corrupted or reduced) but not with ``"group"``
    runs — the robust reductions are global (enforced upstream by
    ``Experiment.validate`` / ``sequences.make_engine``).

    ``compress``: optional :class:`CompressCfg` — the named sections'
    reductions move quantized and/or top-k-sparsified sends
    (:func:`_compressed_mean`; on the sharded path the collective itself
    moves the narrow dtype).  With ``compress`` set the call returns
    ``(bufs, ef)`` — ``ef`` being the updated per-client error-feedback
    buffers (pass the current ones via ``ef=``; the empty tuple when
    ``compress.has_ef`` is false).  ``compress=None`` (the default) keeps
    the original signature and a bit-identical trajectory.  Compression
    does not compose with ``corrupt=``/``robust=`` (the guarded reductions
    consume raw client rows — enforced upstream, asserted here).
    """
    n_sections = max(len(spec.sections), 1)
    assert len(modes) == n_sections, (modes, spec.sections)
    assert all(m in ("none", "mean", "group") for m in modes), modes
    guarded = corrupt is not None or robust is not None
    if guarded:
        assert all(m in ("none", "mean") for m in modes), (
            "corrupt=/robust= do not compose with grouped (hierarchical) "
            "means", modes)
    comp_of_sec = None
    if compress is not None:
        assert not guarded, (
            "compress= does not compose with corrupt=/robust= — enforced "
            "upstream by sequences.make_engine / Experiment.validate")
        if compress.topk_frac > 0:
            assert all(m in ("none", "mean") for m in modes), (
                "top-k compression does not compose with grouped "
                "(hierarchical) means — enforced upstream", modes)
        names = spec.sections if spec.sections else ("",)
        comp_of_sec = tuple(
            (not compress.sections) or (nm in compress.sections)
            for nm in names)
    w_of_sec = _normalize_weights(spec, weights)
    if shard is not None:
        return _client_mean_masked_sharded(spec, bufs, modes, num_groups,
                                           w_of_sec, shard,
                                           corrupt=corrupt, robust=robust,
                                           compress=compress,
                                           comp_of_sec=comp_of_sec, ef=ef)
    has_ef = compress is not None and compress.has_ef
    ebufs = tuple(ef) if ef else (None,) * len(spec.groups)
    if has_ef:
        assert len(ebufs) == len(spec.groups), (
            "compress with error feedback needs one f32 EF buffer per "
            "dtype group (pass ef=)", len(ebufs), len(spec.groups))
    kmode, kflag = _dispatch(None)
    out, ef_out = [], []
    for gi, (grp, buf) in enumerate(zip(spec.groups, bufs)):
        assert buf.ndim >= 2, "client_mean_masked needs a leading client axis"
        ebuf = ebufs[gi] if has_ef else None
        for mode, w, start, stop, comp in _section_runs(
                grp, spec.shards, modes, w_of_sec, comp_of_sec):
            if mode == "none":
                continue
            nd = buf.ndim
            if comp:
                # compressed runs are whole-run and pair the buffer write
                # with the EF write (the top-k/quant tiles are block-local;
                # the CPU cache chunking could split a tile)
                seg = buf[..., start:stop]
                eseg = (ebuf[..., start:stop]
                        if (ebuf is not None and mode == "mean") else None)
                if mode == "mean":
                    upd, new_e = _compressed_mean(seg, eseg, w, compress,
                                                  grp.block, kmode, kflag)
                else:
                    upd = _compressed_mean_grouped(seg, w, compress,
                                                   grp.block, num_groups,
                                                   kmode, kflag)
                    new_e = None
                buf = lax.dynamic_update_slice(
                    buf, upd.astype(buf.dtype), (0,) * (nd - 1) + (start,))
                if new_e is not None:
                    ebuf = lax.dynamic_update_slice(
                        ebuf, new_e.astype(ebuf.dtype),
                        (0,) * (nd - 1) + (start,))
                continue
            if mode == "mean":
                if guarded:
                    upd = functools.partial(
                        lambda s, w: _robust_bcast_mean(s, w, corrupt,
                                                        robust), w=w)
                else:
                    upd = functools.partial(lambda s, w: _bcast_mean(s, w),
                                            w=w)
            else:
                upd = functools.partial(
                    lambda s, w: _bcast_mean_grouped(s, num_groups, w), w=w)
            # the guarded reduction's health norms span the whole run — the
            # CPU cache chunking would make them chunk-local
            buf = _update_run(buf, start, stop, upd, chunk=not guarded)
        out.append(buf)
        ef_out.append(ebuf)
    if compress is None:
        return tuple(out)
    return tuple(out), (tuple(ef_out) if has_ef else ())


def _group_index_sets(shard: ShardCtx, num_groups: int):
    """Contiguous device groups along the data axis for the pod-local mean
    (``axis_index_groups`` of the grouped psum)."""
    d = shard.data_size
    if d % num_groups:
        raise ValueError(
            f"hierarchy_groups={num_groups} must divide the mesh "
            f"{shard.data_axis} axis size {d} on the sharded path")
    per = d // num_groups
    return [[g * per + i for i in range(per)] for g in range(num_groups)]


def _allreduce(x, shard: ShardCtx, groups):
    """True all-reduce of per-shard partial sums over the data axis: one
    ``psum`` — or its ``psum_scatter`` + ``all_gather`` decomposition
    (``use_scatter``), the form XLA can software-pipeline with compute."""
    if (shard.use_scatter and groups is None
            and x.shape[-1] % shard.data_size == 0):
        piece = lax.psum_scatter(x, shard.data_axis,
                                 scatter_dimension=x.ndim - 1, tiled=True)
        return lax.all_gather(piece, shard.data_axis, axis=x.ndim - 1,
                              tiled=True)
    return lax.psum(x, shard.data_axis, axis_index_groups=groups)


def _wire_allreduce(partial, quant, block: int, shard: ShardCtx, gidx,
                    nsum: int):
    """All-reduce of per-device f32 partial sums [L] in the WIRE dtype —
    what makes the collective itself cheap, not just the sends.

    * bf16 — cast, reduce, cast back (the collective moves 2 B/elem).
    * int8 — symmetric per-tile quantization on a scale SHARED by the
      ``nsum`` devices being summed: ``s = psum(per-tile absmax) /
      (127 − nsum/2)``.  The headroom term bounds the reduced value away
      from wraparound — each device's |q| <= absmax/s + 1/2 from rounding,
      so Σ|q| <= (127 − nsum/2) + nsum/2 = 127 exactly (XLA integer adds
      WRAP, they do not saturate).  The scale exchange is one tiny f32 psum
      of [L/block] — the 4/block bytes/elem overhead of the bytes model.
    * None (top-k only) — dense f32: sparsity does not shrink a psum.
    """
    if quant is None:
        return _allreduce(partial, shard, gidx)
    if quant == "bf16":
        return _allreduce(partial.astype(jnp.bfloat16), shard,
                          gidx).astype(jnp.float32)
    assert quant == "int8", quant
    t = partial.reshape(-1, block)
    amax = jnp.max(jnp.abs(t), axis=-1)
    gmax = lax.psum(amax, shard.data_axis, axis_index_groups=gidx)
    s = gmax / (127.0 - 0.5 * nsum)
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(t / safe[:, None]), -127.0, 127.0).astype(jnp.int8)
    totq = _allreduce(q.reshape(partial.shape), shard, gidx)
    return (totq.reshape(t.shape).astype(jnp.float32)
            * s[:, None]).reshape(partial.shape)


def _robust_mean_sharded(seg0, seg, w_l, robust: RobustCfg | None,
                         shard: ShardCtx, M: int):
    """The guarded participant mean of one run inside the ``shard_map`` body
    (the sharded mirror of :func:`_robust_bcast_mean`): per-client row stats
    (finiteness, norms) are completed with a ``psum`` over the MODEL axis —
    each device holds a column slice of every local row — screening and
    aggregation stats with ``psum``s over the DATA axis, clipping stays
    row-local, and the trimmed mean ``all_gather``s rows over the data axis
    (the documented gather-based sharding: an order statistic needs whole
    rows).  ``seg`` is the corrupted (as-sent) local chunk, ``seg0`` the
    original pass-through rows."""
    da, ma = shard.data_axis, shard.model_axis
    wv = (jnp.ones((seg.shape[0],), jnp.float32) if w_l is None else w_l)
    p = wv > 0
    if w_l is not None:
        # a zero-weight client SENDS nothing: its faults never reach the
        # round (0 x NaN would otherwise poison the unguarded sum)
        seg = jnp.where(p[:, None], seg, seg0)
    if robust is None:
        # unguarded faulty mean: corrupted rows enter the sum (and poison it)
        wsum = lax.psum(jnp.sum(wv), da)
        scale = jnp.where(wsum > 0, M / wsum, 0.0)
        col = (wv * scale).astype(seg.dtype)[:, None]
        tot = _allreduce(jnp.sum(seg * col, axis=0), shard, None)
        m = jnp.broadcast_to((tot / M)[None].astype(seg0.dtype), seg0.shape)
        return m if w_l is None else jnp.where(col > 0, m, seg0)
    if robust.screen:
        nonfinite = lax.psum(
            jnp.sum(~jnp.isfinite(seg), axis=1).astype(jnp.float32), ma)
        h = p & (nonfinite == 0)
    else:
        h = p
    sq = lax.psum(jnp.sum(jnp.square(
        jnp.where(h[:, None], seg, 0).astype(jnp.float32)), axis=1), ma)
    n = jnp.sqrt(sq)
    hf = h.astype(jnp.float32)
    if robust.screen and robust.z_thresh > 0:
        cnt = jnp.maximum(lax.psum(jnp.sum(hf), da), 1.0)
        mu = lax.psum(jnp.sum(n * hf), da) / cnt
        sd = jnp.sqrt(lax.psum(jnp.sum(jnp.square(n - mu) * hf), da) / cnt)
        tol = robust.z_thresh * sd + 1e-4 * mu + 1e-12
        h = h & (jnp.abs(n - mu) <= tol)
        hf = h.astype(jnp.float32)
    w_eff = wv * hf
    wsum_eff = lax.psum(jnp.sum(w_eff), da)
    if robust.aggregator == "trim":
        xs = jnp.sort(lax.all_gather(
            jnp.where(h[:, None], seg.astype(jnp.float32), jnp.inf),
            da, axis=0, tiled=True), axis=0)
        nh = lax.psum(jnp.sum(hf), da)
        keep, k = _trim_keep(M, nh, robust.trim_frac, 2)
        m = (jnp.sum(jnp.where(keep, xs, 0.0), axis=0, keepdims=True)
             / jnp.maximum(nh - 2.0 * k, 1.0))
    else:
        xh = jnp.where(h[:, None], seg, jnp.zeros((), seg.dtype))
        if robust.aggregator == "clip":
            tau = robust.clip_factor * (
                lax.psum(jnp.sum(n * w_eff), da)
                / jnp.maximum(wsum_eff, 1e-12))
            sc = jnp.minimum(1.0, tau / jnp.maximum(n, 1e-12))
            xh = xh * sc[:, None].astype(xh.dtype)
        scale = jnp.where(wsum_eff > 0, M / wsum_eff, 0.0)
        col = (w_eff * scale).astype(seg.dtype)[:, None]
        tot = _allreduce(jnp.sum(xh * col, axis=0), shard, None)
        m = (tot / M)[None]
    m = jnp.broadcast_to(m.astype(seg0.dtype), seg0.shape)
    return jnp.where(p[:, None] & (wsum_eff > 0), m, seg0)


def _client_mean_masked_sharded(spec: FlatSpec, bufs, modes, num_groups,
                                w_of_sec, shard: ShardCtx,
                                corrupt=None, robust: RobustCfg | None = None,
                                compress: CompressCfg | None = None,
                                comp_of_sec=None, ef=None):
    guarded = corrupt is not None or robust is not None
    assert compress is None or not guarded
    has_ef = compress is not None and compress.has_ef
    ebufs = tuple(ef) if ef else (None,) * len(spec.groups)
    kmode, kflag = _dispatch(None)
    # the fault masks ride the shard_map as [M]-over-"data" operands, like
    # the participation weights
    cops = () if corrupt is None else (corrupt[0], corrupt[1])
    cscale = None if corrupt is None else corrupt[2]
    out, ef_out = [], []
    for gi, (grp, buf) in enumerate(zip(spec.groups, bufs)):
        _check_shard(spec, shard, buf)
        M = buf.shape[0]
        nds = shard.data_size
        # one run list per SHARD CHUNK (the extents are per-chunk already) —
        # identical on every model shard, so the SPMD program's static
        # slices line up on all devices
        runs = _section_runs(grp, 1, modes, w_of_sec, comp_of_sec)
        if all(r[0] == "none" for r in runs):
            out.append(buf)
            ef_out.append(ebufs[gi] if has_ef else None)
            continue
        groups_idx = (_group_index_sets(shard, num_groups)
                      if any(r[0] == "group" for r in runs) else None)
        # distinct weight arrays become shard_map operands ([M] over "data")
        ws: list = []
        w_idx: list = []
        for mode, w, _, _, _ in runs:
            if w is None or mode == "none":
                w_idx.append(None)
                continue
            for k, a in enumerate(ws):
                if a is w:
                    w_idx.append(k)
                    break
            else:
                ws.append(w)
                w_idx.append(len(ws) - 1)
        ebuf = ebufs[gi] if has_ef else None

        def body(b, *ops, runs=runs, w_idx=w_idx, groups_idx=groups_idx):
            e = ops[0] if has_ef else None
            off = 1 if has_ef else 0
            wloc, cloc = ops[off:off + len(ws)], ops[off + len(ws):]
            for (mode, _, a, stop, comp), wi in zip(runs, w_idx):
                if mode == "none":
                    continue        # private tiles never enter the collective
                seg = b[:, a:stop]
                if guarded and mode == "mean":
                    corr = ((cloc[0], cloc[1], cscale) if cloc else None)
                    upd = _robust_mean_sharded(
                        seg, _corrupt_rows(seg, corr),
                        wloc[wi] if wi is not None else None,
                        robust, shard, M)
                    b = lax.dynamic_update_slice(b, upd.astype(b.dtype),
                                                 (0, a))
                    continue
                gidx = groups_idx if mode == "group" else None
                denom = M // num_groups if mode == "group" else M
                if comp:
                    # compressed run: per-client sends are sparsified +
                    # quantized exactly as on the unsharded path (per-tile —
                    # the shard-major layout keeps tile boundaries aligned),
                    # then the per-device partial sums cross the wire in the
                    # narrow dtype (_wire_allreduce)
                    nsum = nds // num_groups if mode == "group" else nds
                    eseg = (e[:, a:stop]
                            if (e is not None and mode == "mean") else None)
                    acc = seg.astype(jnp.float32)
                    if eseg is not None:
                        acc = acc + eseg
                    sent = _compress_sent(acc, grp.block, compress,
                                          kmode, kflag)
                    if wi is None:
                        col = None
                        partial = jnp.sum(sent, axis=0)
                    else:
                        w_l = wloc[wi]
                        wsum = lax.psum(jnp.sum(w_l), shard.data_axis,
                                        axis_index_groups=gidx)
                        scale = jnp.where(wsum > 0, denom / wsum, 0.0)
                        col = (w_l * scale).astype(jnp.float32)[:, None]
                        partial = jnp.sum(sent * col, axis=0)
                    tot = _wire_allreduce(partial, compress.quant, grp.block,
                                          shard, gidx, nsum)
                    m = (tot / denom)[None]
                    upd = (jnp.broadcast_to(m, seg.shape) if col is None
                           else jnp.where(col > 0, m,
                                          seg.astype(jnp.float32)))
                    b = lax.dynamic_update_slice(b, upd.astype(b.dtype),
                                                 (0, a))
                    if eseg is not None:
                        new_e = acc - sent
                        if wi is not None:
                            new_e = jnp.where((wloc[wi] > 0)[:, None],
                                              new_e, eseg)
                        e = lax.dynamic_update_slice(
                            e, new_e.astype(e.dtype), (0, a))
                    continue
                if wi is None:
                    tot = _allreduce(jnp.sum(seg, axis=0), shard, gidx)
                    upd = jnp.broadcast_to((tot / denom)[None].astype(b.dtype),
                                           seg.shape)
                else:
                    w_l = wloc[wi]
                    wsum = lax.psum(jnp.sum(w_l), shard.data_axis,
                                    axis_index_groups=gidx)
                    scale = jnp.where(wsum > 0, denom / wsum, 0.0)
                    col = (w_l * scale).astype(seg.dtype)[:, None]
                    tot = _allreduce(jnp.sum(seg * col, axis=0), shard, gidx)
                    upd = jnp.where(col > 0, (tot / denom)[None], seg)
                b = lax.dynamic_update_slice(b, upd.astype(b.dtype), (0, a))
            return (b, e) if has_ef else b

        pb = shard.buffer_spec
        pw = PartitionSpec(shard.data_axis)
        ins = ((pb,) + ((pb,) if has_ef else ())
               + (pw,) * (len(ws) + len(cops)))
        res = shard_map(body, mesh=shard.mesh, in_specs=ins,
                        out_specs=((pb, pb) if has_ef else pb),
                        check_rep=False)(
            buf, *((ebuf,) if has_ef else ()), *ws, *cops)
        if has_ef:
            out.append(res[0])
            ef_out.append(res[1])
        else:
            out.append(res)
            ef_out.append(None)
    if compress is None:
        return tuple(out)
    return tuple(out), (tuple(ef_out) if has_ef else ())


# ---------------------------------------------------------------------------
# Telemetry metrics (read-only side outputs — never touch a trajectory)
# ---------------------------------------------------------------------------

def _section_cols(spec: FlatSpec, grp: _Group) -> dict:
    """Absolute column ranges of every section in one (possibly shard-major)
    buffer: ``{section_index: [(start, stop), ...]}``.  Extents cover one
    shard chunk, so a sharded layout repeats them at every chunk offset."""
    chunk = grp.padded // spec.shards
    out: dict = {}
    for s, a, b in grp.extents:
        for j in range(spec.shards):
            out.setdefault(s, []).append((j * chunk + a, j * chunk + b))
    return out


def section_norms(spec: FlatSpec, bufs, *, mask=None, prefix="norm") -> dict:
    """Per-section l2 norms of flat [M, N] buffers (telemetry side output):
    ``{"<prefix>/<section>": scalar}``.  ``mask`` [M] restricts the sum to
    participant rows (selected with ``where`` — a zero row never multiplies
    a NaN).  Section padding is zero by construction and contributes
    nothing; runs at the jit level outside ``shard_map``, so sharded
    buffers reduce through XLA's own partitioning."""
    names = spec.sections or ("all",)
    sq: dict = {}
    for grp, buf in zip(spec.groups, bufs):
        x = buf.astype(jnp.float32)
        if mask is not None:
            mcol = (mask > 0).reshape(mask.shape + (1,) * (x.ndim - 1))
            x = jnp.where(mcol, x, 0.0)
        for s, runs in _section_cols(spec, grp).items():
            for a, b in runs:
                sq[s] = sq.get(s, 0.0) + jnp.sum(jnp.square(x[..., a:b]))
    return {f"{prefix}/{names[s]}": jnp.sqrt(v) for s, v in sorted(sq.items())}


def section_drift(spec: FlatSpec, bufs, *, mask=None,
                  prefix="drift") -> dict:
    """Per-section client-drift dispersion (telemetry side output): the rms
    distance of participant rows to the participants' mean row — the
    non-IID heterogeneity term, measured on the LOCAL iterates before
    averaging.  Same masking/sharding posture as :func:`section_norms`."""
    names = spec.sections or ("all",)
    M = bufs[0].shape[0]
    w = (jnp.ones((M,), jnp.float32) if mask is None
         else (mask > 0).astype(jnp.float32))
    cnt = jnp.maximum(jnp.sum(w), 1.0)
    wcol = w[:, None]
    sq: dict = {}
    for grp, buf in zip(spec.groups, bufs):
        x = jnp.where(wcol > 0, buf.astype(jnp.float32), 0.0)
        for s, runs in _section_cols(spec, grp).items():
            for a, b in runs:
                seg = x[..., a:b]
                m = jnp.sum(seg * wcol, axis=0, keepdims=True) / cnt
                sq[s] = sq.get(s, 0.0) + jnp.sum(
                    jnp.square(seg - m) * wcol)
    return {f"{prefix}/{names[s]}": jnp.sqrt(v / cnt)
            for s, v in sorted(sq.items())}


def quant_roundtrip_err(bufs, block: int, quant) -> jnp.ndarray:
    """l2 norm of the quantization round-trip error over ``bufs`` — the
    value error the next compressed send of these buffers would incur
    (telemetry side output; always the jnp lowering, which is bit-identical
    to the Pallas pack/unpack kernels)."""
    sq = 0.0
    for b in bufs:
        x = b.astype(jnp.float32)
        d = _quant_dequant(x, block, quant, "jnp", False) - x
        sq = sq + jnp.sum(jnp.square(d))
    return jnp.sqrt(sq)


def health_screen(spec: FlatSpec, bufs, mask, corrupt,
                  robust: RobustCfg) -> jnp.ndarray:
    """Recomputed health-screen verdicts for telemetry: [M] f32, 1 where a
    participant would FAIL the screen on what it sends this round.  The
    non-finite component reproduces the reduction's screen exactly; the
    z-score is taken over whole-row norms rather than per section run (an
    audit approximation — the guarded reduction itself is untouched)."""
    x = jnp.concatenate([b.astype(jnp.float32) for b in bufs], axis=-1)
    x = _corrupt_rows(x, corrupt)
    M = x.shape[0]
    p = jnp.ones((M,), bool) if mask is None else mask > 0
    h = _health_mask(x, p, robust)
    return p.astype(jnp.float32) * (1.0 - h)
