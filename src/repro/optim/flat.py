"""Flat-buffer optimizer substrate for the STORM-accelerated train steps.

FedBiOAcc runs STORM variance reduction on *three* parallel sequences — the
body/ν, head/ω and auxiliary/q pairs — over the full parameter tree every
local step. Expressed as per-leaf ``jax.tree.map`` chains that is ~9
elementwise passes per step, each dispatched once per leaf; on accelerators
it is pure HBM-bandwidth traffic and the dominant "memory term" of the step.

This module flattens the (x, y, u) trees and their momenta **once at init**
into contiguous per-dtype buffers and keeps all training state flat across
steps (callers jit with buffer donation). Pytree views are materialized only
at oracle / eval / checkpoint boundaries via ``unflatten_tree``.

Layout (``FlatSpec``):

* Leaves are grouped by dtype → one 1-D buffer per dtype.
* Within a buffer, leaves are ordered by **section** (e.g. ``("x","y","u")``)
  and each section is zero-padded up to the kernel tile size ``block``, so
  every tile belongs to exactly one section. ``_Group.section_ids`` maps
  tile → section; at step time the per-section (lr, decay) scalars are
  gathered into per-tile SMEM tables for the triple-sequence Pallas kernel
  (``storm3_step_flat`` / ``storm3_update_flat``).
* Leaf offset metadata (``_Leaf``) makes flatten/unflatten pure
  reshape+concat / slice+reshape — no data-dependent work.
* Buffers may carry leading batch dims (``batch_dims=1`` for the federated
  client axis M → buffers are [M, N]); ``client_mean`` on such a buffer is
  ONE reduction per dtype instead of one per leaf.
* ``client_mean_masked`` supports *partial* communication (the local-lower
  algorithms: average x/ν, keep y/ω private): a per-tile comm mask derived
  from ``section_ids`` collapses to contiguous slices, so the communicated
  sections cost one sliced reduction each while private sections pass
  through bit-identical and never enter an all-reduce.
* **Participation** (``repro.federation.participation``): the same reductions
  take per-client ``weights`` ([M], zero = non-participant) — the mean is
  over participants only (weighted by data size / staleness discounts), and
  non-participant rows pass through bit-identical, exactly like private
  sections do along the section axis.  The weighted mean is computed as
  ``mean(x · w · (M/Σw))`` so that all-ones weights reproduce the unweighted
  path bit-for-bit.  The fused updates take the matching per-client ``mask``:
  a non-participant's tiles get lr = 0 (and STORM decay / heavy-ball β pinned
  to 1), which — together with the engine zeroing its oracle contributions —
  freezes its variable AND momentum buffers bit-exact through the round.

The padding tiles are zero and stay zero under every substrate op (the
update is elementwise and 0 − lr·0 = 0), so round-trips are exact.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.storm.kernel import (BLOCK, momsgd3_step_flat,
                                        momsgd3_step_flat_jnp,
                                        sgd3_step_flat, sgd3_step_flat_jnp,
                                        storm3_step_flat,
                                        storm3_step_flat_jnp,
                                        storm3_update_flat,
                                        storm3_update_flat_jnp)


class _Leaf(NamedTuple):
    index: int          # position in the spec treedef's leaf order
    shape: tuple        # original leaf shape (without batch dims)
    size: int
    offset: int         # element offset inside the dtype buffer


class _Group(NamedTuple):
    dtype: Any                  # np.dtype of the buffer
    leaves: tuple               # of _Leaf, ascending offset
    padded: int                 # buffer length — multiple of block
    block: int
    section_ids: np.ndarray     # [padded // block] int32 — tile → section


class FlatSpec(NamedTuple):
    treedef: Any
    num_leaves: int
    sections: tuple             # section names, () when unsectioned
    groups: tuple               # of _Group


def _round_up(n: int, block: int) -> int:
    return n + (-n) % block


def make_spec(tree, *, sections: Sequence[str] | None = None,
              block: int = BLOCK) -> FlatSpec:
    """Build the flat layout for ``tree`` (arrays or ShapeDtypeStructs).

    ``sections``: top-level dict keys of ``tree`` whose subtrees must occupy
    contiguous, tile-aligned runs of each dtype buffer (the x|y|u segments of
    the triple-sequence kernel). Buffer order follows ``sections``, not the
    treedef's internal key order.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if sections is None:
        sec_names = ()
        sec_of_leaf = [0] * len(leaves)
        n_sections = 1
    else:
        sec_names = tuple(sections)
        # label every leaf with its section index, in treedef leaf order
        # (dict flattening sorts keys the same way for tree and labels)
        sec_of_leaf = jax.tree.leaves(
            {k: jax.tree.map(lambda _, s=i: s, tree[k])
             for i, k in enumerate(sec_names)})
        assert len(sec_of_leaf) == len(leaves), "sections must cover the tree"
        n_sections = len(sec_names)

    # dtype groups, ordered by first appearance in (section, leaf) order
    order = sorted(range(len(leaves)), key=lambda i: (sec_of_leaf[i], i))
    dtypes: list = []
    for i in order:
        dt = np.dtype(leaves[i].dtype)
        if dt not in dtypes:
            dtypes.append(dt)

    groups = []
    for dt in dtypes:
        lfs, sec_ids, offset = [], [], 0
        for s in range(n_sections):
            start = offset
            for i in order:
                if sec_of_leaf[i] != s or np.dtype(leaves[i].dtype) != dt:
                    continue
                shape = tuple(leaves[i].shape)
                size = int(np.prod(shape, dtype=np.int64)) if shape else 1
                lfs.append(_Leaf(i, shape, size, offset))
                offset += size
            if offset > start:     # section present in this dtype group
                offset = _round_up(offset, block)
                sec_ids += [s] * ((offset - start) // block)
        if not lfs:
            continue
        groups.append(_Group(dt, tuple(lfs), offset, block,
                             np.asarray(sec_ids, np.int32)))
    return FlatSpec(treedef, len(leaves), sec_names, tuple(groups))


def flatten_tree(spec: FlatSpec, tree, *, batch_dims: int = 0, dtype=None):
    """Pack ``tree`` into the spec's flat buffers (tuple, one per dtype).

    Leaves may carry ``batch_dims`` leading axes (shared across the tree);
    buffers then have shape ``batch_shape + (padded,)``. ``dtype`` overrides
    every buffer's dtype (same layout) — used to keep momenta and oracle
    gradients in f32 buffers alongside low-precision variable buffers.
    """
    leaves = spec.treedef.flatten_up_to(tree)
    bufs = []
    for grp in spec.groups:
        out_dt = dtype if dtype is not None else grp.dtype
        batch_shape = tuple(
            jnp.shape(leaves[grp.leaves[0].index])[:batch_dims])
        parts, cursor = [], 0
        for lf in grp.leaves:
            if lf.offset > cursor:
                parts.append(jnp.zeros(batch_shape + (lf.offset - cursor,),
                                       out_dt))
            x = jnp.asarray(leaves[lf.index], out_dt)
            parts.append(x.reshape(batch_shape + (-1,)))
            cursor = lf.offset + lf.size
        if cursor < grp.padded:
            parts.append(jnp.zeros(batch_shape + (grp.padded - cursor,),
                                   out_dt))
        bufs.append(parts[0] if len(parts) == 1
                    else jnp.concatenate(parts, axis=-1))
    return tuple(bufs)


def unflatten_tree(spec: FlatSpec, bufs):
    """Materialize the pytree view of flat buffers (slice + reshape only)."""
    leaves = [None] * spec.num_leaves
    for grp, buf in zip(spec.groups, bufs):
        batch_shape = tuple(buf.shape[:-1])
        for lf in grp.leaves:
            seg = buf[..., lf.offset:lf.offset + lf.size]
            leaves[lf.index] = seg.reshape(batch_shape + lf.shape)
    return spec.treedef.unflatten(leaves)


def zeros_buffers(spec: FlatSpec, *, batch_shape: tuple = ()):
    return tuple(jnp.zeros(batch_shape + (g.padded,), g.dtype)
                 for g in spec.groups)


def _per_tile(grp: _Group, buf, table):
    """Per-section scalar table → per-tile SMEM array for ``buf``
    (section pattern repeats over any leading batch dims)."""
    reps = int(np.prod(buf.shape[:-1], dtype=np.int64)) if buf.ndim > 1 else 1
    seg = np.tile(grp.section_ids, reps)
    return jnp.stack(table)[seg]


def _mask_per_tile(grp: _Group, buf, mask):
    """Per-client participation mask [M] → per-tile array aligned with
    ``_per_tile``'s layout (client-major: client m owns a contiguous run of
    ``padded // block`` tiles)."""
    assert buf.ndim >= 2, "participation mask needs a leading client axis"
    reps = int(np.prod(buf.shape[:-1], dtype=np.int64))
    tiles = grp.padded // grp.block
    assert mask.shape == (reps,), (mask.shape, reps)
    return jnp.repeat(mask.astype(jnp.float32), tiles)


def _gate(grp: _Group, buf, lr_tiles, decay_tiles, mask, frozen_decay: float):
    """Gate per-tile (lr, decay|β) tables with the participation mask:
    non-participants get lr = 0 and decay pinned to ``frozen_decay`` (1.0
    freezes STORM/heavy-ball momenta bit-exact once their oracle
    contributions are zeroed)."""
    if mask is None:
        return lr_tiles, decay_tiles
    mt = _mask_per_tile(grp, buf, mask)
    lr_tiles = lr_tiles * mt
    if decay_tiles is not None:
        decay_tiles = jnp.where(mt > 0, decay_tiles,
                                jnp.float32(frozen_decay))
    return lr_tiles, decay_tiles


def mask_buffers(bufs, mask):
    """Zero non-participant rows of [M, N] buffers (the "oracle skipped"
    half of the freeze: under vmap/SPMD every client computes, but a
    non-participant's gradients must not reach its momentum).  A ``where``
    select, not a multiply: participants pass through bit-identical, and a
    non-finite gradient on a skipped client's batch still zeroes out
    (0 · inf would poison the frozen momentum with NaN)."""
    if mask is None:
        return bufs
    return tuple(jnp.where(mask[:, None] > 0, b, jnp.zeros((), b.dtype))
                 for b in bufs)


def _dispatch(interpret):
    """Pick the lowering for the triple-sequence update.

    ``interpret=None`` (the default) → the Pallas kernel, compiled, on TPU;
    the bit-identical jnp lowering elsewhere (the interpreter validates the
    kernel but is far slower than XLA's fused loops). An explicit True/False
    forces the Pallas kernel with that interpret flag.
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return "pallas", False
        return "jnp", None
    return "pallas", interpret


def storm_partial_step(spec: FlatSpec, var_bufs, mom_bufs, g_old_bufs,
                       lrs, decays, *, mask=None,
                       interpret: bool | None = None):
    """One fused triple-sequence launch per dtype buffer:

        v_new  = v − lr_sec·m            (variable step, entering momentum)
        m_part = decay_sec·(m − g_old)   (partial STORM momentum)

    ``lrs``/``decays``: one scalar per section (traced OK). The correction
    ``m_part + g_new`` is a single elementwise add once the new-iterate
    oracle exists (after communication).

    ``mask``: optional per-client participation mask [M] — non-participants'
    tiles run with lr = 0 and decay = 1, so (with ``g_old`` zeroed via
    :func:`mask_buffers`) their variable and momentum rows are frozen
    bit-exact inside the same fused launch.
    """
    mode, flag = _dispatch(interpret)
    out_v, out_m = [], []
    for grp, v, m, go in zip(spec.groups, var_bufs, mom_bufs, g_old_bufs):
        lr_t, dc_t = _gate(grp, v, _per_tile(grp, v, lrs),
                           _per_tile(grp, v, decays), mask, 1.0)
        args = (v.reshape(-1), m.reshape(-1), go.reshape(-1), lr_t, dc_t)
        if mode == "pallas":
            vn, mn = storm3_step_flat(*args, block=grp.block, interpret=flag)
        else:
            vn, mn = storm3_step_flat_jnp(*args, block=grp.block)
        out_v.append(vn.reshape(v.shape))
        out_m.append(mn.reshape(m.shape))
    return tuple(out_v), tuple(out_m)


def storm_full_update(spec: FlatSpec, var_bufs, mom_bufs, g_new_bufs,
                      g_old_bufs, lrs, decays, *,
                      interpret: bool | None = None):
    """Full fused update (v − lr·m, g_new + decay·(m − g_old)) — usable when
    both oracle values are already in hand (benchmarks, single-shot tests)."""
    mode, flag = _dispatch(interpret)
    out_v, out_m = [], []
    for grp, v, m, gn, go in zip(spec.groups, var_bufs, mom_bufs,
                                 g_new_bufs, g_old_bufs):
        args = (v.reshape(-1), m.reshape(-1), gn.reshape(-1), go.reshape(-1),
                _per_tile(grp, v, lrs), _per_tile(grp, v, decays))
        if mode == "pallas":
            vn, mn = storm3_update_flat(*args, block=grp.block,
                                        interpret=flag)
        else:
            vn, mn = storm3_update_flat_jnp(*args, block=grp.block)
        out_v.append(vn.reshape(v.shape))
        out_m.append(mn.reshape(m.shape))
    return tuple(out_v), tuple(out_m)


def momentum_sgd_step(spec: FlatSpec, var_bufs, mom_bufs, g_bufs,
                      lrs, betas, *, mask=None,
                      interpret: bool | None = None):
    """One fused heavy-ball launch per dtype buffer:

        m_new = β_sec·m + g        (momentum update — FedAvg ordering)
        v_new = v − lr_sec·m_new   (variable step with the *updated* momentum)

    Momentum-less specs (β = 0 everywhere, no momentum state) should use
    :func:`sgd_step` instead — same variable result without the dead
    momentum stream.

    ``mask``: optional per-client participation mask [M] — non-participants
    run with lr = 0 and β = 1 (identity momentum; pair with zeroed ``g`` via
    :func:`mask_buffers` for a bit-exact freeze).
    """
    mode, flag = _dispatch(interpret)
    out_v, out_m = [], []
    for grp, v, m, gb in zip(spec.groups, var_bufs, mom_bufs, g_bufs):
        lr_t, bt_t = _gate(grp, v, _per_tile(grp, v, lrs),
                           _per_tile(grp, v, betas), mask, 1.0)
        args = (v.reshape(-1), m.reshape(-1), gb.reshape(-1), lr_t, bt_t)
        if mode == "pallas":
            vn, mn = momsgd3_step_flat(*args, block=grp.block, interpret=flag)
        else:
            vn, mn = momsgd3_step_flat_jnp(*args, block=grp.block)
        out_v.append(vn.reshape(v.shape))
        out_m.append(mn.reshape(m.shape))
    return tuple(out_v), tuple(out_m)


def sgd_step(spec: FlatSpec, var_bufs, g_bufs, lrs, *, mask=None,
             interpret: bool | None = None):
    """One fused plain-SGD launch per dtype buffer: v_new = v − lr_sec·g.

    The β = 0 fast path for momentum-less specs (FedBiO / FedBiO-Local):
    2 reads + 1 write per element — a pallas_call's outputs are opaque to
    XLA DCE, so the heavy-ball kernel would pay a full dead momentum write.

    ``mask``: optional per-client participation mask [M] — non-participants'
    tiles run with lr = 0 (v − 0·g = v, bit-exact freeze).
    """
    mode, flag = _dispatch(interpret)
    out_v = []
    for grp, v, gb in zip(spec.groups, var_bufs, g_bufs):
        lr_t, _ = _gate(grp, v, _per_tile(grp, v, lrs), None, mask, 1.0)
        args = (v.reshape(-1), gb.reshape(-1), lr_t)
        if mode == "pallas":
            vn = sgd3_step_flat(*args, block=grp.block, interpret=flag)
        else:
            vn = sgd3_step_flat_jnp(*args, block=grp.block)
        out_v.append(vn.reshape(v.shape))
    return tuple(out_v)


def buffers_add(a, b):
    """Elementwise a + b over buffer tuples (the STORM correction add)."""
    return tuple(x + y for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# Section-masked communication
# ---------------------------------------------------------------------------

def _weight_col(x, w):
    """Per-client weights → a broadcastable [M, 1, ...] column, rescaled so
    the *plain mean* of ``x · col`` is the participation-weighted mean:

        col_m = w_m · (M / Σ w)   ⇒   mean_m(x_m · col_m) = Σ w_m x_m / Σ w

    The rescale-into-the-mean form is what makes all-ones weights a
    bit-identical no-op (col = 1.0 exactly, x · 1.0 = x, then the same
    ``jnp.mean`` as the unweighted path).  Empty groups (Σw = 0) scale to 0;
    callers pass non-participants through with a ``where`` on ``col > 0``.
    """
    wsum = jnp.sum(w, axis=-1, keepdims=True)
    scale = jnp.where(wsum > 0, w.shape[-1] / wsum, 0.0)
    col = (w * scale).astype(x.dtype)
    return col.reshape(col.shape + (1,) * (x.ndim - col.ndim))


def _bcast_mean(x, w=None):
    """Full client mean over the leading axis, broadcast back (the paper's
    communication round — one all-reduce under pjit).  Mirrors
    ``core.tree_util.client_mean`` at array level; importing tree_util here
    would close an import cycle (optim.flat ← core ← optim.sequences).

    ``w``: optional per-client weights [M] (zero = non-participant): the mean
    is over participants only and non-participant rows pass through
    bit-identical (selected *around* the reduction, like private sections).
    """
    if w is None:
        return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)
    col = _weight_col(x, w)
    m = jnp.broadcast_to(jnp.mean(x * col, axis=0, keepdims=True), x.shape)
    return jnp.where(col > 0, m, x)


def _bcast_mean_grouped(x, num_groups: int, w=None):
    """Pod-local grouped mean over contiguous client groups (hierarchical
    multi-pod schedule — the all-reduce stays on the intra-pod ICI).
    ``w`` as in :func:`_bcast_mean`, applied within each group."""
    M = x.shape[0]
    g = x.reshape((num_groups, M // num_groups) + x.shape[1:])
    if w is None:
        m = jnp.mean(g, axis=1, keepdims=True)
        return jnp.broadcast_to(m, g.shape).reshape(x.shape)
    col = _weight_col(g, w.reshape(num_groups, M // num_groups))
    m = jnp.broadcast_to(jnp.mean(g * col, axis=1, keepdims=True), g.shape)
    return jnp.where(col > 0, m, g).reshape(x.shape)


def client_mean_masked(spec: FlatSpec, bufs, modes, *, num_groups: int = 2,
                       weights=None):
    """Section-masked client communication over flat [M, N] buffers.

    ``modes``: one entry per section (aligned with ``spec.sections``; a
    single entry for unsectioned specs), each ``"none"`` (private — the
    section must not be communicated), ``"mean"`` (full client mean) or
    ``"group"`` (pod-local grouped mean over ``num_groups`` groups).

    ``weights``: optional participation weights — one [M] array shared by
    every section, or a tuple of per-section [M] arrays (staleness-discounted
    sequences).  Zero-weight clients are non-participants: the mean is taken
    over participants only and their rows pass through bit-identical.

    Sections are contiguous tile-aligned runs of each dtype buffer
    (``_Group.section_ids``), so the per-tile comm mask collapses to
    contiguous same-mode slices: each communicated run is ONE sliced
    reduction, and ``"none"`` runs are passed through as unreduced slices of
    the input buffer — private sections are bit-identical by construction
    and never enter an all-reduce (no wasted cross-client traffic).  Runs
    merge across adjacent sections only when both the mode and the weight
    array coincide.
    """
    n_sections = max(len(spec.sections), 1)
    assert len(modes) == n_sections, (modes, spec.sections)
    assert all(m in ("none", "mean", "group") for m in modes), modes
    if isinstance(weights, (tuple, list)):
        assert len(weights) == n_sections, (len(weights), n_sections)
        w_of_sec = tuple(weights)
    else:
        w_of_sec = (weights,) * n_sections
    out = []
    for grp, buf in zip(spec.groups, bufs):
        assert buf.ndim >= 2, "client_mean_masked needs a leading client axis"
        runs = []                      # [mode, weight, start elem, stop elem]
        for tile, sec in enumerate(grp.section_ids):
            mode, w = modes[int(sec)], w_of_sec[int(sec)]
            if runs and runs[-1][0] == mode and (
                    runs[-1][1] is w or mode == "none"):
                runs[-1][3] += grp.block
            else:
                runs.append([mode, w, tile * grp.block,
                             (tile + 1) * grp.block])
        parts = []
        for mode, w, start, stop in runs:
            seg = buf[..., start:stop]
            if mode == "none":
                parts.append(seg)
            elif mode == "mean":
                parts.append(_bcast_mean(seg, w))
            else:
                parts.append(_bcast_mean_grouped(seg, num_groups, w))
        out.append(parts[0] if len(parts) == 1
                   else jnp.concatenate(parts, axis=-1))
    return tuple(out)
