from repro.kernels.storm.ops import storm_update  # noqa: F401
