"""Fused STORM momentum + SGD update — Pallas TPU kernel.

FedBiOAcc triples the elementwise optimizer traffic (three momentum sequences
over the full parameter tree, each needing two oracle values). Unfused, the
update chain

    p_new = p − lr·m                          (variable step, Alg. 2 line 4)
    m_new = g_new + decay·(m − g_old)         (STORM correction, lines 10-12)

reads p, m, g_new, g_old and writes two intermediates plus two outputs — on
TPU this is purely HBM-bandwidth bound. The kernel streams all four inputs
through VMEM once and writes the two outputs: 6 HBM transfers vs 10 for the
naive chain (XLA usually fuses some of it; the kernel makes the floor
explicit and is the §Perf "memory term" optimization for the train step).

Layout: inputs are flattened to [N] and tiled as (BLOCK,) VMEM blocks on a 1D
grid. Scalars (lr, decay) arrive via scalar prefetch (SMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 64 * 1024   # elements per VMEM tile (bf16: 128 KiB/input, 4 inputs
                    # + 2 outputs ≈ 768 KiB of VMEM — comfortably under 16 MiB)


def _storm_kernel(scal_ref, p_ref, m_ref, gnew_ref, gold_ref,
                  pout_ref, mout_ref):
    lr = scal_ref[0]
    decay = scal_ref[1]
    p = p_ref[...]
    m = m_ref[...].astype(jnp.float32)
    g_new = gnew_ref[...].astype(jnp.float32)
    g_old = gold_ref[...].astype(jnp.float32)
    # variable step with the *entering* momentum (Alg. 2 ordering)
    pout_ref[...] = (p.astype(jnp.float32) - lr * m).astype(p_ref.dtype)
    mout_ref[...] = (g_new + decay * (m - g_old)).astype(mout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def storm_update_flat(p, m, g_new, g_old, lr, decay, *, interpret: bool = True):
    """p, m, g_new, g_old: flat [N] arrays (N a multiple of BLOCK)."""
    n = p.shape[0]
    assert n % BLOCK == 0, n
    grid = (n // BLOCK,)
    scal = jnp.stack([jnp.asarray(lr, jnp.float32),
                      jnp.asarray(decay, jnp.float32)])
    block = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _storm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # (lr, decay) scalars
            block, block, block, block,
        ],
        out_specs=[block, block],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=interpret,
    )(scal, p, m, g_new, g_old)
