"""Fused STORM momentum + SGD update — Pallas TPU kernel.

FedBiOAcc triples the elementwise optimizer traffic (three momentum sequences
over the full parameter tree, each needing two oracle values). Unfused, the
update chain

    p_new = p − lr·m                          (variable step, Alg. 2 line 4)
    m_new = g_new + decay·(m − g_old)         (STORM correction, lines 10-12)

reads p, m, g_new, g_old and writes two intermediates plus two outputs — on
TPU this is purely HBM-bandwidth bound. The kernel streams all four inputs
through VMEM once and writes the two outputs: 6 HBM transfers vs 10 for the
naive chain (XLA usually fuses some of it; the kernel makes the floor
explicit and is the §Perf "memory term" optimization for the train step).

Two kernel families live here:

* ``storm_update_flat`` — the original single-sequence update: one (lr,
  decay) pair for the whole buffer.
* ``storm3_update_flat`` / ``storm3_step_flat`` — the **triple-sequence**
  update used by the flat-buffer substrate (``repro.optim.flat``). The three
  FedBiOAcc sequences x/ν, y/ω, u/q are laid out as contiguous,
  tile-aligned segments of ONE flat buffer; per-*block* (lr, decay) scalars
  arrive through SMEM and are indexed with ``pl.program_id``, so a single
  launch streams all three sequences with their own hyper-parameters.
  ``storm3_step_flat`` is the half-step variant (variable step + partial
  momentum ``decay·(m − g_old)``) used inside the real train step, where the
  new-iterate oracle — and hence ``g_new`` — only exists after the updated
  variables have been communicated.
* ``momsgd3_step_flat`` / ``sgd3_step_flat`` — the heavy-ball and plain-SGD
  companions used by the sequence-spec engine (``repro.optim.sequences``)
  for the non-STORM algorithms: ``m' = β·m + g`` then ``p' = p − lr·m'``
  (FedAvg applies the *updated* momentum), and the momentum-less
  ``p' = p − lr·g`` (FedBiO / FedBiO-Local — 2 reads + 1 write, no dead
  momentum stream).

Layout: inputs are flattened to [N] and tiled as (BLOCK,) VMEM blocks on a 1D
grid. Scalars (lr, decay — one pair, or one pair per block) arrive via
scalar prefetch (SMEM). ``interpret`` defaults to auto: Pallas interpreter
everywhere except on a real TPU backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 64 * 1024   # elements per VMEM tile (bf16: 128 KiB/input, 4 inputs
                    # + 2 outputs ≈ 768 KiB of VMEM — comfortably under 16 MiB)


def _resolve_interpret(interpret):
    """interpret=None → auto: compile on TPU, interpret elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _storm_kernel(scal_ref, p_ref, m_ref, gnew_ref, gold_ref,
                  pout_ref, mout_ref):
    lr = scal_ref[0]
    decay = scal_ref[1]
    p = p_ref[...]
    m = m_ref[...].astype(jnp.float32)
    g_new = gnew_ref[...].astype(jnp.float32)
    g_old = gold_ref[...].astype(jnp.float32)
    # variable step with the *entering* momentum (Alg. 2 ordering)
    pout_ref[...] = (p.astype(jnp.float32) - lr * m).astype(p_ref.dtype)
    mout_ref[...] = (g_new + decay * (m - g_old)).astype(mout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def storm_update_flat(p, m, g_new, g_old, lr, decay, *,
                      interpret: bool | None = None):
    """p, m, g_new, g_old: flat [N] arrays (N a multiple of BLOCK)."""
    n = p.shape[0]
    assert n % BLOCK == 0, n
    grid = (n // BLOCK,)
    scal = jnp.stack([jnp.asarray(lr, jnp.float32),
                      jnp.asarray(decay, jnp.float32)])
    block = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _storm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # (lr, decay) scalars
            block, block, block, block,
        ],
        out_specs=[block, block],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=_resolve_interpret(interpret),
    )(scal, p, m, g_new, g_old)


# ---------------------------------------------------------------------------
# Triple-sequence kernels (flat-buffer substrate)
# ---------------------------------------------------------------------------

def _storm3_kernel(lrs_ref, decays_ref, p_ref, m_ref, gnew_ref, gold_ref,
                   pout_ref, mout_ref):
    i = pl.program_id(0)
    lr = lrs_ref[i]
    decay = decays_ref[i]
    m = m_ref[...].astype(jnp.float32)
    g_new = gnew_ref[...].astype(jnp.float32)
    g_old = gold_ref[...].astype(jnp.float32)
    pout_ref[...] = (p_ref[...].astype(jnp.float32) - lr * m).astype(pout_ref.dtype)
    mout_ref[...] = (g_new + decay * (m - g_old)).astype(mout_ref.dtype)


def _storm3_step_kernel(lrs_ref, decays_ref, p_ref, m_ref, gold_ref,
                        pout_ref, mout_ref):
    i = pl.program_id(0)
    lr = lrs_ref[i]
    decay = decays_ref[i]
    m = m_ref[...].astype(jnp.float32)
    g_old = gold_ref[...].astype(jnp.float32)
    pout_ref[...] = (p_ref[...].astype(jnp.float32) - lr * m).astype(pout_ref.dtype)
    mout_ref[...] = (decay * (m - g_old)).astype(mout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def storm3_update_flat(p, m, g_new, g_old, lrs, decays, *,
                       block: int = BLOCK, interpret: bool | None = None):
    """Full triple-sequence fused STORM update on one flat buffer.

    p, m, g_new, g_old: [N] with N a multiple of ``block``; segment
    boundaries are block-aligned. lrs, decays: [N // block] per-block
    hyper-parameters (constant within a segment), read from SMEM.
    """
    n = p.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    assert lrs.shape == decays.shape == grid, (lrs.shape, grid)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.pallas_call(
        _storm3_kernel,
        grid=grid,
        in_specs=[smem, smem, bspec, bspec, bspec, bspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=_resolve_interpret(interpret),
    )(lrs.astype(jnp.float32), decays.astype(jnp.float32), p, m, g_new, g_old)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def storm3_step_flat(p, m, g_old, lrs, decays, *,
                     block: int = BLOCK, interpret: bool | None = None):
    """Half-step: p_new = p − lr·m ; m_part = decay·(m − g_old).

    This is the launch the real FedBiOAcc step uses — the new-iterate
    gradient is only available after communication, so the STORM correction
    ``m_part + g_new`` is completed by a single elementwise add later.
    """
    n = p.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    assert lrs.shape == decays.shape == grid, (lrs.shape, grid)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.pallas_call(
        _storm3_step_kernel,
        grid=grid,
        in_specs=[smem, smem, bspec, bspec, bspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=_resolve_interpret(interpret),
    )(lrs.astype(jnp.float32), decays.astype(jnp.float32), p, m, g_old)


# ---------------------------------------------------------------------------
# Heavy-ball / SGD triple-sequence kernels (sequence-spec engine, non-STORM
# algorithms). FedAvg applies the *updated* momentum (m' = β·m + g then
# p' = p − lr·m'), which neither storm3 kernel expresses: storm3_step uses
# the entering momentum for the variable step. Momentum-less specs (FedBiO /
# FedBiO-Local) take the dedicated sgd3 kernel instead — a pallas_call's
# outputs are opaque to XLA DCE, so reusing the heavy-ball kernel at β = 0
# would pay a full-size dead momentum write every step.
# ---------------------------------------------------------------------------

def _momsgd3_kernel(lrs_ref, betas_ref, p_ref, m_ref, g_ref,
                    pout_ref, mout_ref):
    i = pl.program_id(0)
    lr = lrs_ref[i]
    beta = betas_ref[i]
    m_new = beta * m_ref[...].astype(jnp.float32) + g_ref[...].astype(jnp.float32)
    pout_ref[...] = (p_ref[...].astype(jnp.float32) - lr * m_new).astype(pout_ref.dtype)
    mout_ref[...] = m_new.astype(mout_ref.dtype)


def _sgd3_kernel(lrs_ref, p_ref, g_ref, pout_ref):
    i = pl.program_id(0)
    lr = lrs_ref[i]
    pout_ref[...] = (p_ref[...].astype(jnp.float32)
                     - lr * g_ref[...].astype(jnp.float32)).astype(pout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sgd3_step_flat(p, g, lrs, *,
                   block: int = BLOCK, interpret: bool | None = None):
    """Plain fused SGD step p_new = p − lr·g — the β = 0 fast path of the
    momentum-less specs (FedBiO / FedBiO-Local): 2 reads + 1 write per
    element, no dead momentum output for the pallas_call to keep alive."""
    n = p.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    assert lrs.shape == grid, (lrs.shape, grid)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.pallas_call(
        _sgd3_kernel,
        grid=grid,
        in_specs=[smem, bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct((n,), p.dtype),
        interpret=_resolve_interpret(interpret),
    )(lrs.astype(jnp.float32), p, g)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def momsgd3_step_flat(p, m, g, lrs, betas, *,
                      block: int = BLOCK, interpret: bool | None = None):
    """Fused heavy-ball step: m_new = β·m + g ; p_new = p − lr·m_new.

    Same layout contract as the storm3 kernels: flat [N] buffers with
    block-aligned segment boundaries and per-block (lr, β) SMEM tables.
    """
    n = p.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    assert lrs.shape == betas.shape == grid, (lrs.shape, grid)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.pallas_call(
        _momsgd3_kernel,
        grid=grid,
        in_specs=[smem, smem, bspec, bspec, bspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=_resolve_interpret(interpret),
    )(lrs.astype(jnp.float32), betas.astype(jnp.float32), p, m, g)


# ---------------------------------------------------------------------------
# jnp lowerings of the triple-sequence updates — the ref.py oracles, jitted.
# The substrate (repro.optim.flat) dispatches here off-TPU: the Pallas
# interpreter exists for kernel validation, not speed, while these compile to
# a handful of fused XLA loops over the flat buffer (still far fewer passes
# than the per-leaf tree-map chain). Delegating keeps ref.py the single
# source of the jnp math, with the Pallas kernel as the independent
# implementation the test sweeps compare against.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block",))
def storm3_update_flat_jnp(p, m, g_new, g_old, lrs, decays, *, block: int):
    from repro.kernels.storm.ref import storm3_update_ref
    return storm3_update_ref(p, m, g_new, g_old, lrs, decays, block)


@functools.partial(jax.jit, static_argnames=("block",))
def storm3_step_flat_jnp(p, m, g_old, lrs, decays, *, block: int):
    from repro.kernels.storm.ref import storm3_step_ref
    return storm3_step_ref(p, m, g_old, lrs, decays, block)


@functools.partial(jax.jit, static_argnames=("block",))
def momsgd3_step_flat_jnp(p, m, g, lrs, betas, *, block: int):
    from repro.kernels.storm.ref import momsgd3_step_ref
    return momsgd3_step_ref(p, m, g, lrs, betas, block)


@functools.partial(jax.jit, static_argnames=("block",))
def sgd3_step_flat_jnp(p, g, lrs, *, block: int):
    from repro.kernels.storm.ref import sgd3_step_ref
    return sgd3_step_ref(p, g, lrs, block)
