"""Pure-jnp oracle for the fused STORM update kernel."""
from __future__ import annotations

import jax.numpy as jnp


def storm_update_ref(p, m, g_new, g_old, lr, decay):
    """Elementwise reference (fp32 accumulation, matching the kernel):

        p_new = p − lr·m
        m_new = g_new + decay·(m − g_old)
    """
    m32 = m.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * m32).astype(p.dtype)
    m_new = (g_new.astype(jnp.float32)
             + decay * (m32 - g_old.astype(jnp.float32))).astype(m.dtype)
    return p_new, m_new


def storm3_update_ref(p, m, g_new, g_old, lrs, decays, block):
    """Triple-sequence reference: per-block (lr, decay) scalars expanded to
    per-element, then the same fp32 elementwise update as the kernel.

    Single source of the jnp math — kernel.py's ``storm3_*_flat_jnp``
    lowerings (the off-TPU production path) delegate here, so the Pallas
    kernel stays the only independent implementation and the
    kernel-vs-ref test sweeps remain meaningful.
    """
    lr = jnp.repeat(jnp.asarray(lrs, jnp.float32), block)
    decay = jnp.repeat(jnp.asarray(decays, jnp.float32), block)
    m32 = m.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * m32).astype(p.dtype)
    m_new = (g_new.astype(jnp.float32)
             + decay * (m32 - g_old.astype(jnp.float32))).astype(m.dtype)
    return p_new, m_new


def sgd3_step_ref(p, g, lrs, block):
    """Plain SGD reference: p_new = p − lr·g (fp32 accumulation)."""
    lr = jnp.repeat(jnp.asarray(lrs, jnp.float32), block)
    return (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype)


def momsgd3_step_ref(p, m, g, lrs, betas, block):
    """Heavy-ball reference (fp32 accumulation, matching the kernel):

        m_new = β·m + g
        p_new = p − lr·m_new      (the *updated* momentum — FedAvg ordering;
                                   β = 0 degenerates to SGD: p − lr·g)
    """
    lr = jnp.repeat(jnp.asarray(lrs, jnp.float32), block)
    beta = jnp.repeat(jnp.asarray(betas, jnp.float32), block)
    m_new = beta * m.astype(jnp.float32) + g.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * m_new).astype(p.dtype)
    return p_new, m_new.astype(m.dtype)


def storm3_step_ref(p, m, g_old, lrs, decays, block):
    """Half-step reference: p − lr·m and the partial momentum
    decay·(m − g_old) (the correction add happens post-communication)."""
    lr = jnp.repeat(jnp.asarray(lrs, jnp.float32), block)
    decay = jnp.repeat(jnp.asarray(decays, jnp.float32), block)
    m32 = m.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * m32).astype(p.dtype)
    m_part = (decay * (m32 - g_old.astype(jnp.float32))).astype(m.dtype)
    return p_new, m_part


def quantpack_ref(x, block):
    """Per-tile symmetric int8 quantization of a flat [N] buffer
    (N a multiple of ``block``): each tile is scaled by its own
    absmax/127 and rounded (half-to-even, ``jnp.round`` — the kernel uses
    the same rounding so pack results are bit-identical).  A zero tile
    stores scale 0 and quantizes to zeros (the divisor is ``where``-guarded).

    Returns ``(q int8 [N], scales f32 [N // block])``.
    """
    t = x.astype(jnp.float32).reshape(-1, block)
    amax = jnp.max(jnp.abs(t), axis=-1)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(t / safe[:, None]), -127.0, 127.0).astype(jnp.int8)
    return q.reshape(x.shape), scale


def quantunpack_ref(q, scales, block):
    """Dequantize :func:`quantpack_ref` output back to f32 [N]:
    ``q · scale_tile`` (exact — each step is one f32 multiply)."""
    t = q.astype(jnp.float32).reshape(-1, block)
    return (t * scales.astype(jnp.float32)[:, None]).reshape(q.shape)
