"""Pure-jnp oracle for the fused STORM update kernel."""
from __future__ import annotations

import jax.numpy as jnp


def storm_update_ref(p, m, g_new, g_old, lr, decay):
    """Elementwise reference (fp32 accumulation, matching the kernel):

        p_new = p − lr·m
        m_new = g_new + decay·(m − g_old)
    """
    m32 = m.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * m32).astype(p.dtype)
    m_new = (g_new.astype(jnp.float32)
             + decay * (m32 - g_old.astype(jnp.float32))).astype(m.dtype)
    return p_new, m_new
