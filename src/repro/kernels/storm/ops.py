"""jit'd pytree wrapper around the fused STORM kernel.

Handles arbitrary pytrees: leaves are flattened, concatenated per-dtype,
padded to the kernel tile size, updated in one fused pass and scattered back.

This wrapper re-flattens on every call — fine for one-off updates and tests.
Hot training loops should use ``repro.optim.flat``, which flattens ONCE at
init and keeps the state flat across steps (the triple-sequence substrate).
``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.storm.kernel import BLOCK, storm_update_flat


def _flatten_group(leaves):
    flat = [l.reshape(-1) for l in leaves]
    sizes = [f.shape[0] for f in flat]
    cat = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
    pad = (-cat.shape[0]) % BLOCK
    if pad:
        cat = jnp.pad(cat, (0, pad))
    return cat, sizes, pad


def _unflatten_group(cat, sizes, pad, leaves):
    if pad:
        cat = cat[:-pad] if pad else cat
    out, off = [], 0
    for l, s in zip(leaves, sizes):
        out.append(cat[off:off + s].reshape(l.shape))
        off += s
    return out


def storm_update(params, mom, g_new, g_old, lr, decay, *,
                 interpret: bool | None = None):
    """Fused p_new = p − lr·m ; m_new = g_new + decay·(m − g_old) over pytrees.

    Leaves are grouped by dtype-pair and processed in single fused streams.
    Returns (params_new, mom_new) with the input structures.
    """
    p_leaves, treedef = jax.tree.flatten(params)
    m_leaves = treedef.flatten_up_to(mom)
    gn_leaves = treedef.flatten_up_to(g_new)
    go_leaves = treedef.flatten_up_to(g_old)

    groups = {}
    for i, (p, m) in enumerate(zip(p_leaves, m_leaves)):
        groups.setdefault((p.dtype, m.dtype), []).append(i)

    p_out = [None] * len(p_leaves)
    m_out = [None] * len(m_leaves)
    for (_, _), idxs in groups.items():
        pc, sizes, pad = _flatten_group([p_leaves[i] for i in idxs])
        mc, _, _ = _flatten_group([m_leaves[i] for i in idxs])
        gnc, _, _ = _flatten_group([jnp.asarray(gn_leaves[i], mc.dtype)
                                    for i in idxs])
        goc, _, _ = _flatten_group([jnp.asarray(go_leaves[i], mc.dtype)
                                    for i in idxs])
        pn, mn = storm_update_flat(pc, mc, gnc, goc, lr, decay,
                                   interpret=interpret)
        pn_leaves = _unflatten_group(pn, sizes, pad, [p_leaves[i] for i in idxs])
        mn_leaves = _unflatten_group(mn, sizes, pad, [m_leaves[i] for i in idxs])
        for j, i in enumerate(idxs):
            p_out[i] = pn_leaves[j]
            m_out[i] = mn_leaves[j]
    return treedef.unflatten(p_out), treedef.unflatten(m_out)
