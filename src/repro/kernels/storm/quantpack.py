"""Per-tile int8 pack/unpack — Pallas TPU kernel for compressed comm.

The compressed communication policies (``repro.optim.flat.CompressCfg``)
move the client reductions in a narrow dtype.  bf16 is a plain cast; int8
needs a per-tile scale: each ``block``-sized tile of the flat buffer is
quantized symmetrically to ``q = round(x / (absmax/127))`` with its scale
stored alongside (one f32 per tile — 4/block bytes of overhead per
element).  Per-TILE granularity is deliberate: tiles are the substrate's
section/shard quantum, so the very same tile boundaries exist on the
unsharded buffer and on every ``shard_map`` chunk, and the quantization
error is independent of how the buffer is partitioned.

Layout contract (same as the storm3 kernels): flat [N] input with N a
multiple of ``block``, 1-D grid of (block,) VMEM tiles.  ``quantpack_flat``
emits the int8 payload plus the [N // block] scale vector; ``quantunpack_flat``
consumes them (scales via SMEM, indexed by ``pl.program_id`` like the
per-tile lr/decay tables).  The ``*_jnp`` lowerings delegate to
``ref.quantpack_ref`` / ``ref.quantunpack_ref`` — the single source of the
jnp math — and are bit-identical to the kernels (same ``jnp.round``
half-to-even rounding, same ``where``-guarded zero-tile divisor), so the
substrate can dispatch per backend (``_dispatch`` in ``optim/flat.py``)
without changing a single communicated bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.storm.kernel import BLOCK, _resolve_interpret


def _quantpack_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / safe), -127.0, 127.0).astype(jnp.int8)
    s_ref[0] = scale


def _quantunpack_kernel(s_ref, q_ref, x_ref):
    i = pl.program_id(0)
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[i]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantpack_flat(x, *, block: int = BLOCK, interpret: bool | None = None):
    """Pack a flat f32 [N] buffer into (q int8 [N], scales f32 [N//block])."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _quantpack_kernel,
        grid=grid,
        in_specs=[bspec],
        out_specs=[bspec, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int8),
                   jax.ShapeDtypeStruct((n // block,), jnp.float32)],
        interpret=_resolve_interpret(interpret),
    )(x)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantunpack_flat(q, scales, *, block: int = BLOCK,
                     interpret: bool | None = None):
    """Dequantize (q int8 [N], scales f32 [N//block]) back to f32 [N]."""
    n = q.shape[0]
    assert n % block == 0, (n, block)
    assert scales.shape == (n // block,), (scales.shape, n, block)
    grid = (n // block,)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.pallas_call(
        _quantunpack_kernel,
        grid=grid,
        in_specs=[smem, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(scales.astype(jnp.float32), q)


@functools.partial(jax.jit, static_argnames=("block",))
def quantpack_flat_jnp(x, *, block: int):
    from repro.kernels.storm.ref import quantpack_ref
    return quantpack_ref(x, block)


@functools.partial(jax.jit, static_argnames=("block",))
def quantunpack_flat_jnp(q, scales, *, block: int):
    from repro.kernels.storm.ref import quantunpack_ref
    return quantunpack_ref(q, scales, block)
