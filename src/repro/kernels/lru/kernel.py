"""RG-LRU gated linear recurrence scan — Pallas TPU kernel.

    h_t = a_t ⊙ h_{t−1} + b_t          (per-channel, per-batch)

On GPU this is typically a custom CUDA scan; the TPU adaptation keeps the
running state ``h`` resident in VMEM scratch while streaming (a, b) time
tiles HBM→VMEM: grid = (B, W_blocks, T_tiles) with the time dimension
innermost (TPU grids are sequential minor-to-major, so the scratch state
carries across time tiles). Within a tile the recurrence is an unrolled
loop over rows — sequential in time but fully vectorised over the channel
lanes, which matches the VPU's (8, 128) register tiling.

Compared with ``lax.associative_scan`` (O(log T) full-array passes through
HBM) the kernel reads a/b once and writes h once — the memory-roofline floor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TIME_TILE = 128
CHAN_BLOCK = 512


def _lru_kernel(a_ref, b_ref, h0_ref, o_ref, state_ref, *, time_tile: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state_ref[...] = h0_ref[...]

    h = state_ref[...]                                  # [1, C] fp32
    a = a_ref[0]                                        # [time_tile, C]
    b = b_ref[0]
    rows = []
    for t in range(time_tile):
        h = a[t][None, :] * h + b[t][None, :]
        rows.append(h)
    o_ref[0] = jnp.concatenate(rows, axis=0)
    state_ref[...] = h


@functools.partial(jax.jit, static_argnames=("time_tile", "interpret"))
def lru_scan_padded(a, b, h0, *, time_tile: int = TIME_TILE,
                    interpret: bool = True):
    """a, b: [B, S, C] fp32 (S divisible by time_tile); h0: [B, C].
    Returns h: [B, S, C] with h[:, t] = a[:,t]·h[:,t-1] + b[:,t], h[:,-1]=h0."""
    B, S, C = a.shape
    assert S % time_tile == 0, (S, time_tile)
    cb = min(CHAN_BLOCK, C)
    assert C % cb == 0, (C, cb)
    grid = (B, C // cb, S // time_tile)

    kernel = functools.partial(_lru_kernel, time_tile=time_tile)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, time_tile, cb), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, time_tile, cb), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, cb), lambda bi, ci, ti: (bi, ci)),
        ],
        out_specs=pl.BlockSpec((1, time_tile, cb), lambda bi, ci, ti: (bi, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, cb), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
