"""jit'd wrapper for the LRU scan kernel: padding + initial state handling."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lru.kernel import TIME_TILE, lru_scan_padded


def lru_scan(a, b, h0=None, *, time_tile: int = TIME_TILE,
             interpret: bool = True):
    """h_t = a_t·h_{t−1} + b_t along axis 1. a, b: [B, S, C] fp32.

    Pads S to the time tile (a=1, b=0 padding is a no-op on the state) and C
    to the channel block.
    """
    B, S, C = a.shape
    tt = min(time_tile, max(8, S))
    pad_s = (-S) % tt
    from repro.kernels.lru.kernel import CHAN_BLOCK
    cb = min(CHAN_BLOCK, C)
    pad_c = (-C) % cb
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)
    if pad_s or pad_c:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_c)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_c)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_c)))
    out = lru_scan_padded(a.astype(jnp.float32), b.astype(jnp.float32),
                          h0.astype(jnp.float32), time_tile=tt,
                          interpret=interpret)
    return out[:, :S, :C]
