"""Pure-jnp oracle for the LRU scan kernel (associative-scan based)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def lru_scan_ref(a, b, h0=None):
    """h_t = a_t·h_{t−1} + b_t along axis 1; a, b: [B, S, C]."""
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h
