from repro.kernels.lru.ops import lru_scan  # noqa: F401
