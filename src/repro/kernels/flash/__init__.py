from repro.kernels.flash.ops import flash_attention  # noqa: F401
