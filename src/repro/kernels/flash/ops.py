"""jit'd wrapper: [B, S, H, D] attention → flash kernel on [B·H, S, D].

Pads the sequence to the tile size and flattens (batch, heads); this is the
call site used by ``repro.models.layers.attention`` when the trainer's
``use_flash`` flag is enabled.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash.kernel import DEFAULT_BK, DEFAULT_BQ, flash_attention_bh


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True):
    """q, k, v: [B, S, H, D] (kv heads already repeated to H). Returns the
    attention output in the same layout."""
    B, S, H, D = q.shape
    bq = min(bq, max(16, 1 << (S - 1).bit_length() if S < bq else bq))
    bk = min(bk, bq)
    pad = (-S) % bq
    padk = (-S) % bk

    def prep(x, p):
        x = jnp.transpose(x, (0, 2, 1, 3)).reshape(B * H, S, D)
        if p:
            x = jnp.pad(x, ((0, 0), (0, p), (0, 0)))
        return x

    qf = prep(q, pad)
    kf = prep(k, padk)
    vf = prep(v, padk)
    out = flash_attention_bh(qf, kf, vf, causal=causal, window=window,
                             softcap=softcap, scale=scale, bq=bq, bk=bk,
                             interpret=interpret)
    out = out[:, :S, :].reshape(B, H, S, D)
    return jnp.transpose(out, (0, 2, 1, 3))
