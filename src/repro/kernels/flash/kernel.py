"""Block-wise (flash) attention with causal + sliding-window masking.

TPU adaptation of the long-context attention hot spot (gemma2 local layers,
recurrentgemma's 2048-token windows, 32k–500k contexts): instead of
materialising the [S, S] score matrix in HBM, q/k/v are streamed through VMEM
in MXU-aligned (BQ × BK) tiles with an online-softmax accumulator, and —
the structural win for sliding windows — **k-blocks entirely outside the
(causal, window) band are skipped via the grid index map**, so a W-token
window costs O(S·W) instead of O(S²).

Grid: (B·H, num_q_blocks, num_k_blocks); the innermost k dimension iterates
sequentially per q block (TPU grids execute minor-to-major sequentially, so
the VMEM accumulator carries across k steps).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # skip blocks fully outside the (causal, window) band
    first_q = qi * bq
    last_q = first_q + bq - 1
    first_k = ki * bk
    last_k = first_k + bk - 1
    in_band = True
    if causal:
        in_band = jnp.asarray(first_k <= last_q)
    if window and window > 0:
        in_band = jnp.logical_and(in_band, jnp.asarray(last_k > first_q - window))

    @pl.when(in_band)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # [bq, d]
        k = k_ref[0].astype(jnp.float32)                     # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap and softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        ok = k_pos < kv_len                                  # padding mask
        if causal:
            ok &= k_pos <= q_pos
        if window and window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                                  # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)                     # [bk, d]
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk",
                     "interpret"))
def flash_attention_bh(q, k, v, *, causal: bool = True, window: int = 0,
                       softcap: float = 0.0, scale: float = None,
                       bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                       interpret: bool = True):
    """q, k, v: [BH, S, D] (batch×heads flattened). S must divide by bq/bk."""
    BH, S, D = q.shape
    kv_len = k.shape[1]
    assert S % bq == 0 and kv_len % bk == 0, (S, kv_len, bq, bk)
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    grid = (BH, S // bq, kv_len // bk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running denom l
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
