"""Pure-jnp oracle for the windowed flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, scale: float = None):
    """q, k, v: [BH, S, D] — dense masked attention in fp32."""
    BH, S, D = q.shape
    kv_len = k.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(kv_len)[None, :]
    ok = jnp.ones((S, kv_len), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window and window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
