# TPU Pallas kernels for the compute hot-spots (DESIGN.md §6):
#   storm — fused STORM variance-reduction + SGD update (HBM-bandwidth bound)
#   flash — block-wise causal/sliding-window attention (VMEM-tiled)
#   lru   — RG-LRU gated linear recurrence scan (time-tiled, state in VMEM)
# Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with padding/reshape logic) and ref.py (pure-jnp oracle used by the
# allclose test sweeps). Validated with interpret=True on CPU; TPU is the
# compilation target.
