# TPU Pallas kernels for the compute hot-spots (DESIGN.md §6):
#   storm — fused STORM variance-reduction + SGD update (HBM-bandwidth bound).
#           Single-sequence (storm_update) plus the triple-sequence variants
#           (storm3_*): one launch streams the x/ν, y/ω, u/q segments of a
#           flat buffer with per-tile (lr, decay) scalars from SMEM — the
#           compute side of the flat-buffer substrate in repro.optim.flat
#           (enabled by fuse_storm=True in the FedBiOAcc train steps).
#   flash — block-wise causal/sliding-window attention (VMEM-tiled)
#   lru   — RG-LRU gated linear recurrence scan (time-tiled, state in VMEM)
# Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with padding/reshape logic) and ref.py (pure-jnp oracle used by the
# allclose test sweeps). Validated with interpret=True on CPU; TPU is the
# compilation target (interpret=None auto-selects; off-TPU the flat substrate
# dispatches to bit-identical jnp lowerings of the triple-sequence update).
