"""Continuous-batching serving engine.

A fixed pool of ``max_slots`` decode slots shares one batched KV/recurrent
cache. Requests join as slots free up (each request is prefilled in
isolation and its per-layer state *inserted* into its slot); every
:meth:`step` decodes ONE token for all active slots at their own positions
(vector ``pos`` support in ``decode_step``). Finished requests (EOS or
length budget) release their slot immediately — no head-of-line blocking on
long generations, the property that defines continuous batching.

The engine is deliberately host-driven (Python queue + jitted insert/step
functions): the jitted compute is batch-shape-stable so nothing recompiles
as requests come and go.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model


@dataclass
class _Request:
    rid: int
    prompt: jnp.ndarray            # [S] int32
    max_new: int
    eos: Optional[int]
    out: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(self, model: Model, params, *, max_slots: int,
                 cache_len: int, eos: Optional[int] = None):
        if model.cfg.family == "audio":
            raise ValueError("encoder-only model cannot be served for decode")
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.eos = eos
        self.caches = model.init_cache(max_slots, cache_len)
        self.pos = jnp.zeros((max_slots,), jnp.int32)     # next position
        self.tok = jnp.zeros((max_slots, 1), jnp.int32)   # next input token
        self.active: Dict[int, _Request] = {}             # slot -> request
        self._next_rid = 0
        self.waiting: List[_Request] = []

        def _prefill(params, batch):
            return model.prefill(params, batch, cache_len=cache_len)

        def _insert(caches, single, slot):
            """Scatter a single-request cache (batch dim 1) into `slot`."""
            def one(c, s):
                return c.at[:, slot].set(s[:, 0]) if c.ndim >= 2 else c
            return jax.tree.map(one, caches, single)

        def _step(params, caches, tok, pos):
            return model.decode_step(params, caches, tok, pos)

        self._prefill = jax.jit(_prefill)
        self._insert = jax.jit(_insert)
        self._step = jax.jit(_step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int, eos: Optional[int] = None) -> int:
        """Queue a request; returns its id."""
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append(_Request(rid, jnp.asarray(prompt, jnp.int32),
                                     max_new, eos if eos is not None else self.eos))
        self._admit()
        return rid

    def _free_slots(self):
        return [s for s in range(self.max_slots) if s not in self.active]

    def _admit(self):
        for slot in self._free_slots():
            if not self.waiting:
                break
            req = self.waiting.pop(0)
            batch = {"tokens": req.prompt[None, :],
                     "labels": req.prompt[None, :]}
            last, single = self._prefill(self.params, batch)
            self.caches = self._insert(self.caches, single, slot)
            first = jnp.argmax(last[0]).astype(jnp.int32)
            req.out.append(int(first))
            self.pos = self.pos.at[slot].set(req.prompt.shape[0])
            self.tok = self.tok.at[slot, 0].set(first)
            self.active[slot] = req

    # ------------------------------------------------------------------
    def step(self) -> List[_Request]:
        """Decode one token for every active slot; returns finished
        requests (their slots are immediately refilled from the queue)."""
        if not self.active:
            self._admit()
            if not self.active:
                return []
        logits, self.caches = self._step(self.params, self.caches, self.tok,
                                         self.pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.pos = self.pos + 1                     # inactive slots harmless
        self.tok = nxt[:, None]
        done = []
        for slot, req in list(self.active.items()):
            t = int(nxt[slot])
            req.out.append(t)
            finished = (len(req.out) >= req.max_new
                        or (req.eos is not None and t == req.eos))
            if finished:
                done.append(req)
                del self.active[slot]
        if done:
            self._admit()
        return done

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drain the queue; returns {request id: generated tokens}."""
        results = {}
        for _ in range(max_steps):
            for req in self.step():
                results[req.rid] = req.out
            if not self.active and not self.waiting:
                break
        return results
