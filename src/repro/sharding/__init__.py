from repro.sharding.rules import (batch_specs, cache_specs,  # noqa: F401
                                  flat_state_specs, param_specs, state_specs)
