from repro.sharding.rules import (batch_specs, cache_specs, param_specs,  # noqa: F401
                                  state_specs)
