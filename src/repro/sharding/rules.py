"""Partition-spec inference for parameters, federated state, caches, batches.

Rules (DESIGN.md §4):

* **Megatron-style tensor parallelism** over the mesh ``"model"`` axis:
  column-parallel input projections (wq/wk/wv/wi/wg/in_*), row-parallel
  output projections (wo/out/out_proj); expert-parallel MoE (expert axis over
  "model"); embedding/head shard the vocab (or fall back to d_model when the
  vocab is not divisible, e.g. granite's 49155 or hubert's 504).
* **Client placement**: ``client_sharded`` puts the leading client axis of
  every federated-state leaf on ``"data"`` (and ``("pod","data")`` multi-pod);
  ``client_replicated`` leaves it unsharded and instead FSDP-shards a large
  parameter dim over ``"data"`` (and the client axis over ``"pod"``).
* Divisibility is always checked; non-divisible dims fall back to the next
  candidate or replication.

Mesh & sharding of the flat substrate
-------------------------------------

The sequence-spec engine's :class:`~repro.optim.sequences.FlatState` holds
per-dtype [M, N] buffers (client axis M × packed tile-padded parameter axis
N).  :func:`flat_state_specs` places them ``P("data", "model")``: clients
over the mesh "data" axis, the packed axis over "model" — which is exactly
the partitioning ``repro.optim.flat.make_spec(..., shards=k)`` lays the
buffer out for.

**Tile-aligned section/shard invariant.**  With ``shards = k`` every
variable section (x | y | u …) is padded to a multiple of ``block · k`` and
the buffer is stored *shard-major*: the j-th contiguous 1/k chunk holds the
j-th 1/k slice of every section, in section order.  A plain contiguous
``NamedSharding`` over "model" therefore gives every shard the SAME
tile-aligned section pattern (``_Group.extents`` describes one chunk), so:

* the per-tile (lr, decay) SMEM tables slice consistently with the buffer
  (same ``P("data", "model")`` on the [M, tiles] table);
* inside ``shard_map`` the section-run slices are static and identical on
  every device — each communicated run is per-shard partial sums + ONE
  ``lax.psum`` (or ``psum_scatter``+``all_gather``) over "data", and
  private / non-participant tiles never enter the collective.

**Overlap schedule.**  ``make_engine(..., overlap=True)`` issues the
variable-section reduction, runs the new-iterate oracle on the local
(pre-reduction) iterate, and only then consumes the correction add — the
issued "data"-axis collective has no consumer during oracle compute, so
XLA async collectives can hide it behind the oracle at unchanged
communication volume (deviation documented in ``repro.optim.sequences``).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig

# name → rule. COL: "model" on last dim; ROW: "model" on first core dim.
_COL = {"wq", "wk", "wv", "wi", "wg", "in_proj", "in_x", "in_gate", "wa", "wx",
        "w", "table", "patch_proj", "frontend_proj"}
_ROW = {"wo", "out", "out_proj"}
_REPL = {"scale", "ba", "bx", "Lambda", "conv_w", "conv_b", "A_log", "D",
         "dt_bias", "b", "router"}


def _leaf_names(path) -> List[str]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
    return names


def _divisible(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


def _param_core_spec(name: str, shape: Tuple[int, ...], model_size: int,
                     is_moe: bool) -> List[Optional[str]]:
    spec: List[Optional[str]] = [None] * len(shape)
    if len(shape) == 0 or name in _REPL:
        return spec
    if is_moe and len(shape) == 3:
        # [E, d, f] expert-parallel
        if _divisible(shape[0], model_size):
            spec[0] = "model"
        return spec
    if name in _COL and len(shape) >= 2:
        if _divisible(shape[-1], model_size):
            spec[-1] = "model"
        elif _divisible(shape[-2], model_size):
            spec[-2] = "model"
        return spec
    if name in _ROW and len(shape) >= 2:
        if _divisible(shape[0], model_size):
            spec[0] = "model"
        elif _divisible(shape[-1], model_size):
            spec[-1] = "model"
        return spec
    return spec


def _add_fsdp(spec: List[Optional[str]], shape: Tuple[int, ...],
              data_size: int) -> None:
    """Shard the largest remaining dim over "data" (FSDP), in place."""
    best, best_dim = -1, -1
    for i, (s, sp) in enumerate(zip(shape, spec)):
        if sp is None and _divisible(s, data_size) and s > best:
            best, best_dim = s, i
    if best_dim >= 0:
        spec[best_dim] = "data"


def param_specs(params: Any, mesh: MeshConfig, *, placement: str = "client_sharded",
                client_axis: bool = False, fsdp: Optional[bool] = None):
    """PartitionSpecs for model params / federated state pytrees.

    ``client_axis``: leaves carry a leading client dim.
    ``fsdp``: force FSDP on/off (default: on iff client_replicated).
    """
    axes = dict(zip(mesh.axes, mesh.shape))
    model_size = axes["model"]
    data_size = axes["data"]
    if fsdp is None:
        fsdp = placement == "client_replicated"

    def one(path, leaf):
        names = _leaf_names(path)
        name = names[-1] if names else ""
        in_stages = "stages" in names
        is_moe = name in ("wi", "wg", "wo") and "ffn" in names
        lead = (1 if client_axis else 0) + (1 if in_stages else 0)
        core_shape = leaf.shape[lead:]
        if placement == "client_pure":
            # clients consume the whole mesh; per-client tensors unsharded
            core = [None] * len(core_shape)
        elif placement == "dp_within_client":
            core = _dp_core_spec(core_shape, data_size)
        else:
            # MoE leaves under stages have an extra reps axis before [E, d, f]
            core = _param_core_spec(name, core_shape, model_size,
                                    is_moe and len(core_shape) == 3)
            if fsdp:
                _add_fsdp(core, core_shape, data_size)
        lead_spec: List[Any] = []
        if client_axis:
            lead_spec.append(_client_axis_spec(placement, mesh))
        if in_stages:
            lead_spec.append(None)   # scanned reps axis
        return P(*(lead_spec + core))

    return jax.tree_util.tree_map_with_path(one, params)


def _client_axis_spec(placement: str, mesh: MeshConfig):
    if placement == "client_sharded":
        return ("pod", "data") if mesh.multi_pod else "data"
    if placement == "client_pure":
        # multi-pod: the global batch cannot feed pod×data×model pure
        # clients; the client axis stays ("data","model"), pod replicates
        return ("data", "model")
    if placement == "dp_within_client":
        # clients on "model"; each client data-parallel over "data" with
        # weights replicated (grad all-reduce) except vocab-sized tensors
        return ("pod", "model") if mesh.multi_pod else "model"
    # client_replicated
    return "pod" if mesh.multi_pod else None


_VOCAB_DIM_MIN = 32768   # dp_within_client: shard only vocab-sized leaves


def _dp_core_spec(core_shape, data_size: int) -> List[Optional[str]]:
    spec: List[Optional[str]] = [None] * len(core_shape)
    if any(s >= _VOCAB_DIM_MIN for s in core_shape):
        _add_fsdp(spec, core_shape, data_size)       # "data" on largest dim
    return spec


def state_specs(state: Any, mesh: MeshConfig, *, placement: str):
    """Specs for a federated TrainState (client axis on every leaf, except
    the scalar step counter)."""
    def per_leaf(path, leaf):
        if leaf.ndim == 0:
            return P()
        names = _leaf_names(path)
        name = names[-1] if names else ""
        in_stages = "stages" in names
        is_moe = name in ("wi", "wg", "wo") and "ffn" in names
        lead = 1 + (1 if in_stages else 0)
        core_shape = leaf.shape[lead:]
        axes = dict(zip(mesh.axes, mesh.shape))
        if placement == "client_pure":
            core: List[Any] = [None] * len(core_shape)
        elif placement == "dp_within_client":
            core = _dp_core_spec(core_shape, axes["data"])
        else:
            core = _param_core_spec(name, core_shape, axes["model"],
                                    is_moe and len(core_shape) == 3)
            if placement == "client_replicated":
                _add_fsdp(core, core_shape, axes["data"])
        client_spec = _client_axis_spec(placement, mesh)
        lead_spec: List[Any] = [client_spec]
        if in_stages:
            lead_spec.append(None)
        return P(*(lead_spec + core))

    return jax.tree_util.tree_map_with_path(per_leaf, state)


def flat_state_specs(state: Any, *, data_axis: str = "data",
                     model_axis: str = "model"):
    """PartitionSpecs for a flat-substrate ``FlatState`` (or any pytree of
    its shape): [M, N] buffers shard the client axis over ``data_axis`` and
    the packed parameter axis over ``model_axis`` (the layout
    ``optim.flat.make_spec(..., shards=)`` is built for — see the module
    docstring's section/shard invariant); [M] staleness counters shard over
    ``data_axis``; scalars (the step counter) replicate."""
    def one(leaf):
        if leaf.ndim == 2:
            return P(data_axis, model_axis)
        if leaf.ndim == 1:
            return P(data_axis)
        return P()

    return jax.tree.map(one, state)


def _generic_spec(shape: Sequence[int], mesh: MeshConfig) -> P:
    """Greedy axis assignment for caches/batches: pod/data left→right (batch
    and sequence dims), model right→left (feature dims)."""
    spec: List[Optional[Any]] = [None] * len(shape)
    axes = list(zip(mesh.axes, mesh.shape))
    fwd = [a for a in axes if a[0] in ("pod", "data")]
    bwd = [a for a in axes if a[0] == "model"]
    used = set()
    for name, size in fwd:
        for i, s in enumerate(shape):
            if i not in used and spec[i] is None and _divisible(s, size):
                spec[i] = name
                used.add(i)
                break
    for name, size in bwd:
        for i in range(len(shape) - 1, -1, -1):
            if i not in used and spec[i] is None and _divisible(shape[i], size):
                spec[i] = name
                used.add(i)
                break
    return P(*spec)


def cache_specs(caches: Any, mesh: MeshConfig):
    """Specs for decode caches (kv rings, recurrent/conv states). Leaves have
    a leading scanned reps axis (kept unsharded) then [B, ...].

    KV rings [reps, B, S, hkv, hd] shard **B over data and S over model**:
    sharding hd (or hkv) makes every attention layer all-gather the full
    cache (measured: 2 GiB/layer/token f32 gathers on llama3-405b decode,
    §Perf pair 2); S-sharding keeps attention local per shard with only a
    tiny partial-softmax all-reduce.
    """
    axes = dict(zip(mesh.axes, mesh.shape))

    def one(path, leaf):
        if leaf.ndim <= 1:
            return P()
        shape = leaf.shape[1:]
        if leaf.ndim == 5:                      # kv ring [B, S, hkv, hd]
            b_spec = None
            s_spec = None
            if _divisible(shape[0], axes["data"]):
                b_spec = "data"
            if _divisible(shape[1], axes["model"]):
                s_spec = "model"
            return P(None, b_spec, s_spec, None, None)
        core = _generic_spec(shape, mesh)
        return P(*((None,) + tuple(core)))

    return jax.tree_util.tree_map_with_path(one, caches)


def batch_specs(batch: Any, mesh: MeshConfig, *, client_axis: bool = False,
                placement: str = "client_sharded"):
    """Specs for input batches.

    Federated train batches: leading [M, per_client, ...]; serve batches:
    leading [B, ...].
    """
    def one(path, leaf):
        if leaf.ndim == 0:
            return P()
        if client_axis:
            if placement in ("client_sharded", "client_pure"):
                lead = _client_axis_spec(placement, mesh)
                rest = [None] * (leaf.ndim - 1)
                return P(*([lead] + rest))
            if placement == "dp_within_client":
                # [M, per_client, ...]: clients on "model", batch on "data"
                lead = _client_axis_spec(placement, mesh)
                rest = [None] * (leaf.ndim - 1)
                if leaf.ndim >= 2 and _divisible(
                        leaf.shape[1], dict(zip(mesh.axes, mesh.shape))["data"]):
                    rest[0] = "data"
                return P(*([lead] + rest))
            # client_replicated: [M, per_client, ...] → per_client over data
            lead = "pod" if mesh.multi_pod else None
            rest: List[Any] = [None] * (leaf.ndim - 1)
            if leaf.ndim >= 2 and _divisible(leaf.shape[1], dict(zip(mesh.axes, mesh.shape))["data"]):
                rest[0] = "data"
            return P(*([lead] + rest))
        return _generic_spec(leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, batch)
