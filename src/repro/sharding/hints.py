"""Optional intra-model sharding hints.

Model code is mesh-agnostic; under jit with a mesh context the launchers can
activate hint mode so that performance-critical intermediates (decode
attention) carry ``with_sharding_constraint`` annotations. Measured effect
(EXPERIMENTS.md §Perf pair 2): without the hints XLA resolves the
S-sharded-KV vs head-sharded-q conflict by *replicating the KV cache*
(≈2 GiB f32 all-gathers per layer per token on llama3-405b); with them it
keeps the cache S-sharded and emits tiny partial-softmax all-reduces.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

from jax import lax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@contextmanager
def sharding_hints(enabled: bool = True):
    prev = getattr(_STATE, "on", False)
    _STATE.on = enabled
    try:
        yield
    finally:
        _STATE.on = prev


def active() -> bool:
    return getattr(_STATE, "on", False)


def hint(x, *spec):
    """Apply a PartitionSpec constraint when hint mode is on (no-op
    otherwise, so models stay runnable without any mesh)."""
    if not active():
        return x
    try:
        return lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x      # axis not in mesh / no mesh context — stay mesh-agnostic
