"""Configuration system for the repro framework.

Everything in the framework is driven by three dataclasses:

* :class:`ModelConfig`   — architecture hyper-parameters (one instance per assigned arch).
* :class:`FederatedConfig` — the paper's algorithm knobs (M clients, I local steps,
  learning rates, STORM constants, Neumann terms, placement strategy).
* :class:`RunConfig`     — a launchable bundle: model + federated + mesh + input shape.

Configs are plain frozen dataclasses so they can be closed over by jitted functions
safely (hashable, usable as static args).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

ARCH_FAMILIES = (
    "dense",        # llama / gemma2 / granite style decoder
    "moe",          # mixture-of-experts decoder
    "ssm",          # mamba2 (attention-free)
    "hybrid",       # recurrentgemma: RG-LRU + local attention
    "audio",        # encoder-only transformer (hubert)
    "vlm",          # vision-language: LM decoder + patch-embedding stub frontend
)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # one of ARCH_FAMILIES
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int                # GQA kv heads
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_heads: int = 0               # mamba2 value heads
    ssm_head_dim: int = 64
    ssm_chunk: int = 256             # SSD chunk length
    conv_width: int = 4
    # --- hybrid (recurrentgemma) ---
    lru_width: int = 0               # RG-LRU recurrence width (0 -> d_model)
    attention_pattern: str = "global"   # "global" | "local_global" | "rg" (rec,rec,attn)
    window_size: int = 0             # sliding window (0 = full)
    # --- misc ---
    logit_softcap: float = 0.0       # gemma2 final-logit soft capping
    attn_softcap: float = 0.0        # gemma2 attention-logit soft capping
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    causal: bool = True              # False for encoder-only
    # --- modality stub frontends (audio frames / vision patches) ---
    num_patches: int = 0             # patch embeddings prepended per sample (vlm)
    frontend_dim: int = 0            # dim of precomputed frame/patch embeddings
    scale_embed: bool = False        # gemma-family sqrt(d_model) embedding scaling
    # --- citation ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind, length == num_layers."""
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        if self.family == "hybrid":
            # recurrentgemma: repeating (recurrent, recurrent, local attention)
            pat = ("rec", "rec", "local")
            return tuple(pat[i % 3] for i in range(self.num_layers))
        if self.attention_pattern == "local_global":
            return tuple("local" if i % 2 == 0 else "attn" for i in range(self.num_layers))
        return ("attn",) * self.num_layers

    def reduced(self, num_layers: int = 2, d_model: int = 256, d_ff: int = 512,
                vocab_size: int = 512, num_experts: int = 4) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(1, heads // 2)) if self.num_kv_heads else 0
        changes = dict(
            num_layers=num_layers,
            d_model=d_model,
            d_ff=d_ff if self.d_ff else 0,
            vocab_size=vocab_size,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=(d_model // heads if heads else self.ssm_head_dim),
        )
        if self.num_experts:
            changes["num_experts"] = num_experts
            changes["experts_per_token"] = min(self.experts_per_token, 2)
        if self.family == "ssm":
            changes["ssm_state"] = 16
            changes["ssm_heads"] = 4
            changes["ssm_head_dim"] = 32
            changes["ssm_chunk"] = 32
            changes["num_heads"] = 0
            changes["num_kv_heads"] = 0
            changes["head_dim"] = 0
        if self.family == "hybrid":
            changes["lru_width"] = d_model
            changes["num_layers"] = 3    # one full (rec, rec, local) block
        if self.window_size:
            changes["window_size"] = 64
        if self.num_patches:
            changes["num_patches"] = 8
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Federated / algorithm configuration
# ---------------------------------------------------------------------------

ALGORITHMS = (
    "fedbio",          # Algorithm 1
    "fedbioacc",       # Algorithm 2 (STORM-accelerated)
    "fedbio_local",    # Algorithm 3 (local lower level, Neumann hypergrad)
    "fedbioacc_local", # Algorithm 4
    "fednest",         # baseline: full hyper-gradient solve every round
    "commfedbio",      # baseline: per-step hypergrad + top-k compression
    "fedavg",          # single-level baseline substrate
)


@dataclass(frozen=True)
class FederatedConfig:
    algorithm: str = "fedbioacc"
    num_clients: int = 16
    local_steps: int = 4             # I in the paper
    # learning rates (paper: gamma (y), eta (x), tau (u))
    lr_x: float = 0.05
    lr_y: float = 0.1
    lr_u: float = 0.1
    # STORM constants (FedBiOAcc): c_nu, c_omega, c_u and alpha_t = delta/(u0+t)^{1/3}
    c_nu: float = 1.0
    c_omega: float = 1.0
    c_u: float = 1.0
    alpha_delta: float = 1.0
    alpha_u0: float = 8.0
    # Neumann series terms Q (local-lower-level hypergradient, Eq. 6)
    neumann_q: int = 8
    neumann_tau: float = 0.5
    # lower-level strong convexity regulariser (lambda)
    lower_l2: float = 1e-2
    # client placement on the mesh (see DESIGN.md §4)
    placement: str = "client_sharded"   # or "client_replicated"
    # CommFedBiO top-k compression ratio
    compress_ratio: float = 0.1
    # hierarchical multi-pod averaging (beyond-paper, EXPERIMENTS §Perf):
    # 0 = flat (paper); k>0 = pod-local averaging every I steps, global
    # (cross-pod) averaging only every k-th round
    hierarchy_period: int = 0
    hierarchy_groups: int = 2
    # §Perf fusion flags (core algorithms; the model-scale trainer takes the
    # same switches as keyword args — uniformly on every algorithm):
    # fuse_oracles — share one forward-over-reverse linearization across the
    #   oracle directions AND one minibatch across them (FedBiOAcc samples
    #   1 batch/step instead of 5, the local variants 1 instead of 3;
    #   hypergrad.fused_oracles / hypergrad.fused_local_oracles)
    # fuse_storm — run the algorithm's sequence spec on the flat-buffer
    #   substrate (repro.optim.sequences): one fused Pallas launch per step
    #   + section-masked communication (private sections never all-reduced);
    #   fuse_storm_block overrides the kernel tile size
    fuse_oracles: bool = False
    fuse_storm: bool = False
    fuse_storm_block: int = 1024
    seed: int = 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Mesh configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# Hardware constants for the roofline model (TPU v5e-class, per brief).
PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


# ---------------------------------------------------------------------------
# Run bundle
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    """A launchable bundle; per-arch production instances are derived from
    :mod:`repro.launch.archspec` (which also carries the §Perf knobs:
    fused oracles, placements, microbatching)."""
    model: ModelConfig
    fed: FederatedConfig = field(default_factory=FederatedConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    shape: InputShape = field(default_factory=lambda: INPUT_SHAPES["train_4k"])
    n_micro: int = 1                 # microbatches (scan + remat)
    remat: bool = True
    param_dtype: str = "bfloat16"
    use_flash: bool = False          # Pallas windowed flash-attention kernel
    use_lru_kernel: bool = False     # Pallas RG-LRU scan kernel
    fuse_oracles: bool = False       # §Perf fused hyper-gradient oracles
