"""Declarative telemetry policy — the spec half of ``repro.telemetry``.

:class:`TelemetrySpec` rides ``Experiment.telemetry`` (optional layer, like
``CompressionSpec``): JSON-round-trippable, ``edit()``-sweepable, validated
by ``Experiment.validate``.  It selects which in-band metric groups the
fused engine computes as a side output of every step, and where the
structured event stream lands.  With the layer absent every trajectory and
every jit cache key is bit-identical to a telemetry-free build — the
metrics side output stays exactly ``{"step": ...}``.

Metric groups (``metrics=None`` resolves to every group the experiment's
other layers make applicable):

* ``"norms"`` — per-sequence l2 update norms (x, y, u runs; the u-sequence
  norm is the hypergradient-estimation proxy of AggITD, arxiv 2302.04969)
  plus momentum norms for momentum-carrying specs;
* ``"drift"`` — per-sequence client-drift dispersion: the participants'
  rms distance to their mean local iterate *before* averaging (the non-IID
  heterogeneity term of the linear-speedup analysis, arxiv 2302.05412);
* ``"compression"`` — error-feedback residual norm and the quantization
  round-trip error of the communicated buffers (needs
  ``Experiment.compression``);
* ``"health"`` — staleness histogram, recomputed health-screen verdicts and
  the round's injected fault masks (needs participation sampling, faults,
  or robustness);
* ``"stragglers"`` — the elastic round's effective/next deadline, arrival
  and quorum counts, extension count and the arrival-time histogram (needs
  ``Experiment.stragglers``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

METRIC_GROUPS = ("norms", "drift", "compression", "health", "stragglers")


class TelemetrySpec(NamedTuple):
    """Telemetry policy of one run (see the module docstring).

    ``sink``: path of the JSONL event stream (relative paths resolve
    against the working directory); ``None`` lets the driver pick
    (``events.jsonl`` next to the checkpoints, or in the cwd).
    ``metrics``: in-band metric groups, a subset of :data:`METRIC_GROUPS`;
    ``None`` = every applicable group, ``()`` = events only (no in-band
    metrics).  ``trace``: emit wall-clock phase spans (host/batch, device
    step, eval, checkpoint) as ``span`` events.
    """
    sink: Optional[str] = None
    metrics: Optional[Tuple[str, ...]] = None
    trace: bool = True


def resolve_metric_groups(metrics, *, compressed: bool = False,
                          guarded: bool = False,
                          sampled: bool = False,
                          straggled: bool = False) -> tuple:
    """The metric groups a run actually computes: an explicit ``metrics``
    tuple passes through verbatim (validated), ``None`` resolves to every
    group the run's layers make applicable."""
    if metrics is None:
        groups = ("norms", "drift")
        if compressed:
            groups += ("compression",)
        if guarded or sampled:
            groups += ("health",)
        if straggled:
            groups += ("stragglers",)
        return groups
    unknown = set(metrics) - set(METRIC_GROUPS)
    if unknown:
        raise ValueError(f"unknown telemetry metric groups "
                         f"{sorted(unknown)} (known: {METRIC_GROUPS})")
    return tuple(metrics)
