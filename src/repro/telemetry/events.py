"""The structured event stream: a schema-versioned, append-only JSONL log.

Every run writes one :class:`EventLog`: the first line of every process
segment is a ``run_start`` event carrying the schema version and the
embedded experiment spec, and each subsequent line is one event with a
monotonic per-segment ``seq``, a wall-clock ``ts``, and the keys
:data:`REQUIRED_KEYS` demands for its type.  The writer is the JSONL
analogue of ``checkpoint/io.py``'s atomic replace: every event is a single
``write`` of a full line flushed to the OS, opens *append* (a resumed or
rolled-back run extends the same stream — retried rounds appear as
distinct events keyed by ``(step, retry)``), and a partial tail line left
by a crash is truncated away on the next open, so the stream always
parses.  ``python -m repro.telemetry.validate`` checks all of this
post-hoc; ``repro.launch.metrics`` renders it.
"""
from __future__ import annotations

import json
import os
import time

EVENT_SCHEMA_VERSION = 1

# event type → keys every event of that type must carry (on top of the
# envelope keys "event", "seq", "ts" stamped by EventLog.emit).  Adding a
# type or key is backward-compatible; removing or renaming one bumps
# EVENT_SCHEMA_VERSION.
REQUIRED_KEYS = {
    "run_start": ("schema", "experiment"),
    "metrics": ("step",),
    "comm": ("step", "round", "elems", "reductions", "bytes_wire"),
    "span": ("name", "dur_s"),
    "rollback": ("step", "retry", "bad_loss"),
    "retry_budget_exhausted": ("step", "retry"),
    "clients_screened": ("step", "round", "clients"),
    "deadline": ("step", "round", "deadline", "arrivals", "quorum",
                 "extensions"),
    "quorum_miss": ("step", "round", "extensions", "deadline"),
    "checkpoint": ("step", "path"),
    "hlo_collectives": ("bytes_by_dtype",),
    "bench": ("name", "us_per_step"),
    "note": ("text",),
    "run_end": ("step", "status"),
}


class TelemetryError(ValueError):
    """A malformed event or an invalid event stream."""


def _repair_tail(path: str) -> None:
    """Truncate a partial (unterminated) tail line left by a crash — the
    append-mode analogue of the checkpoint manifest swap: what survives is
    always a sequence of complete lines."""
    try:
        size = os.path.getsize(path)
    except OSError:
        return
    if size == 0:
        return
    with open(path, "rb+") as f:
        f.seek(-1, os.SEEK_END)
        if f.read(1) == b"\n":
            return
        # walk back to the last newline (or the file start) and cut there
        data = open(path, "rb").read()
        keep = data.rfind(b"\n") + 1
        f.truncate(keep)


class EventLog:
    """Append-only JSONL event writer (see the module docstring).

    ``meta``: extra fields of the segment's ``run_start`` event — pass the
    experiment's JSON dict as ``experiment=`` so the stream is
    self-describing (the validate CLI reconciles ``comm`` events against
    the analytic bytes model rebuilt from it).
    """

    def __init__(self, path: str, **meta):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        _repair_tail(path)
        self.path = path
        self._f = open(path, "a", encoding="utf-8")
        self._seq = 0
        meta.setdefault("experiment", None)
        self.emit("run_start", schema=EVENT_SCHEMA_VERSION, **meta)

    def emit(self, event: str, **fields) -> dict:
        """Write one event line; returns the full record (for rendering)."""
        missing = [k for k in REQUIRED_KEYS.get(event, ())
                   if k not in fields]
        if missing:
            raise TelemetryError(f"event {event!r} missing required keys "
                                 f"{missing}")
        rec = {"event": event, "seq": self._seq,
               "ts": round(time.time(), 3), **fields}  # analysis: ignore[L301] driver stamp
        self._seq += 1
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        return rec

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_events(path: str) -> list:
    """Parse an event stream into a list of dicts (raises
    :class:`TelemetryError` on an unparseable or unterminated line)."""
    out = []
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f):
            if not line.endswith("\n"):
                raise TelemetryError(
                    f"{path}:{i + 1}: unterminated tail line (crashed "
                    f"writer? EventLog repairs this on the next open)")
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise TelemetryError(f"{path}:{i + 1}: {e}") from None
    return out
