"""Per-round analytic communication-bytes plan of a built run.

One :class:`CommPlan` is derived from the engine's flat layout
(``run.step.spec``), its sequence spec (``run.step.aspec``) and the
experiment's compression policy — the same per-element byte model as
``repro.federation.compression`` (PR 7) and the same section-extent
arithmetic the dryrun HLO audit uses, so the ``comm`` events the train
driver emits every communication round are reconcilable against both:
``python -m repro.telemetry.validate --reconcile`` rebuilds the per-elem
model from the event stream's embedded experiment and checks every
``comm`` event's ``bytes_wire`` against it.

``elems`` counts TOTAL logical elements per reduction (per-shard-chunk
extents x ``FlatSpec.shards``); ``bytes_wire`` is what one SPMD
all-reduce of that payload moves (dense partial sums — only the dtype
narrows it), ``bytes_uplink_per_client`` what one participating client
ships (top-k sends only the kept values + indices).
"""
from __future__ import annotations

from typing import NamedTuple

F32_BYTES = 4.0


class CommPlan(NamedTuple):
    """Static per-round byte model: ``sections`` is a tuple of
    ``(name, elems, cadence, compressed)`` for every communicated
    sequence; ``reductions`` is 2 for momentum-carrying specs (vars + mom
    reduce per comm event), 1 otherwise."""
    sections: tuple
    reductions: int
    block: int
    wire_bpe: float
    uplink_bpe: float


def comm_plan(flat_spec, aspec, compression=None) -> CommPlan | None:
    """The run's :class:`CommPlan` (None when nothing is communicated —
    all-private specs)."""
    from repro.federation.compression import (uplink_bytes_per_elem,
                                              wire_bytes_per_elem)
    from repro.optim.sequences import PRIVATE

    comm = [q for q in aspec.sequences if q.comm != PRIVATE]
    if not comm:
        return None
    csecs: set = set()
    if compression is not None:
        csecs = set(compression.sections
                    or tuple(q.section for q in comm))
    # extents carry section INDICES into flat_spec.sections and cover one
    # shard chunk — total elems is (b - a) summed over groups, x shards
    elems = {}
    for grp in flat_spec.groups:
        for s, a, b in grp.extents:
            name = flat_spec.sections[s]
            elems[name] = elems.get(name, 0) + (b - a) * flat_spec.shards
    block = flat_spec.groups[0].block if flat_spec.groups else 256
    secs = tuple((q.section, elems.get(q.section, 0), q.comm_every,
                  q.section in csecs) for q in comm)
    wire = (wire_bytes_per_elem(compression, block)
            if compression is not None else F32_BYTES)
    uplink = (uplink_bytes_per_elem(compression, block)
              if compression is not None else F32_BYTES)
    return CommPlan(secs, 2 if aspec.has_momentum else 1, block, wire,
                    uplink)


def compressed_chunk_elems(flat_spec, aspec, compression) -> int:
    """Per-shard-chunk elements of every compressed section — the section-
    extent arithmetic shared by the dryrun HLO audit and the
    ``repro.analysis`` wire-dtype rule (W103), so the two consumers can
    never drift from this byte model."""
    from repro.optim.sequences import PRIVATE
    comm = tuple(q.section for q in aspec.sequences if q.comm != PRIVATE)
    csecs = compression.sections or comm
    # extents carry section INDICES into flat_spec.sections
    cids = {i for i, n in enumerate(flat_spec.sections) if n in csecs}
    return sum(b - a for grp in flat_spec.groups
               for s, a, b in grp.extents if s in cids)


def round_bytes(plan: CommPlan, round_idx: int) -> dict | None:
    """The ``comm`` event payload of communication round ``round_idx``
    (``(step + 1) // local_steps`` at a comm step) — None when every
    section's cadence skips this round."""
    e_comp = sum(e for _, e, c, comp in plan.sections
                 if round_idx % c == 0 and comp)
    e_exact = sum(e for _, e, c, comp in plan.sections
                  if round_idx % c == 0 and not comp)
    if e_comp + e_exact == 0:
        return None
    r = plan.reductions
    return {
        "round": int(round_idx),
        "elems": e_comp + e_exact,
        "elems_compressed": e_comp,
        "elems_exact": e_exact,
        "reductions": r,
        "block": plan.block,
        "bytes_wire": int(round(r * (e_comp * plan.wire_bpe
                                     + e_exact * F32_BYTES))),
        "bytes_uplink_per_client": int(round(
            r * (e_comp * plan.uplink_bpe + e_exact * F32_BYTES))),
    }
