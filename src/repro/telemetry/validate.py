"""Validate a telemetry event stream — ``python -m repro.telemetry.validate``.

Checks that a JSONL event stream parses, is schema-valid (known event
types, required keys, supported schema version, monotonic per-segment
``seq``), and — with ``--reconcile``, the default — that every ``comm``
event's reported wire bytes match the analytic bytes model of
``repro.federation.compression`` rebuilt from the stream's embedded
experiment spec.  Straggler ``deadline``/``quorum_miss`` events are always
checked: deadlines finite positive, per-segment rounds strictly
increasing, arrivals >= quorum on every accepted round, and every
quorum_miss carrying at least one extension.  ``--expect`` asserts that
given event types occurred
(e.g. ``rollback`` on a faulty run); ``--trend-decreasing KEY`` asserts a
metrics series (e.g. ``upd_norm/u``, the hypergradient-estimation proxy)
is finite and trends down over the run.

    python -m repro.telemetry.validate events.jsonl
    python -m repro.telemetry.validate events.jsonl \
        --expect run_start,metrics,comm --trend-decreasing upd_norm/u
"""
from __future__ import annotations

import argparse
import math
import sys

from repro.telemetry.events import (EVENT_SCHEMA_VERSION, REQUIRED_KEYS,
                                    TelemetryError, read_events)


def _reconcile_comm(ev: dict, exp_json) -> None:
    """Check one ``comm`` event's bytes against the analytic model rebuilt
    from the embedded experiment (the PR 7 per-elem model)."""
    from repro.federation.compression import (CompressionSpec,
                                              wire_bytes_per_elem)
    cp = None
    if exp_json and exp_json.get("compression"):
        d = dict(exp_json["compression"])
        if d.get("sections") is not None:
            d["sections"] = tuple(d["sections"])
        cp = CompressionSpec(**d)
    ec = ev.get("elems_compressed", ev["elems"] if cp is not None else 0)
    ee = ev.get("elems_exact", ev["elems"] - ec)
    wire = (wire_bytes_per_elem(cp, ev.get("block", 256))
            if cp is not None else 4.0)
    expected = ev["reductions"] * (ec * wire + ee * 4.0)
    tol = max(16.0, 0.005 * expected)
    if abs(ev["bytes_wire"] - expected) > tol:
        raise TelemetryError(
            f"comm event (seq {ev['seq']}, round {ev['round']}): reported "
            f"bytes_wire={ev['bytes_wire']} disagrees with the analytic "
            f"model ({expected:.0f} B = {ev['reductions']} reductions x "
            f"({ec} compressed elems x {wire:.4f} B + {ee} exact elems x "
            f"4 B))")


def _check_deadlines(path: str, events: list) -> int:
    """Straggler invariants over ``deadline`` / ``quorum_miss`` events:
    every accepted round reports ``arrivals >= quorum`` (the quorum
    fallback guarantees this even on exhausted extensions), deadlines are
    finite and positive, rounds are strictly increasing within a segment
    (reset at each ``run_start``), and every ``quorum_miss`` carries
    ``extensions >= 1``.  Returns the number of deadline events checked."""
    checked = 0
    last_round = None
    for i, ev in enumerate(events):
        kind = ev.get("event")
        if kind == "run_start":
            last_round = None
        elif kind == "deadline":
            dl = ev["deadline"]
            if not (isinstance(dl, (int, float)) and math.isfinite(dl)
                    and dl > 0):
                raise TelemetryError(
                    f"{path}: line {i + 1}: deadline event round "
                    f"{ev['round']}: deadline {dl!r} is not finite "
                    f"positive")
            if ev["arrivals"] < ev["quorum"]:
                raise TelemetryError(
                    f"{path}: line {i + 1}: deadline event round "
                    f"{ev['round']}: arrivals {ev['arrivals']} < quorum "
                    f"{ev['quorum']} — an accepted round must meet quorum")
            if last_round is not None and ev["round"] <= last_round:
                raise TelemetryError(
                    f"{path}: line {i + 1}: deadline event round "
                    f"{ev['round']} not increasing within its segment "
                    f"(prev {last_round})")
            last_round = ev["round"]
            checked += 1
        elif kind == "quorum_miss":
            if ev["extensions"] < 1:
                raise TelemetryError(
                    f"{path}: line {i + 1}: quorum_miss event round "
                    f"{ev['round']}: extensions {ev['extensions']} < 1 — "
                    f"a miss implies at least one deadline extension")
    return checked


def _trend_decreasing(events: list, key: str) -> None:
    """Assert the metrics series ``key`` is finite and trends down.  The
    STORM sequences update with the ENTERING momentum, so step 1's update
    norm is exactly 0 — leading zeros are dropped before the comparison."""
    vals = [e[key] for e in events
            if e.get("event") == "metrics" and key in e]
    if not vals:
        raise TelemetryError(f"no metrics events carry {key!r}")
    bad = [v for v in vals if not math.isfinite(v)]
    if bad:
        raise TelemetryError(f"{key!r} has non-finite values: {bad[:4]}")
    while vals and vals[0] == 0.0:
        vals = vals[1:]
    if len(vals) < 2:
        raise TelemetryError(f"{key!r} has {len(vals)} nonzero values — "
                             f"too few to establish a trend")
    if not vals[-1] < vals[0]:
        raise TelemetryError(f"{key!r} does not trend down: first nonzero "
                             f"{vals[0]:.6g} -> last {vals[-1]:.6g}")


def validate_events(path: str, *, reconcile: bool = True,
                    expect: tuple = (), trend_decreasing: tuple = ()) -> dict:
    """Validate one stream; returns a summary dict, raises
    :class:`TelemetryError` on the first violation."""
    events = read_events(path)
    if not events:
        raise TelemetryError(f"{path}: empty event stream")
    by_type: dict = {}
    segments = 0
    exp_json = None
    last_seq = None
    reconciled = 0
    for i, ev in enumerate(events):
        kind = ev.get("event")
        if kind not in REQUIRED_KEYS:
            raise TelemetryError(f"{path}: line {i + 1}: unknown event "
                                 f"type {kind!r}")
        missing = [k for k in REQUIRED_KEYS[kind] if k not in ev]
        if missing or "seq" not in ev or "ts" not in ev:
            raise TelemetryError(f"{path}: line {i + 1}: event {kind!r} "
                                 f"missing keys {missing or ['seq/ts']}")
        if kind == "run_start":
            if ev["schema"] > EVENT_SCHEMA_VERSION:
                raise TelemetryError(
                    f"{path}: line {i + 1}: schema {ev['schema']} is newer "
                    f"than supported ({EVENT_SCHEMA_VERSION})")
            segments += 1
            exp_json = ev.get("experiment") or exp_json
            last_seq = ev["seq"]
        else:
            if segments == 0:
                raise TelemetryError(f"{path}: line {i + 1}: event before "
                                     f"any run_start")
            if ev["seq"] <= last_seq:
                raise TelemetryError(
                    f"{path}: line {i + 1}: seq {ev['seq']} not monotonic "
                    f"within its segment (prev {last_seq})")
            last_seq = ev["seq"]
        by_type[kind] = by_type.get(kind, 0) + 1
        if kind == "comm" and reconcile:
            if exp_json is None:
                raise TelemetryError(
                    f"{path}: line {i + 1}: cannot reconcile comm bytes — "
                    f"no embedded experiment in any run_start")
            _reconcile_comm(ev, exp_json)
            reconciled += 1
    deadlines_checked = _check_deadlines(path, events)
    for kind in expect:
        if kind not in by_type:
            raise TelemetryError(f"{path}: expected at least one "
                                 f"{kind!r} event — none found "
                                 f"(saw {sorted(by_type)})")
    for key in trend_decreasing:
        _trend_decreasing(events, key)
    return {"events": len(events), "segments": segments,
            "by_type": by_type, "comm_reconciled": reconciled,
            "deadlines_checked": deadlines_checked}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="event stream JSONL files")
    ap.add_argument("--no-reconcile", action="store_true",
                    help="skip the comm-bytes reconciliation against the "
                         "analytic model")
    ap.add_argument("--expect", default="",
                    help="comma-separated event types that must occur")
    ap.add_argument("--trend-decreasing", action="append", default=[],
                    metavar="KEY",
                    help="metrics key that must be finite and trend down "
                         "(repeatable), e.g. upd_norm/u")
    ns = ap.parse_args(argv)
    expect = tuple(t for t in ns.expect.split(",") if t)
    failed = False
    for path in ns.paths:
        try:
            s = validate_events(path, reconcile=not ns.no_reconcile,
                                expect=expect,
                                trend_decreasing=tuple(ns.trend_decreasing))
        except (TelemetryError, OSError, KeyError) as e:
            print(f"FAIL {path}: {e}")
            failed = True
            continue
        counts = " ".join(f"{k}={v}" for k, v in sorted(s["by_type"].items()))
        print(f"OK {path}: {s['events']} events, {s['segments']} segment(s), "
              f"{s['comm_reconciled']} comm event(s) reconciled, "
              f"{s['deadlines_checked']} deadline event(s) checked "
              f"[{counts}]")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
