"""Span-based phase tracing and the shared measured-run path.

Host-side phases (batch synthesis, the dispatched device step, eval,
checkpoint writes, benchmark measurement windows) are wrapped in
:func:`phase`: a context manager that enters a
``jax.profiler.TraceAnnotation`` (so the phase shows up in a profiler
trace) and records the wall-clock span as a ``span`` event in the run's
:class:`~repro.telemetry.events.EventLog`.  Device-side phases (oracle,
fused kernel, collective) are marked with :func:`annotate` —
``jax.named_scope`` — which attaches the phase name to the traced
equations' metadata for profiler/HLO attribution without touching the
computation.

:func:`measure_run` is the one warmed, donation-aware measured training
run that ``benchmarks/run.py``'s sweeps share (previously three copies of
the same timing boilerplate): compile + step 1 off the clock, the
remaining steps timed, client-mean val losses evaluated off the clock —
so BENCH_kernels.json rows and run events come from the same measurement
path.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

import jax


def annotate(name: str):
    """Device-phase marker for traced code: attaches ``name`` to the traced
    equations (profiler/HLO metadata only — numerics and trajectories are
    untouched)."""
    return jax.named_scope(name)


@contextmanager
def phase(name: str, log=None, **fields):
    """Wall-clock + profiler span around a host-side phase; records a
    ``span`` event on ``log`` (ignored when ``log`` is None)."""
    with jax.profiler.TraceAnnotation(name):
        t0 = time.perf_counter()  # analysis: ignore[L301] driver timing
        try:
            yield
        finally:
            if log is not None:
                log.emit("span", name=name,
                         dur_s=round(time.perf_counter() - t0, 6), **fields)  # analysis: ignore[L301] driver timing


def _client_mean_loss(run, eval_batch):
    """Participation-insensitive convergence metric: val loss at the
    CLIENT-MEAN iterate (``run.eval_fn`` reads client 0 only, which under
    m < M sampling may be frozen all run and show no signal)."""
    import jax.numpy as jnp

    def mean_loss(state):
        v = run.views(state)
        p = (v.params if hasattr(v, "params")
             else {"body": v.x, "head": v.y})
        p = jax.tree.map(lambda t: jnp.mean(t, axis=0), p)
        return float(run.model.loss(p, eval_batch["val"])[0])

    return mean_loss


def measure_run(exp, *, curve: bool = False, log=None, label: str = None):
    """Build ``exp``, run its full schedule, and measure it (see the module
    docstring).  ``curve=True`` synchronizes every step
    (``block_until_ready``) and evaluates the client-mean val loss after
    each one with the eval excluded from the timed wall — the convergence-
    curve mode; ``curve=False`` times the steps as one dispatch stream and
    evaluates only after step 1 and at the end.

    Returns ``{"us_per_step", "val_loss_step1", "val_loss_final",
    "val_loss_curve", "run", "state"}`` (``val_loss_curve`` is None
    without ``curve``).
    """
    from repro.api import build
    label = label or exp.algorithm.name
    with phase(f"bench/{label}/build", log):
        run = build(exp)
    eval_batch = jax.tree.map(lambda v: v[0],
                              run.batch_fn(jax.random.PRNGKey(123)))
    mean_loss = _client_mean_loss(run, eval_batch)

    key = jax.random.PRNGKey(exp.schedule.seed)
    state = run.init(key)
    jstep = jax.jit(run.step, donate_argnums=(0,))
    key, sub = jax.random.split(key)
    with phase(f"bench/{label}/compile+step1", log):
        state, _ = jstep(state, run.batch_fn(sub))       # compile + step 1
    loss1 = round(mean_loss(state), 5)

    n = max(exp.schedule.steps - 1, 1)
    losses = [loss1]
    if curve:
        t0 = time.perf_counter()  # analysis: ignore[L301] driver timing
        wall = 0.0
        for _ in range(exp.schedule.steps - 1):
            key, sub = jax.random.split(key)
            state, _ = jstep(state, run.batch_fn(sub))
            jax.block_until_ready(state)
            wall += time.perf_counter() - t0  # analysis: ignore[L301] driver timing
            losses.append(round(mean_loss(state), 5))  # eval off the clock
            t0 = time.perf_counter()  # analysis: ignore[L301] driver timing
    else:
        t0 = time.perf_counter()  # analysis: ignore[L301] driver timing
        for _ in range(exp.schedule.steps - 1):
            key, sub = jax.random.split(key)
            state, _ = jstep(state, run.batch_fn(sub))
        wall = time.perf_counter() - t0  # analysis: ignore[L301] driver timing
    us = wall / n * 1e6
    if log is not None:
        log.emit("span", name=f"bench/{label}/steps",
                 dur_s=round(wall, 6), steps=exp.schedule.steps - 1)
    final = losses[-1] if curve else round(mean_loss(state), 5)
    return {"us_per_step": round(us, 1), "val_loss_step1": loss1,
            "val_loss_final": final,
            "val_loss_curve": losses if curve else None,
            "run": run, "state": state}
