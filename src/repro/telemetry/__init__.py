"""Round-level telemetry: in-band metrics, phase spans, and the structured
event stream of every run.

* :class:`TelemetrySpec` — the declarative policy on
  ``Experiment.telemetry`` (metric groups + sink path);
* :class:`EventLog` — the schema-versioned, append-only JSONL event
  writer every driver emits to (``launch.train``, ``launch.dryrun``,
  ``benchmarks.run``);
* :func:`phase` / :func:`annotate` — host wall-clock spans (wrapping
  ``jax.profiler.TraceAnnotation``) and in-jit phase markers;
* :func:`comm_plan` / :func:`round_bytes` — the per-round analytic
  communication-bytes model ``comm`` events carry;
* ``python -m repro.telemetry.validate`` — schema validation + comm-bytes
  reconciliation; ``python -m repro.launch.metrics`` — the summarizer.

The in-band metrics themselves are computed by the fused engine
(``repro.optim.sequences.make_engine(..., telemetry=)``) as a side output
of the jitted step — with the layer absent the side output stays exactly
``{"step": ...}`` and every trajectory, jit cache key and checkpoint
structure is bit-identical to a telemetry-free build.
"""
from repro.telemetry.comm import CommPlan, comm_plan, round_bytes
from repro.telemetry.events import (EVENT_SCHEMA_VERSION, REQUIRED_KEYS,
                                    EventLog, TelemetryError, read_events)
from repro.telemetry.spec import (METRIC_GROUPS, TelemetrySpec,
                                  resolve_metric_groups)
from repro.telemetry.trace import annotate, measure_run, phase
from repro.telemetry.validate import validate_events

__all__ = [
    "CommPlan", "EVENT_SCHEMA_VERSION", "EventLog", "METRIC_GROUPS",
    "REQUIRED_KEYS", "TelemetryError", "TelemetrySpec", "annotate",
    "comm_plan", "measure_run", "phase", "read_events",
    "resolve_metric_groups", "round_bytes", "validate_events",
]
