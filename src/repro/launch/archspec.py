"""Per-architecture deployment decisions (DESIGN.md §4–§5).

* placement / client count — memory napkin math per pod (4 TB HBM);
* algorithm — fedbioacc everywhere it fits; llama3-405b runs fedbio
  (Algorithm 1: one body-sized persistent tensor per client instead of two);
* microbatching — bounds activation memory of the remat'd loss scan;
* shape applicability — decode shapes skip encoder-only; long_500k only for
  sub-quadratic families (ssm / hybrid / gemma2's sliding-window layers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.config import INPUT_SHAPES, MeshConfig, ModelConfig


@dataclass(frozen=True)
class DeploySpec:
    placement: str            # client_sharded | client_replicated | client_pure
    num_clients: int          # single-pod client count (doubles on multi-pod
                              # for client_sharded)
    algorithm: str            # fedbio | fedbioacc
    n_micro_train: int        # microbatches per client in train_4k
    serve_fsdp: bool          # shard serve-params over "data" too
    fuse_oracles: bool = False  # §Perf beyond-paper: fused hyper-grad oracles


_SPECS = {
    "llama3-405b": DeploySpec("client_replicated", 2, "fedbio", 16, True),
    "internvl2-76b": DeploySpec("client_replicated", 2, "fedbioacc", 16, True),
}
_DEFAULT = DeploySpec("client_sharded", 16, "fedbioacc", 4, False)

# §Perf-optimized deployments (EXPERIMENTS.md §Perf — beyond-paper):
#  * fused oracles everywhere (same math, fewer weight-streaming passes);
#  * client_pure for the sub-2B archs: 256 clients consume the whole mesh,
#    eliminating tensor-parallel activation all-reduces entirely (the
#    paper's own preferred regime — more clients, linear speedup).
_OPTIMIZED = {
    # n_micro 16→8: the fused oracle cut activation temporaries ~18× (3.2 TB
    # → 178 GB global), buying headroom to halve the ZeRO-3 regather count
    "llama3-405b": DeploySpec("client_replicated", 2, "fedbio", 8, True, True),
    "internvl2-76b": DeploySpec("client_replicated", 2, "fedbioacc", 8, True, True),
    "mamba2-130m": DeploySpec("client_pure", 256, "fedbioacc", 1, False, True),
    "granite-moe-1b-a400m": DeploySpec("client_pure", 256, "fedbioacc", 1, False, True),
    # gemma2 (2.6B): 16-way TP of a small model is all-reduce-bound; flip to
    # within-client data parallelism with vocab-sharded embed/head (§Perf)
    "gemma2-2b": DeploySpec("dp_within_client", 16, "fedbioacc", 4, False, True),
}
_OPT_DEFAULT = DeploySpec("client_sharded", 16, "fedbioacc", 4, False, True)

# long_500k is run only for sub-quadratic attention (see DESIGN.md §5)
_LONG_OK = {"mamba2-130m", "recurrentgemma-9b", "gemma2-2b"}


def deploy_spec(arch: str, optimized: bool = False) -> DeploySpec:
    if optimized:
        return _OPTIMIZED.get(arch, _OPT_DEFAULT)
    return _SPECS.get(arch, _DEFAULT)


def num_clients(arch: str, mesh: MeshConfig, optimized: bool = False) -> int:
    spec = deploy_spec(arch, optimized)
    if spec.placement == "client_pure" and mesh.multi_pod:
        # global batch (256) cannot feed 512 pure clients; multi-pod keeps
        # the single-pod client count replicated over the pod axis
        return spec.num_clients
    if spec.placement == "client_sharded" and mesh.multi_pod:
        return spec.num_clients * 2      # client axis spans ("pod","data")
    return spec.num_clients


def shape_applicable(arch: str, cfg: ModelConfig, shape_name: str
                     ) -> Tuple[bool, Optional[str]]:
    """(runs?, skip_reason)."""
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "decode":
        if cfg.family == "audio":
            return False, "encoder-only architecture has no decode step"
        if shape_name == "long_500k" and arch not in _LONG_OK:
            return False, ("pure full-attention architecture; long_500k "
                           "requires sub-quadratic attention")
    return True, None


def all_combos():
    """The assigned 10×4 grid with applicability annotations."""
    from repro.configs import ARCHS
    out = []
    for arch, cfg in ARCHS.items():
        for shape_name in INPUT_SHAPES:
            ok, reason = shape_applicable(arch, cfg, shape_name)
            out.append((arch, shape_name, ok, reason))
    return out
