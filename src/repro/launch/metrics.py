"""Summarize a telemetry event stream — ``python -m repro.launch.metrics``.

The reader half of ``repro.telemetry``: renders the JSONL event stream a
run wrote (``launch.train --telemetry-sink``, ``launch.dryrun``,
``benchmarks.run --events``) as

* a run summary (segments, rounds, final loss, wall-clock by phase,
  rollback/screening counts);
* a per-communication-round table (step, wire bytes, val loss, the
  u-sequence norms and client drift at that round) — ``--table``;
* the communication-efficiency curve the paper's plots are built on:
  cumulative wire MB vs round vs val loss — ``--comm``;
* the last N metric records verbatim — ``--tail N`` (the "tail the run"
  mode: point it at a live sink).

    python -m repro.launch.metrics events.jsonl
    python -m repro.launch.metrics events.jsonl --table --comm
    python -m repro.launch.metrics events.jsonl --tail 5
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.telemetry.events import read_events


def summarize(events: list) -> dict:
    by_type: dict = {}
    spans: dict = {}
    for ev in events:
        by_type.setdefault(ev.get("event"), []).append(ev)
        if ev.get("event") == "span":
            spans[ev["name"]] = spans.get(ev["name"], 0.0) + ev["dur_s"]
    losses = [(ev["step"], ev["val_loss"])
              for ev in by_type.get("metrics", ()) if "val_loss" in ev]
    comm = by_type.get("comm", ())
    out = {
        "segments": len(by_type.get("run_start", ())),
        "events": len(events),
        "counts": {k: len(v) for k, v in sorted(by_type.items())},
        "rounds_communicated": len({ev["round"] for ev in comm}),
        "wire_bytes_total": sum(ev["bytes_wire"] for ev in comm),
        "rollbacks": len(by_type.get("rollback", ())),
        "clients_screened": sorted({c
                                    for ev in by_type.get(
                                        "clients_screened", ())
                                    for c in ev["clients"]}),
        "span_seconds": {k: round(v, 3) for k, v in sorted(spans.items())},
        "final_val_loss": losses[-1][1] if losses else None,
        "status": (by_type["run_end"][-1]["status"]
                   if by_type.get("run_end") else "(no run_end — live or "
                                                  "crashed run)"),
    }
    return out


def round_table(events: list) -> list:
    """One row per communication round: the comm event joined with the
    in-band metrics of its step."""
    # a step can carry two metrics events (in-band + eval) — merge them;
    # rollback retries overwrite, so a row reflects the surviving attempt
    merged: dict = {}
    for ev in events:
        if ev.get("event") == "metrics":
            merged.setdefault(ev["step"], {}).update(ev)
    rows = []
    for ev in events:
        if ev.get("event") != "comm":
            continue
        m = merged.get(ev["step"], {})
        rows.append({"round": ev["round"], "step": ev["step"],
                     "retry": ev.get("retry", 0),
                     "bytes_wire": ev["bytes_wire"],
                     "val_loss": m.get("val_loss"),
                     "upd_norm/u": m.get("upd_norm/u"),
                     "mom_norm/u": m.get("mom_norm/u"),
                     "drift/x": m.get("drift/x")})
    return rows


def comm_curve(events: list) -> list:
    """Cumulative wire MB vs round vs the nearest val loss — the
    communication-efficiency curve."""
    losses = sorted((ev["step"], ev["val_loss"])
                    for ev in events
                    if ev.get("event") == "metrics" and "val_loss" in ev)
    rows, cum = [], 0
    for ev in events:
        if ev.get("event") != "comm":
            continue
        cum += ev["bytes_wire"]
        loss = None
        for s, l in losses:           # last loss at or before this step
            if s <= ev["step"]:
                loss = l
        rows.append({"round": ev["round"], "step": ev["step"],
                     "cum_wire_mb": round(cum / 2 ** 20, 3),
                     "val_loss": loss})
    return rows


def _fmt(v):
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.5g}"
    return str(v)


def _print_table(rows: list) -> None:
    if not rows:
        print("(no comm events)")
        return
    cols = list(rows[0])
    widths = [max(len(c), *(len(_fmt(r[c])) for r in rows)) for c in cols]
    print("  ".join(c.ljust(w) for c, w in zip(cols, widths)))
    for r in rows:
        print("  ".join(_fmt(r[c]).ljust(w) for c, w in zip(cols, widths)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="event stream JSONL (see repro.telemetry)")
    ap.add_argument("--table", action="store_true",
                    help="per-communication-round table")
    ap.add_argument("--comm", action="store_true",
                    help="cumulative wire-MB vs round vs val-loss curve")
    ap.add_argument("--tail", type=int, default=0, metavar="N",
                    help="print the last N metrics records verbatim")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output (one JSON object)")
    ns = ap.parse_args(argv)
    events = read_events(ns.path)
    if ns.tail:
        for ev in [e for e in events if e.get("event") == "metrics"][-ns.tail:]:
            print(json.dumps(ev))
        return 0
    out = {"summary": summarize(events)}
    if ns.table:
        out["rounds"] = round_table(events)
    if ns.comm:
        out["comm_curve"] = comm_curve(events)
    if ns.json:
        print(json.dumps(out, indent=1))
        return 0
    s = out["summary"]
    print(f"{ns.path}: {s['events']} events, {s['segments']} segment(s), "
          f"status={s['status']}")
    print(f"  rounds communicated: {s['rounds_communicated']}, total wire: "
          f"{s['wire_bytes_total'] / 2 ** 20:.2f} MB, rollbacks: "
          f"{s['rollbacks']}, screened clients: "
          f"{s['clients_screened'] or '-'}")
    if s["final_val_loss"] is not None:
        print(f"  final val_loss: {s['final_val_loss']:.6g}")
    if s["span_seconds"]:
        top = sorted(s["span_seconds"].items(), key=lambda kv: -kv[1])
        print("  wall by phase: "
              + ", ".join(f"{k}={v:.3f}s" for k, v in top[:6]))
    if ns.table:
        print()
        _print_table(out["rounds"])
    if ns.comm:
        print()
        _print_table(out["comm_curve"])
    return 0


if __name__ == "__main__":
    sys.exit(main())
