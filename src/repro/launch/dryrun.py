import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture × input shape) on the
# production mesh with 512 placeholder host devices, and extract the roofline
# inputs (HLO FLOPs / bytes from cost_analysis, collective bytes parsed from
# the compiled HLO, per-device memory from memory_analysis).
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results.json
#
# The env override above must precede ANY other import (jax locks the device
# count on first initialisation).

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import (INPUT_SHAPES, FederatedConfig, MeshConfig)  # noqa: E402
from repro.configs import ARCHS, get_config                 # noqa: E402
from repro.launch import archspec                           # noqa: E402
from repro.launch.hlo_stats import collective_bytes         # noqa: E402,F401
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.sharding import rules                            # noqa: E402


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str, mesh_cfg: MeshConfig,
                optimized: bool = False, num_clients: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this combo (no
    device allocation).  ``num_clients`` overrides the archspec client count
    (the fused-mesh path sizes M to the custom mesh's data axis)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    spec = archspec.deploy_spec(arch, optimized)
    S, B = shape.seq_len, shape.global_batch
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16

    def lm_batch(lead):
        b = {"tokens": jax.ShapeDtypeStruct(lead + (S,), i32),
             "labels": jax.ShapeDtypeStruct(lead + (S,), i32)}
        if cfg.family == "vlm":
            b["patches"] = jax.ShapeDtypeStruct(
                lead + (cfg.num_patches, cfg.frontend_dim), bf16)
        if cfg.family == "audio":
            b = {"frames": jax.ShapeDtypeStruct(lead + (S, cfg.frontend_dim), bf16),
                 "labels": jax.ShapeDtypeStruct(lead + (S,), i32)}
        return b

    if shape.kind == "train":
        M = (num_clients if num_clients is not None
             else archspec.num_clients(arch, mesh_cfg, optimized))
        per = max(B // M, 1)
        one = lm_batch((M, per))
        return {"train": one, "val": one}
    if shape.kind == "prefill":
        return lm_batch((B,))
    # decode: one token + position
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


# ---------------------------------------------------------------------------
# builders per mode
# ---------------------------------------------------------------------------

def build_train(arch: str, shape_name: str, mesh, mesh_cfg: MeshConfig,
                optimized: bool = False):
    from repro.api import registry
    cfg = get_config(arch)
    spec = archspec.deploy_spec(arch, optimized)
    M = archspec.num_clients(arch, mesh_cfg, optimized)
    model = build_model(cfg)
    # the unfused production-mesh lowering keeps its rules-driven pjit
    # shardings (archspec placement), but dispatches through the registry —
    # no bespoke per-algorithm maker choice
    fed = FederatedConfig(algorithm=spec.algorithm, num_clients=M,
                          local_steps=4, placement=spec.placement)
    init, step = registry.get(spec.algorithm).factory(
        model, fed, n_micro=spec.n_micro_train, remat=True,
        fuse_oracles=spec.fuse_oracles)
    state_shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
    batch_shapes = input_specs(arch, shape_name, mesh_cfg, optimized)

    state_spec = rules.state_specs(state_shapes, mesh_cfg, placement=spec.placement)
    batch_spec = rules.batch_specs(batch_shapes, mesh_cfg, client_axis=True,
                                   placement=spec.placement)
    in_sh = (_named(mesh, state_spec), _named(mesh, batch_spec))
    out_sh = (_named(mesh, state_spec), _named(mesh, jax.tree.map(
        lambda _: P(), jax.eval_shape(step, state_shapes, batch_shapes)[1])))
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0,))
    return jitted, (state_shapes, batch_shapes)


def _experiment_for_fused(arch: str, fused_mesh: tuple, optimized: bool,
                          overlap: bool, num_clients: int):
    """The declarative Experiment the ``--fused-mesh`` dry-run lowers: the
    archspec deployment (algorithm, fused oracles, microbatching) as spec
    fields — the same ``repro.api.build`` path train/bench/resume use."""
    from repro.api import (AlgorithmSpec, ExecutionSpec, Experiment,
                           ProblemSpec, ScheduleSpec)
    spec = archspec.deploy_spec(arch, optimized)
    return Experiment(
        algorithm=AlgorithmSpec(spec.algorithm),
        problem=ProblemSpec(arch=arch, reduced=False,
                            num_clients=num_clients),
        execution=ExecutionSpec(fuse_storm=True,
                                fuse_oracles=spec.fuse_oracles,
                                mesh=tuple(fused_mesh), overlap=overlap,
                                n_micro=spec.n_micro_train, remat=True),
        schedule=ScheduleSpec(local_steps=4))


def _jit_sharded_run(run, state_shapes, batch_shapes):
    """jit a built Run's step for mesh lowering — flat-state shardings from
    the run, batches client-axis over "data", metrics replicated, state
    donated (the one sharded-train jit recipe, shared by ``--fused-mesh``
    and ``--experiment``)."""
    state_sh = run.shardings(state_shapes)
    batch_sh = jax.tree.map(
        lambda l: NamedSharding(run.mesh,
                                P(*(("data",) + (None,) * (l.ndim - 1)))),
        batch_shapes)
    metrics_sh = jax.tree.map(
        lambda _: NamedSharding(run.mesh, P()),
        jax.eval_shape(run.step, state_shapes, batch_shapes)[1])
    return jax.jit(run.step, in_shardings=(state_sh, batch_sh),
                   out_shardings=(state_sh, metrics_sh),
                   donate_argnums=(0,))


def build_train_fused(arch: str, shape_name: str, mesh, mesh_cfg: MeshConfig,
                      optimized: bool = False, overlap: bool = False):
    """Fused sharded flat-substrate train step on a custom ("data", "model")
    mesh: [M, N] buffers partitioned by ``rules.flat_state_specs``, fused
    launches + psum reductions under shard_map (``--fused-mesh``).  Built
    through ``repro.api.build`` from a declarative Experiment."""
    from repro.api import build as api_build
    axes = dict(mesh.shape)
    M = 2 * axes["data"]                  # two clients per data shard
    exp = _experiment_for_fused(arch, (axes["data"], axes["model"]),
                                optimized, overlap, M)
    run = api_build(exp)
    state_shapes = jax.eval_shape(run.init, jax.random.PRNGKey(0))
    batch_shapes = input_specs(arch, shape_name, mesh_cfg, optimized,
                               num_clients=M)
    return _jit_sharded_run(run, state_shapes, batch_shapes), \
        (state_shapes, batch_shapes)


def build_train_experiment(exp_path: str):
    """``--experiment exp.json``: lower the spec'd run exactly as
    ``launch.train`` would execute it (state/batch shapes from the spec
    itself; sharded iff the spec carries a mesh)."""
    from repro.api import Experiment, build as api_build
    run = api_build(Experiment.load(exp_path))
    state_shapes = jax.eval_shape(run.init, jax.random.PRNGKey(0))
    batch_shapes = jax.eval_shape(run.batch_fn, jax.random.PRNGKey(0))
    if run.mesh is None:
        jitted = jax.jit(run.step, donate_argnums=(0,))
        return jitted, (state_shapes, batch_shapes), run
    return (_jit_sharded_run(run, state_shapes, batch_shapes),
            (state_shapes, batch_shapes), run)


def build_prefill(arch: str, shape_name: str, mesh, mesh_cfg: MeshConfig):
    cfg = get_config(arch)
    spec = archspec.deploy_spec(arch)
    model = build_model(cfg)
    S = INPUT_SHAPES[shape_name].seq_len
    batch_shapes = input_specs(arch, shape_name, mesh_cfg)

    if cfg.family == "audio":
        def fn(params, batch):
            logits, _ = model.forward(params, batch, remat=True)
            return logits[:, -1, :]
    else:
        def fn(params, batch):
            last, caches = model.prefill(params, batch, cache_len=S)
            return last, caches

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = rules.param_specs(params_shapes, mesh_cfg, placement="client_sharded",
                               client_axis=False, fsdp=spec.serve_fsdp)
    b_spec = rules.batch_specs(batch_shapes, mesh_cfg, client_axis=False)
    jitted = jax.jit(fn, in_shardings=(_named(mesh, p_spec), _named(mesh, b_spec)))
    return jitted, (params_shapes, batch_shapes)


def build_decode(arch: str, shape_name: str, mesh, mesh_cfg: MeshConfig):
    cfg = get_config(arch)
    spec = archspec.deploy_spec(arch)
    model = build_model(cfg)
    shape = INPUT_SHAPES[shape_name]
    S, B = shape.seq_len, shape.global_batch
    cache_len = S + (cfg.num_patches if cfg.family == "vlm" else 0)

    def fn(params, caches, tokens, pos):
        from repro.sharding.hints import sharding_hints
        with sharding_hints():
            return model.decode_step(params, caches, tokens, pos)

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, cache_len))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    p_spec = rules.param_specs(params_shapes, mesh_cfg, placement="client_sharded",
                               client_axis=False, fsdp=spec.serve_fsdp)
    c_spec = rules.cache_specs(cache_shapes, mesh_cfg)
    jitted = jax.jit(fn, in_shardings=(
        _named(mesh, p_spec), _named(mesh, c_spec),
        NamedSharding(mesh, P()), NamedSharding(mesh, P())),
        donate_argnums=(1,))
    return jitted, (params_shapes, cache_shapes, tok, pos)


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def _compiled_stats(compiled, rec: Dict[str, Any], keep_hlo: bool) -> None:
    """memory/cost/collective analysis shared by every dry-run mode."""
    mem: Dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:            # pragma: no cover
        mem["error"] = str(e)

    cost: Dict[str, float] = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k in ("flops", "bytes accessed", "transcendentals",
                  "bytes accessed output", "optimal_seconds"):
            if k in ca:
                cost[k] = float(ca[k])
    except Exception as e:            # pragma: no cover
        cost["error"] = str(e)

    hlo = compiled.as_text()
    rec.update(memory=mem, cost=cost, collectives=collective_bytes(hlo),
               hlo_bytes=len(hlo))
    if keep_hlo:
        rec["hlo"] = hlo


# The compressed-collective audit is SHARED with the static verifier
# (repro.analysis audits the comm-only subprogram with the same function,
# its W103 rule) — one byte model, no drift between the two consumers.
from repro.analysis.collectives import (                     # noqa: E402
    check_compressed_collectives as _check_compressed_collectives)


def run_experiment(exp_path: str, *, keep_hlo: bool = False) -> Dict[str, Any]:
    """Lower + compile one declarative Experiment spec (``--experiment``)."""
    rec: Dict[str, Any] = {"experiment": exp_path, "kind": "train"}
    t0 = time.time()  # analysis: ignore[L301] driver timing
    jitted, args, run = build_train_experiment(exp_path)
    mesh = run.mesh
    if mesh is not None:
        rec["mesh"] = dict(mesh.shape)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0  # analysis: ignore[L301] driver timing
            compiled = lowered.compile()
    else:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0  # analysis: ignore[L301] driver timing
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower  # analysis: ignore[L301] driver timing
    rec.update(status="OK", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1))
    _compiled_stats(compiled, rec, keep_hlo)
    exp = run.spec
    if exp.compression is not None and exp.compression.quant is not None:
        if mesh is None:
            rec["compression_check"] = "unsharded: no collectives to audit"
        else:
            rec["compression_check"] = _check_compressed_collectives(
                exp, run.step.spec, rec["collectives"])
    if exp.telemetry is not None:
        # the dryrun's side of the bytes reconciliation: the MEASURED
        # per-dtype collective bytes of the compiled step next to the
        # analytic per-round model the train driver's `comm` events carry
        from repro.telemetry import EventLog, comm_plan, round_bytes
        sink = exp.telemetry.sink or "dryrun_events.jsonl"
        with EventLog(sink, experiment=json.loads(exp.to_json()),
                      kind="dryrun") as log:
            log.emit("hlo_collectives",
                     bytes_by_dtype=rec["collectives"]["bytes_by_dtype"],
                     counts=rec["collectives"].get("counts"),
                     sharded=mesh is not None)
            flat_spec = getattr(run.step, "spec", None)
            aspec = getattr(run.step, "aspec", None)
            if flat_spec is not None and aspec is not None:
                plan = comm_plan(flat_spec, aspec, exp.compression)
                rb = round_bytes(plan, 1) if plan is not None else None
                if rb is not None:
                    log.emit("comm", step=exp.schedule.local_steps,
                             retry=0, **rb)
        rec["telemetry_sink"] = sink
    return rec


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            keep_hlo: bool = False, optimized: bool = False,
            fused_mesh: tuple | None = None,
            overlap: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    ok, reason = archspec.shape_applicable(arch, cfg, shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod, "optimized": optimized}
    if fused_mesh is not None:
        rec["fused_mesh"] = list(fused_mesh)
        rec["overlap"] = overlap
    if not ok:
        rec.update(status="SKIP", reason=reason)
        return rec

    mesh_cfg = MeshConfig(multi_pod=multi_pod)
    kind = INPUT_SHAPES[shape_name].kind
    if fused_mesh is not None:
        if kind != "train":
            rec.update(status="SKIP",
                       reason="--fused-mesh applies to train shapes only")
            return rec
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(*fused_mesh)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()  # analysis: ignore[L301] driver timing
    with mesh:
        if kind == "train" and fused_mesh is not None:
            jitted, args = build_train_fused(arch, shape_name, mesh, mesh_cfg,
                                             optimized=optimized,
                                             overlap=overlap)
        elif kind == "train":
            jitted, args = build_train(arch, shape_name, mesh, mesh_cfg,
                                       optimized=optimized)
        elif kind == "prefill":
            jitted, args = build_prefill(arch, shape_name, mesh, mesh_cfg)
        else:
            jitted, args = build_decode(arch, shape_name, mesh, mesh_cfg)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0  # analysis: ignore[L301] driver timing
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower  # analysis: ignore[L301] driver timing

    rec.update(status="OK", kind=kind, lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1))
    _compiled_stats(compiled, rec, keep_hlo)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="§Perf-optimized deployment (fused oracles, "
                         "client_pure placement for small archs)")
    ap.add_argument("--fused-mesh", default=None, metavar="DATA,MODEL",
                    help="lower the FUSED sharded flat-substrate train step "
                         "on a custom (data, model) mesh instead of the "
                         "unfused step on the production mesh (train shapes "
                         "only): shard_map launches + real psum collectives")
    ap.add_argument("--overlap", action="store_true",
                    help="with --fused-mesh: the comm/compute overlap "
                         "schedule (variable all-reduce issued concurrently "
                         "with the new-iterate oracle)")
    ap.add_argument("--experiment", default=None, metavar="EXP.json",
                    help="lower ONE declarative repro.api Experiment spec "
                         "instead of the (arch × shape) grid — the exact "
                         "run launch.train would execute (sharded iff the "
                         "spec carries a mesh)")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) on the chosen mesh")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()
    fused_mesh = (tuple(int(v) for v in args.fused_mesh.split(","))
                  if args.fused_mesh else None)

    if args.experiment:
        try:
            rec = run_experiment(args.experiment)
        except Exception as e:
            rec = {"experiment": args.experiment, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}"}
        print(json.dumps({k: v for k, v in rec.items() if k != "hlo"},
                         indent=1), flush=True)
        if args.out:
            with open(args.out, "a") as fh:
                fh.write(json.dumps(rec) + "\n")
        if rec["status"] != "OK":
            raise SystemExit(1)
        return

    combos = []
    if args.all:
        for arch, shape_name, _, _ in archspec.all_combos():
            combos.append((arch, shape_name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    records = []
    for arch, shape_name in combos:
        print(f"=== {arch} × {shape_name} (multi_pod={args.multi_pod}) ===",
              flush=True)
        try:
            rec = run_one(arch, shape_name, multi_pod=args.multi_pod,
                          optimized=args.optimized, fused_mesh=fused_mesh,
                          overlap=args.overlap)
        except Exception as e:        # record failures — they are bugs
            rec = {"arch": arch, "shape": shape_name,
                   "multi_pod": args.multi_pod, "optimized": args.optimized,
                   "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}"}
        print(json.dumps({k: v for k, v in rec.items() if k != "hlo"},
                         indent=1), flush=True)
        records.append(rec)
        if args.out:
            with open(args.out, "a") as fh:
                fh.write(json.dumps(rec) + "\n")

    n_ok = sum(r["status"] == "OK" for r in records)
    n_skip = sum(r["status"] == "SKIP" for r in records)
    n_fail = sum(r["status"] == "FAIL" for r in records)
    print(f"done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
