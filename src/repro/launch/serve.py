"""Batched serving driver: prefill a batch of prompts then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "audio":
        raise SystemExit("encoder-only architecture has no decode step")
    model = build_model(cfg, dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": prompts, "labels": prompts}
    offset = 0
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_patches, cfg.frontend_dim))
        offset = cfg.num_patches

    cache_len = offset + S + args.gen
    t0 = time.time()  # analysis: ignore[L301] driver timing
    last, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len))(params, batch)
    print(f"prefill {B}x{S} in {time.time()-t0:.2f}s")  # analysis: ignore[L301] driver timing

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()  # analysis: ignore[L301] driver timing
    for i in range(args.gen - 1):
        pos = jnp.int32(offset + S + i)
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0  # analysis: ignore[L301] driver timing
    print(f"decoded {args.gen-1} steps x {B} seqs in {dt:.2f}s "
          f"({(args.gen-1)*B/max(dt,1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())
    assert not bool(jnp.isnan(logits).any())
    return gen


if __name__ == "__main__":
    main()
