"""Federated bilevel training driver.

Runs the same train-step code path the dry-run lowers, on whatever devices
exist (CPU debug mesh in this container, the production mesh on real pods).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \
        --algo fedbioacc --steps 100 --clients 4 --per-client 2 --seq 128

Checkpoints land in --ckpt-dir every --ckpt-every rounds.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import FederatedConfig
from repro.configs import ARCHS, get_config
from repro.data import make_fed_batch_fn
from repro.federation.participation import ParticipationSpec
from repro.federation.trainer import (make_fedavg_train_step,
                                      make_fedbio_local_train_step,
                                      make_fedbio_train_step,
                                      make_fedbioacc_local_train_step,
                                      make_fedbioacc_train_step)
from repro.launch.mesh import parse_mesh_arg
from repro.models import build_model

_MAKERS = {
    "fedbio": make_fedbio_train_step,
    "fedbioacc": make_fedbioacc_train_step,
    "fedbio_local": make_fedbio_local_train_step,
    "fedbioacc_local": make_fedbioacc_local_train_step,
    "fedavg": make_fedavg_train_step,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced same-family variant (CPU-sized)")
    ap.add_argument("--algo", choices=sorted(_MAKERS), default="fedbioacc")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--per-client", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr-x", type=float, default=0.02)
    ap.add_argument("--lr-y", type=float, default=0.05)
    ap.add_argument("--lr-u", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--hierarchy-period", type=int, default=0,
                    help="k>0: pod-local averaging, cross-pod only every "
                         "k-th round (all algorithms honor this)")
    ap.add_argument("--neumann-q", type=int, default=8,
                    help="Neumann series terms for the local-lower "
                         "hyper-gradient (fedbio_local/fedbioacc_local)")
    ap.add_argument("--participation",
                    choices=["full", "uniform", "weighted", "trace"],
                    default="full",
                    help="client sampler: m-of-M uniform/data-size-weighted "
                         "sampling or a trace-driven availability process "
                         "(non-participants frozen, participants-only means)")
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="m for the uniform/weighted samplers (0 = all "
                         "clients; implies --participation uniform when set)")
    ap.add_argument("--availability-seed", type=int, default=0,
                    help="seed of the deterministic per-round availability "
                         "process (resume-safe: masks depend only on "
                         "seed + round)")
    ap.add_argument("--availability-rate", type=float, default=0.7,
                    help="trace sampler: per-round client up-probability")
    ap.add_argument("--availability-trace", default=None, metavar="PATH.json",
                    help="recorded availability log ([rounds, clients] 0/1 "
                         "JSON matrix) replayed deterministically (cyclic) "
                         "through the trace sampler; implies "
                         "--participation trace")
    ap.add_argument("--client-weights", default=None,
                    help="comma-separated per-client data sizes (required by "
                         "--participation weighted; also weights the means)")
    ap.add_argument("--stale-discount", type=float, default=1.0,
                    help="alpha^staleness discount for returning clients' "
                         "contributions (fused engine only; 1.0 = off; "
                         "a no-op under full participation)")
    ap.add_argument("--fuse-storm", action="store_true",
                    help="flat-buffer substrate: the algorithm's sequence "
                         "spec compiled to fused triple-sequence updates "
                         "+ section-masked communication (all algorithms)")
    ap.add_argument("--fuse-oracles", action="store_true",
                    help="share one linearization (and one batch) across "
                         "the oracle directions (no-op for fedavg)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="shard the flat substrate over a (data, model) "
                         "device mesh (e.g. 4,2 under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8, or "
                         "'production' for the 16x16 pod mesh): clients "
                         "over 'data', packed params over 'model', real "
                         "psum collectives under shard_map; needs "
                         "--fuse-storm")
    ap.add_argument("--overlap", action="store_true",
                    help="comm/compute overlap: issue the variable-section "
                         "all-reduce concurrently with the new-iterate "
                         "oracle (STORM algorithms; needs --fuse-storm)")
    ap.add_argument("--scatter-comm", action="store_true",
                    help="with --mesh: lower the participant mean to the "
                         "psum_scatter + all_gather all-reduce decomposition "
                         "instead of one psum (the form XLA can software-"
                         "pipeline with compute)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    fed = FederatedConfig(algorithm=args.algo, num_clients=args.clients,
                          local_steps=args.local_steps, lr_x=args.lr_x,
                          lr_y=args.lr_y, lr_u=args.lr_u,
                          hierarchy_period=args.hierarchy_period,
                          neumann_q=args.neumann_q)
    sampler = args.participation
    if args.availability_trace:
        # check before the clients-per-round promotion below so the error
        # names the flag the user actually passed
        if args.clients_per_round:
            raise SystemExit(
                "--availability-trace drives participation from the "
                "recorded log — --clients-per-round has no effect; unset it")
        if sampler not in ("full", "trace"):
            raise SystemExit(
                f"--availability-trace replays a recorded log through the "
                f"trace sampler — it conflicts with --participation "
                f"{sampler} (drop one of the two)")
        sampler = "trace"
    elif sampler == "full" and args.clients_per_round:
        sampler = "uniform"
    pspec = None
    if sampler != "full":
        cw = (tuple(float(v) for v in args.client_weights.split(","))
              if args.client_weights else None)
        pspec = ParticipationSpec(
            sampler=sampler, clients_per_round=args.clients_per_round,
            client_weights=cw, seed=args.availability_seed,
            availability_rate=args.availability_rate,
            stale_discount=args.stale_discount,
            trace_path=args.availability_trace)
    elif args.stale_discount != 1.0:
        # full participation keeps every staleness counter at 0, so the
        # discount could never bite — flag the no-op instead of aborting
        print("--stale-discount ignored: full participation has no "
              "stale clients (pick a sampler)")
    mesh = parse_mesh_arg(args.mesh) if args.mesh else None
    if args.overlap and mesh is None:
        # overlap re-schedules the STORM round (a documented algorithmic
        # deviation at comm rounds) — without a mesh there is no collective
        # to hide, so refuse rather than silently change the trajectory
        raise SystemExit("--overlap needs --mesh: the overlap schedule "
                         "exists to hide the data-axis collective behind "
                         "the new-iterate oracle")
    if mesh is not None:
        axes = dict(mesh.shape)
        if args.clients % axes["data"]:
            raise SystemExit(f"--clients {args.clients} must be divisible by "
                             f"the mesh data axis ({axes['data']})")
        print(f"mesh: data={axes['data']} model={axes['model']} "
              f"({len(mesh.devices.flat)} devices)"
              + (" overlap=on" if args.overlap else "")
              + (" comm=psum_scatter" if args.scatter_comm else ""))
    elif args.scatter_comm:
        print("--scatter-comm ignored: needs --mesh")
    mesh_arg = mesh
    if mesh is not None and args.scatter_comm:
        from repro.optim.flat import make_shard_ctx
        mesh_arg = make_shard_ctx(mesh, use_scatter=True)
    # every factory takes the full uniform switch set (sequence-spec engine)
    init, step = _MAKERS[args.algo](model, fed, n_micro=1, remat=False,
                                    fuse_storm=args.fuse_storm,
                                    fuse_oracles=args.fuse_oracles,
                                    participation=pspec,
                                    mesh=mesh_arg, overlap=args.overlap)
    if pspec is not None:
        if pspec.trace_path is not None:
            detail = f"log={pspec.trace_path}"
        elif pspec.sampler == "trace":
            detail = f"rate={pspec.availability_rate}"
        else:
            detail = f"m={pspec.clients_per_round or args.clients}/{args.clients}"
        print(f"participation: {pspec.sampler} {detail} seed={pspec.seed}")
    # flat-substrate states expose pytree views for eval/checkpoint
    as_view = step.views if hasattr(step, "views") else (lambda s: s)
    batch_fn = make_fed_batch_fn(cfg, num_clients=args.clients,
                                 per_client=args.per_client, seq_len=args.seq,
                                 seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    state = init(key)
    jstep = jax.jit(step, donate_argnums=(0,))
    if mesh is not None:
        # batches ride the mesh too: client axis over "data", rest replicated
        from jax.sharding import NamedSharding, PartitionSpec as P
        b_shard = NamedSharding(mesh, P("data"))
        place_batch = lambda b: jax.device_put(b, jax.tree.map(
            lambda _: b_shard, b))
    else:
        place_batch = lambda b: b
    # the eval batch is fixed — generate it once, not per eval_loss call
    eval_batch = jax.tree.map(lambda v: v[0], batch_fn(jax.random.PRNGKey(123)))

    def eval_loss(state):
        state = as_view(state)
        p = (state.params if hasattr(state, "params")
             else {"body": state.x, "head": state.y})
        p0 = jax.tree.map(lambda v: v[0], p)
        l, _ = model.loss(p0, eval_batch["val"])
        return float(l)

    # parameter count from shapes only — no second full model.init
    n_params = sum(int(np.prod(s.shape)) for s in
                   jax.tree.leaves(jax.eval_shape(model.init, key)))
    print(f"arch={cfg.name} family={cfg.family} algo={args.algo} "
          f"params={n_params:,}")
    t0 = time.time()
    history = []
    for t in range(args.steps):
        key, sub = jax.random.split(key)
        state, metrics = jstep(state, place_batch(batch_fn(sub)))
        if (t + 1) % args.log_every == 0 or t == 0:
            l = eval_loss(state)
            history.append({"step": t + 1, "val_loss": l,
                            "wall_s": round(time.time() - t0, 1)})
            print(json.dumps(history[-1]), flush=True)
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            payload = as_view(state)._asdict()
            # the legacy view drops FlatState.stale — without the per-client
            # staleness counters a discounted run cannot resume exactly
            stale = getattr(state, "stale", ())
            if not isinstance(stale, tuple):
                payload["stale"] = stale
            save_checkpoint(args.ckpt_dir, payload,
                            {"step": t + 1, "arch": cfg.name})
            print(f"checkpoint @ step {t+1} -> {args.ckpt_dir}")
    assert not any(jnp.isnan(jnp.asarray(h["val_loss"])) for h in history)
    return history


if __name__ == "__main__":
    main()
