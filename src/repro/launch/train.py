"""Federated bilevel training driver — a thin adapter over ``repro.api``.

Every run IS a declarative :class:`repro.api.Experiment`: flags never feed a
bespoke kwargs pile, they produce spec edits applied to a base Experiment
(the built-in defaults, ``--experiment exp.json``, or the spec embedded in a
checkpoint).  The spec is embedded in every checkpoint, so resume needs no
re-specified flags.

    # flags build a spec
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \
        --algo fedbioacc --steps 100 --clients 4 --per-client 2 --seq 128

    # a committed spec runs as-is; flags override individual fields
    PYTHONPATH=src python -m repro.launch.train --experiment exp.json
    PYTHONPATH=src python -m repro.launch.train --experiment exp.json --steps 500

    # resume reconstructs the exact run from the embedded spec — zero flags;
    # a spec-affecting flag that CONTRADICTS the embedded spec fails loudly
    PYTHONPATH=src python -m repro.launch.train --resume ckpt_dir

Checkpoints land in --ckpt-dir every --ckpt-every rounds (raw train state +
``experiment.json``).

Fault tolerance (see ``repro.federation.faults``): with
``experiment.robustness`` set, the loop snapshots last-known-good states
and rolls back on a non-finite or spiking eval loss (re-drawing batches and
fault masks for the retried rounds) before failing loudly; without it a
non-finite eval loss still fails loudly — diagnostic checkpoint + non-zero
exit naming the offending round.  ``--max-restarts N`` supervises the run
in a subprocess and auto-resumes from the latest checkpoint after a crash
(``--crash-at-step`` injects one for testing).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Experiment, RollbackError, RollbackGuard, SpecError, \
    build
from repro.checkpoint import (checkpoint_metadata, load_checkpoint,
                              load_experiment, save_checkpoint)
from repro.configs import ARCHS

# CLI dest → dotted Experiment path, for every flag that simply sets one
# spec field.  Flags with coupled semantics (--seed, --participation
# promotions, --mesh parsing, --comm-every) are handled in
# :func:`apply_overrides` below.
_FLAG_PATHS = {
    "algo": "algorithm.name",
    "arch": "problem.arch",
    "reduced": "problem.reduced",
    "clients": "problem.num_clients",
    "per_client": "problem.per_client",
    "seq": "problem.seq_len",
    "steps": "schedule.steps",
    "local_steps": "schedule.local_steps",
    "lr_x": "schedule.lr_x",
    "lr_y": "schedule.lr_y",
    "lr_u": "schedule.lr_u",
    "hierarchy_period": "schedule.hierarchy_period",
    "neumann_q": "schedule.neumann_q",
    "fuse_storm": "execution.fuse_storm",
    "fuse_oracles": "execution.fuse_oracles",
    "overlap": "execution.overlap",
    "scatter_comm": "execution.scatter_comm",
    "participation": "participation.sampler",
    "clients_per_round": "participation.clients_per_round",
    "availability_seed": "participation.seed",
    "availability_rate": "participation.availability_rate",
    "availability_trace": "participation.trace_path",
    "stale_discount": "participation.stale_discount",
    "telemetry_sink": "telemetry.sink",
}


def _parser() -> argparse.ArgumentParser:
    S = argparse.SUPPRESS   # spec-affecting flags: only record what was SET
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--experiment", default=None, metavar="EXP.json",
                    help="base Experiment spec (repro.api JSON); other "
                         "flags override individual fields")
    ap.add_argument("--resume", default=None, metavar="CKPT_DIR",
                    help="reconstruct the run from the checkpoint's embedded "
                         "experiment.json and continue it; spec-affecting "
                         "flags must match the embedded spec")
    ap.add_argument("--arch", choices=sorted(ARCHS), default=S)
    ap.add_argument("--reduced", action="store_true", default=S,
                    help="train the reduced same-family variant (CPU-sized)")
    ap.add_argument("--algo", default=S,
                    help="registered algorithm name (repro.api.algorithms())")
    ap.add_argument("--steps", type=int, default=S)
    ap.add_argument("--clients", type=int, default=S)
    ap.add_argument("--local-steps", type=int, default=S)
    ap.add_argument("--per-client", type=int, default=S)
    ap.add_argument("--seq", type=int, default=S)
    ap.add_argument("--lr-x", type=float, default=S)
    ap.add_argument("--lr-y", type=float, default=S)
    ap.add_argument("--lr-u", type=float, default=S)
    ap.add_argument("--seed", type=int, default=S,
                    help="sets both problem.data_seed and schedule.seed")
    ap.add_argument("--hierarchy-period", type=int, default=S,
                    help="k>0: pod-local averaging, cross-pod only every "
                         "k-th round (all algorithms honor this)")
    ap.add_argument("--neumann-q", type=int, default=S,
                    help="Neumann series terms for the local-lower "
                         "hyper-gradient (fedbio_local/fedbioacc_local)")
    ap.add_argument("--comm-every", default=S, metavar="SEC=K[,SEC=K]",
                    help="per-section async communication cadence, e.g. "
                         "'u=2' reduces the u sequence every 2nd comm round")
    ap.add_argument("--participation",
                    choices=["full", "uniform", "weighted", "trace"],
                    default=S,
                    help="client sampler: m-of-M uniform/data-size-weighted "
                         "sampling or a trace-driven availability process "
                         "(non-participants frozen, participants-only means)")
    ap.add_argument("--clients-per-round", type=int, default=S,
                    help="m for the uniform/weighted samplers (0 = all "
                         "clients; implies --participation uniform when set)")
    ap.add_argument("--availability-seed", type=int, default=S,
                    help="seed of the deterministic per-round availability "
                         "process (resume-safe: masks depend only on "
                         "seed + round)")
    ap.add_argument("--availability-rate", type=float, default=S,
                    help="trace sampler: per-round client up-probability")
    ap.add_argument("--availability-trace", default=S, metavar="PATH.json",
                    help="recorded availability log ([rounds, clients] 0/1 "
                         "JSON matrix) replayed deterministically (cyclic) "
                         "through the trace sampler; implies "
                         "--participation trace")
    ap.add_argument("--client-weights", default=S,
                    help="comma-separated per-client data sizes (required by "
                         "--participation weighted; also weights the means)")
    ap.add_argument("--stale-discount", type=float, default=S,
                    help="alpha^staleness discount for returning clients' "
                         "contributions (1.0 = off; a no-op under full "
                         "participation)")
    ap.add_argument("--fuse-storm", action="store_true", default=S,
                    help="flat-buffer substrate: the algorithm's sequence "
                         "spec compiled to fused triple-sequence updates "
                         "+ section-masked communication (all algorithms)")
    ap.add_argument("--fuse-oracles", action="store_true", default=S,
                    help="share one linearization (and one batch) across "
                         "the oracle directions (no-op for fedavg)")
    ap.add_argument("--mesh", default=S, metavar="DATA,MODEL",
                    help="shard the flat substrate over a (data, model) "
                         "device mesh (e.g. 4,2 under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8, or "
                         "'production' for the 16x16 pod mesh): clients "
                         "over 'data', packed params over 'model', real "
                         "psum collectives under shard_map; needs "
                         "--fuse-storm")
    ap.add_argument("--overlap", action="store_true", default=S,
                    help="comm/compute overlap: issue the variable-section "
                         "all-reduce concurrently with the new-iterate "
                         "oracle (STORM algorithms; needs --mesh)")
    ap.add_argument("--scatter-comm", action="store_true", default=S,
                    help="with --mesh: lower the participant mean to the "
                         "psum_scatter + all_gather all-reduce decomposition "
                         "instead of one psum (the form XLA can software-"
                         "pipeline with compute)")
    ap.add_argument("--telemetry-sink", default=S, metavar="EVENTS.jsonl",
                    help="write the structured event stream here (a spec "
                         "edit: enables experiment.telemetry when absent; "
                         "see repro.telemetry)")
    # driver-only knobs (never part of the spec / trajectory)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-restarts", type=int, default=0,
                    help="supervise the run in a subprocess and auto-resume "
                         "from the latest --ckpt-dir checkpoint after a "
                         "crash, up to N times (requires --ckpt-dir)")
    ap.add_argument("--restart-backoff", type=float, default=1.0,
                    help="base seconds between restart attempts (doubles "
                         "each retry)")
    ap.add_argument("--crash-at-step", type=int, default=0,
                    help="testing: hard-exit the process after completing "
                         "this step (fresh runs only — inert on --resume, "
                         "so a supervised restart runs to completion)")
    return ap


def apply_overrides(base: Experiment, ov: dict) -> Experiment:
    """Apply explicitly-passed CLI flags as spec edits (the only way flags
    reach the run), mirroring the CLI's coupled-flag semantics."""
    edits = {}
    for dest, path in _FLAG_PATHS.items():
        if dest in ov:
            edits[path] = ov[dest]
    if "seed" in ov:
        edits["problem.data_seed"] = ov["seed"]
        edits["schedule.seed"] = ov["seed"]
    if "client_weights" in ov:
        edits["participation.client_weights"] = tuple(
            float(v) for v in ov["client_weights"].split(","))
    if "mesh" in ov:
        m = ov["mesh"]
        if m == "production":
            edits["execution.mesh"] = "production"
        else:
            try:
                edits["execution.mesh"] = tuple(int(v) for v in m.split(","))
            except ValueError:
                raise SystemExit(f"--mesh expects DATA,MODEL (e.g. 4,2) or "
                                 f"'production'; got {m!r}")
    if "comm_every" in ov:
        try:
            edits["schedule.comm_every"] = {
                k: int(v) for k, v in
                (pair.split("=") for pair in ov["comm_every"].split(","))}
        except ValueError:
            raise SystemExit(f"--comm-every expects SEC=K[,SEC=K] (e.g. "
                             f"u=2); got {ov['comm_every']!r}")
    # sampler promotions (trace_path / clients_per_round on the default
    # sampler) are part of the SPEC's normal form — Experiment.normalize(),
    # applied by validate()/build() for every consumer, not a CLI quirk;
    # contradictory combinations fail there with field-naming errors
    exp = base.edit(**edits).normalize()
    if exp.participation.sampler == "full" \
            and exp.participation.stale_discount != 1.0:
        # full participation keeps every staleness counter at 0, so the
        # discount could never bite — flag the no-op instead of aborting
        print("stale_discount ignored: full participation has no "
              "stale clients (pick a sampler)")
    return exp


def _resolve_experiment(args, overrides: dict) -> tuple[Experiment, int]:
    """(experiment, start_step) from --resume / --experiment / defaults,
    with flag overrides applied."""
    if args.resume:
        base = load_experiment(args.resume)
        if base is None:
            raise SystemExit(
                f"--resume {args.resume}: no experiment.json in the "
                f"checkpoint (pre-spec checkpoint?) — re-specify the run "
                f"with --experiment/flags instead")
        exp = apply_overrides(base, overrides)
        # compare normal forms: CLI-embedded specs are already canonical,
        # hand-embedded ones may predate promotion
        if exp != base.normalize():
            raise SystemExit(
                f"--resume {args.resume}: flags contradict the embedded "
                f"experiment spec (passed: "
                f"{sorted('--' + k.replace('_', '-') for k in overrides)}). "
                f"Resume continues the EXACT run; drop the conflicting "
                f"flags or start a fresh run with --experiment")
        return exp, int(checkpoint_metadata(args.resume)["step"])
    if args.experiment:
        return apply_overrides(Experiment.load(args.experiment),
                               overrides), 0
    if "arch" not in overrides:
        raise SystemExit("--arch is required (or pass --experiment/--resume)")
    # the CLI's historical baseline: nothing reduced unless asked
    base = Experiment()
    base = base.edit(**{"problem.reduced": False})
    return apply_overrides(base, overrides), 0


def _strip_flag(argv: list, flag: str) -> list:
    """``argv`` without ``flag`` and its value (``--f v`` or ``--f=v``)."""
    out, i = [], 0
    while i < len(argv):
        if argv[i] == flag:
            i += 2
        elif argv[i].startswith(flag + "="):
            i += 1
        else:
            out.append(argv[i])
            i += 1
    return out


_RESTART_WAIT_CAP = 60.0   # seconds — a supervised restart never sleeps longer


def _restart_wait(backoff: float, attempt: int, token: str = "") -> float:
    """Bounded exponential backoff with deterministic jitter: the
    exponential wait is capped at :data:`_RESTART_WAIT_CAP` (an unbounded
    ``backoff * 2**attempt`` sleeps for hours by attempt ~12), then spread
    by a ±25% factor derived from ``crc32(token:attempt)`` — deterministic
    (reproducible runs, no RNG), but decorrelated across checkpoint dirs so
    a fleet of supervisors doesn't restart in lockstep."""
    base = min(backoff * (2 ** attempt), _RESTART_WAIT_CAP)
    frac = zlib.crc32(f"{token}:{attempt}".encode()) % 1000 / 999.0
    return min(base * (0.75 + 0.5 * frac), _RESTART_WAIT_CAP)


def _supervise(ns, raw_argv: list) -> list:
    """--max-restarts: run the train loop in a child process, resuming from
    the latest --ckpt-dir checkpoint after each crash (non-zero exit) until
    it succeeds or the restart budget runs out."""
    if not ns.ckpt_dir:
        raise SystemExit("--max-restarts requires --ckpt-dir (restarts "
                         "resume from the latest checkpoint)")
    base = raw_argv
    for flag in ("--max-restarts", "--restart-backoff"):
        base = _strip_flag(base, flag)
    for attempt in range(ns.max_restarts + 1):
        child = list(base)
        if attempt:
            # the crash knob only fires on fresh runs, but strip it anyway —
            # a retry that crashed before its first checkpoint IS fresh
            child = _strip_flag(child, "--crash-at-step")
            if os.path.exists(os.path.join(ns.ckpt_dir, "manifest.json")):
                child = _strip_flag(child, "--resume")
                child += ["--resume", ns.ckpt_dir]
        rc = subprocess.call([sys.executable, "-m", "repro.launch.train",
                              *child])
        if rc == 0:
            return []
        if attempt < ns.max_restarts:
            wait = _restart_wait(ns.restart_backoff, attempt,
                                 ns.ckpt_dir or "")
            print(f"run crashed (exit {rc}); restart "
                  f"{attempt + 1}/{ns.max_restarts} in {wait:.1f}s",
                  flush=True)
            time.sleep(wait)
    raise SystemExit(f"run still crashing after {ns.max_restarts} "
                     f"restarts (last exit {rc}) — inspect "
                     f"{ns.ckpt_dir}/diagnostic or the traceback above")


def _diagnostic_checkpoint(ns, state, step: int, exp) -> None:
    """Dump the offending state next to the regular checkpoints so a failed
    run can be inspected (never overwrites the last good checkpoint)."""
    if not ns.ckpt_dir:
        return
    d = os.path.join(ns.ckpt_dir, "diagnostic")
    save_checkpoint(d, state, {"step": int(step), "diagnostic": True},
                    experiment=exp)
    print(f"diagnostic checkpoint -> {d}", flush=True)


def main(argv=None):
    ap = _parser()
    ns = ap.parse_args(argv)
    if ns.max_restarts > 0:
        return _supervise(ns, list(argv) if argv is not None
                          else sys.argv[1:])
    # SUPPRESS-defaulted flags only exist on the namespace when passed
    driver = {"experiment", "resume", "ckpt_dir", "ckpt_every", "log_every",
              "max_restarts", "restart_backoff", "crash_at_step"}
    overrides = {k: v for k, v in vars(ns).items() if k not in driver}
    exp, start = _resolve_experiment(ns, overrides)

    try:
        run = build(exp)
    except SpecError as e:
        raise SystemExit(str(e))
    exp = run.spec

    # one code path for everything the driver reports: every line goes
    # through the event stream (when experiment.telemetry is set) and
    # stdout stays a thin render of the same records
    log = None
    if exp.telemetry is not None:
        from repro.telemetry import EventLog
        # a resumed run appends its segment to the checkpoint dir's stream
        ckdir = ns.ckpt_dir or ns.resume
        sink = exp.telemetry.sink or (
            os.path.join(ckdir, "events.jsonl") if ckdir
            else "events.jsonl")
        log = EventLog(sink, experiment=json.loads(exp.to_json()),
                       start_step=start)
    tracing = log is not None and exp.telemetry.trace

    def emit(event, render=None, **fields):
        if log is not None:
            log.emit(event, **fields)
        if render is not None:
            print(render, flush=True)

    if run.mesh is not None:
        axes = dict(run.mesh.shape)
        banner = (f"mesh: data={axes['data']} model={axes['model']} "
                  f"({len(run.mesh.devices.flat)} devices)"
                  + (" overlap=on" if exp.execution.overlap else "")
                  + (" comm=psum_scatter" if exp.execution.scatter_comm
                     else ""))
        emit("note", render=banner, text=banner)
    pspec = run.participation
    if pspec is not None:
        M = exp.problem.num_clients
        if pspec.trace_path is not None:
            detail = f"log={pspec.trace_path}"
        elif pspec.sampler == "trace":
            detail = f"rate={pspec.availability_rate}"
        else:
            detail = f"m={pspec.clients_per_round or M}/{M}"
        banner = (f"participation: {pspec.sampler} {detail} "
                  f"seed={pspec.seed}")
        emit("note", render=banner, text=banner)
    sg = exp.stragglers
    if sg is not None:
        banner = (f"stragglers: policy={sg.late_policy} "
                  f"deadline={sg.deadline} quorum={sg.quorum} "
                  f"over_provision={sg.over_provision} tail={sg.tail}")
        emit("note", render=banner, text=banner)

    guard = (RollbackGuard(exp.robustness) if exp.robustness is not None
             else None)
    key = jax.random.PRNGKey(exp.schedule.seed)
    if start:
        state = load_checkpoint(ns.resume, jax.eval_shape(run.init, key))
        if run.shardings(state) is not None:
            state = jax.device_put(state, run.shardings(state))
        md = checkpoint_metadata(ns.resume)
        if md.get("key") is not None:
            # the raw key was recorded: exact even after rollbacks folded
            # retries into it (split-replay could never reconstruct that)
            key = jnp.asarray(np.asarray(md["key"], np.uint32))
        else:
            # pre-fault-tolerance checkpoint: replay the batch-key sequence
            # up to the resume point (exact for rollback-free runs)
            for _ in range(start):
                key, _ = jax.random.split(key)
        if guard is not None:
            guard.retries = int(md.get("retries", 0))
        banner = f"resumed from {ns.resume} @ step {start}"
        emit("note", render=banner, text=banner)
    else:
        state = run.init(key)

    jstep = jax.jit(run.step, donate_argnums=(0,))
    n_params = sum(int(np.prod(s.shape)) for s in
                   jax.tree.leaves(jax.eval_shape(run.model.init,
                                                  jax.random.PRNGKey(0))))
    banner = (f"arch={run.model_cfg.name} family={run.model_cfg.family} "
              f"algo={exp.algorithm.name} params={n_params:,}")
    emit("note", render=banner, text=banner)

    # analytic per-round comm-bytes plan (fused engine only): every comm
    # round emits one reconcilable `comm` event — `python -m
    # repro.telemetry.validate` rebuilds the byte model from the stream's
    # embedded experiment and checks bytes_wire against it
    plan = None
    if log is not None:
        flat_spec = getattr(run.step, "spec", None)
        aspec = getattr(run.step, "aspec", None)
        if flat_spec is not None and aspec is not None:
            from repro.telemetry import comm_plan
            plan = comm_plan(flat_spec, aspec, exp.compression)

    def _host_metrics(metrics) -> dict:
        """The step's in-band metrics side output as JSON scalars/lists
        (the `screened` verdict vector is consumed separately)."""
        out = {}
        for k, v in metrics.items():
            if k in ("step", "screened"):
                continue
            a = np.asarray(v)
            out[k] = (round(float(a), 8) if a.ndim == 0
                      else [round(float(x), 8) for x in a.reshape(-1)])
        return out

    local_steps = exp.schedule.local_steps
    retry = lambda: guard.retries if guard is not None else 0
    t0 = time.time()  # analysis: ignore[L301] driver timing
    history = []
    t = start
    while t < exp.schedule.steps:
        key, sub = jax.random.split(key)
        state, metrics = jstep(state, run.place_batch(run.batch_fn(sub)))
        t += 1
        is_comm = t % local_steps == 0
        is_log = t % ns.log_every == 0 or t == start + 1
        if log is not None and (is_comm or is_log) and len(metrics) > 1:
            # in-band metrics: host-converted at comm/log steps only, so
            # the dispatch stream stays as deep as the telemetry-free loop
            emit("metrics", step=t, retry=retry(), **_host_metrics(metrics))
            screened = metrics.get("screened")
            if (is_comm and screened is not None
                    and exp.robustness is not None
                    and exp.robustness.screen):
                idx = np.flatnonzero(np.asarray(screened) > 0)
                if idx.size:
                    emit("clients_screened", step=t, round=t // local_steps,
                         retry=retry(), clients=[int(i) for i in idx])
            if (is_comm and exp.stragglers is not None
                    and "deadline" in metrics):
                # one first-class event per elastic round, read off the
                # engine's in-band straggler metrics (a warmup round —
                # deadline 0, synchronous — emits nothing)
                dl = round(float(np.asarray(metrics["deadline"])), 6)
                ext = int(np.asarray(metrics["extensions"]))
                if dl > 0:
                    emit("deadline", step=t, round=t // local_steps,
                         retry=retry(), deadline=dl,
                         deadline_next=round(float(np.asarray(
                             metrics["deadline_next"])), 6),
                         arrivals=int(np.asarray(metrics["arrivals"])),
                         quorum=int(np.asarray(metrics["quorum"])),
                         extensions=ext,
                         arrival_hist=[int(round(float(x))) for x in
                                       np.asarray(metrics["arrival_hist"])])
                    if ext > 0:
                        emit("quorum_miss", step=t, round=t // local_steps,
                             retry=retry(), extensions=ext, deadline=dl)
        if plan is not None and is_comm:
            from repro.telemetry import round_bytes
            rb_ev = round_bytes(plan, t // local_steps)
            if rb_ev is not None:
                emit("comm", step=t, retry=retry(), **rb_ev)
        if is_log:
            if tracing:
                from repro.telemetry import phase
                with phase("eval", log, step=t):
                    l = run.eval_fn(state)
            else:
                l = run.eval_fn(state)
            if guard is not None:
                # host-copied snapshot: the live state's buffers are donated
                # to the next jstep call, a stored alias would be invalid
                snap = jax.tree.map(np.array, state)
                try:
                    rb = guard.observe(t, snap, key, l)
                except RollbackError as e:
                    emit("retry_budget_exhausted", step=t, retry=retry(),
                         bad_loss=float(l))
                    if log is not None:
                        log.emit("run_end", step=t,
                                 status="retry_budget_exhausted")
                        log.close()
                    _diagnostic_checkpoint(ns, state, t, exp)
                    raise SystemExit(f"round {t}: {e}")
                if rb is not None:
                    bad = l
                    t, snap, key = rb
                    state = jax.tree.map(jnp.asarray, snap)
                    if run.shardings(state) is not None:
                        state = jax.device_put(state, run.shardings(state))
                    emit("rollback",
                         render=json.dumps(
                             {"rollback_to": t, "retry": guard.retries,
                              "bad_loss": bad}),
                         step=t, retry=guard.retries, bad_loss=float(bad))
                    continue
            elif not np.isfinite(l):
                if log is not None:
                    log.emit("run_end", step=t, status="diverged")
                    log.close()
                _diagnostic_checkpoint(ns, state, t, exp)
                raise SystemExit(
                    f"non-finite eval loss ({l}) at round {t}: training "
                    f"diverged — inspect the diagnostic checkpoint, enable "
                    f"robustness guards (experiment.robustness), or lower "
                    f"the learning rates")
            history.append({"step": t, "val_loss": l,
                            "wall_s": round(time.time() - t0, 1)})  # analysis: ignore[L301] driver timing
            emit("metrics", render=json.dumps(history[-1]), **history[-1])
        if ns.ckpt_dir and t % ns.ckpt_every == 0:
            # the RAW state (flat buffers included) + the embedded spec:
            # --resume rebuilds the structure from the spec alone.  The raw
            # key + retry count make resume exact across rollbacks.
            save_checkpoint(
                ns.ckpt_dir, state,
                {"step": t, "arch": run.model_cfg.name,
                 "key": np.asarray(key).tolist(),
                 "retries": retry()},
                experiment=exp)
            emit("checkpoint", render=f"checkpoint @ step {t} -> "
                                      f"{ns.ckpt_dir}",
                 step=t, path=ns.ckpt_dir)
        if ns.crash_at_step and start == 0 and t == ns.crash_at_step:
            print(f"crash-at-step: hard exit after step {t}", flush=True)
            os._exit(17)
    if log is not None:
        log.emit("run_end", step=t, status="ok")
        log.close()
    assert not any(jnp.isnan(jnp.asarray(h["val_loss"])) for h in history)
    return history


if __name__ == "__main__":
    main()
