"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — callers control when
devices are first queried (critical for the dry-run's
``--xla_force_host_platform_device_count`` override, which must be set before
jax initialises).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(launch.dryrun sets this automatically)")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny ("data", "model") mesh over available devices for CPU tests."""
    import numpy as np
    devices = jax.devices()
    if len(devices) < data * model:
        raise RuntimeError(
            f"mesh ({data}, {model}) needs {data * model} devices, found "
            f"{len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data * model}")
    return jax.sharding.Mesh(
        np.asarray(devices[: data * model]).reshape(data, model),
        ("data", "model"))


# NOTE: the CLI mesh knob is now declarative — ``ExecutionSpec.mesh`` holds
# ``(data, model)`` sizes or ``"production"`` and ``repro.api.build``
# resolves it through the two constructors above.
