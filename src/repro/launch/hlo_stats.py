"""Compiled-HLO statistics shared by the dry-run and the benchmarks.

``collective_bytes`` parses a compiled module's text for collective ops
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
including their async ``-start`` variants) and sums their result bytes —
the measured communication schedule the roofline and the ``sharded_comm``
benchmark records are built on.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def collective_bytes(hlo: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in the compiled HLO.

    Besides per-op ``bytes``/``counts`` the record carries
    ``bytes_by_dtype`` — collective bytes keyed by element dtype (f32,
    bf16, s8, ...).  That is the split the compressed-comm policies are
    audited on: a quantized spec must move its bytes in the narrow dtype,
    and an f32-dominated breakdown means the compression silently fell
    back (``launch.dryrun`` fails loudly on it)."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    by_dtype: Dict[str, int] = {}
    ops = []
    for line in hlo.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        op, op_m = None, None
        for c in _COLLECTIVES:
            # match op name incl. async variants (all-reduce-start)
            m = re.search(rf"\s{c}(-start)?\(", line)
            if m:
                op, op_m = c, m
                break
        if op is None:
            continue
        # result signature = everything between "=" and the op name
        # (handles tuple results like "= (bf16[..], bf16[..]) all-to-all(...)")
        eq = line.index(" = ")
        sig = line[eq + 3:op_m.start()]
        total = 0
        for dt, dims in _SHAPE_RE.findall(sig):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
            by_dtype[dt] = by_dtype.get(dt, 0) + n * _DTYPE_BYTES[dt]
        out[op] += total
        counts[op] += 1
        # per-op record — what repro.analysis pinpoints byte mismatches on
        ops.append({"op": op, "bytes": total,
                    "dtypes": sorted({d for d, _ in
                                      _SHAPE_RE.findall(sig)})})
    return {"bytes": out, "counts": counts, "bytes_by_dtype": by_dtype,
            "total_bytes": sum(out.values()), "ops": ops}
