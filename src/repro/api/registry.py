"""Algorithm registry — the one table :func:`repro.api.build` dispatches on.

Two layers register here:

* **Model-scale trainers** (``repro.federation.trainer``): the five
  ``make_*_train_step`` factories self-register at import with
  :func:`register`, declaring their algorithm-specific hyperparams (which
  :class:`~repro.api.spec.AlgorithmSpec.params` keys exist, their defaults,
  and whether each lands on a :class:`~repro.config.FederatedConfig` field
  or is a factory keyword) and their section names (what
  ``schedule.comm_every`` may address).  Every factory takes the uniform
  switch set ``(model, cfg, *, n_micro, remat, use_flash, use_lru_kernel,
  fuse_oracles, fuse_storm, storm_block, participation, mesh, overlap,
  comm_every)`` — the registry is what lets :func:`repro.api.build` call
  them without a bespoke kwargs pile per algorithm.

* **Core problem-level algorithms** (:func:`make_algorithm`, absorbed from
  the deprecated ``repro.core.api``): the small-problem reference loops the
  paper figures/benchmarks run on (quadratic / data-cleaning / hyper-rep),
  including the Table-1 baselines.  They share the registry module so every
  "which algorithms exist" question has one answer.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Tuple


@dataclass(frozen=True)
class AlgorithmEntry:
    """One registered model-scale trainer factory.

    ``hparams`` maps each algorithm-specific hyperparam name to its default;
    ``cfg_fields`` names the subset that sets same-named
    :class:`~repro.config.FederatedConfig` fields — the rest are passed to
    the factory as keywords (e.g. fedavg's ``momentum``).
    """
    name: str
    factory: Callable
    hparams: Mapping[str, float] = field(default_factory=dict)
    cfg_fields: Tuple[str, ...] = ()
    sections: Tuple[str, ...] = ()
    description: str = ""

    def split_params(self, params: Mapping[str, float]):
        """(cfg_overrides, factory_kwargs) for an AlgorithmSpec's params,
        with the entry's defaults filled in."""
        merged = {**dict(self.hparams), **dict(params)}
        cfg = {k: v for k, v in merged.items() if k in self.cfg_fields}
        kw = {k: v for k, v in merged.items() if k not in self.cfg_fields}
        return cfg, kw


_TRAINERS: Dict[str, AlgorithmEntry] = {}


def register(name: str, *, hparams: Mapping[str, float] | None = None,
             cfg_fields: Tuple[str, ...] = (),
             sections: Tuple[str, ...] = (), description: str = ""):
    """Decorator: register a ``make_*_train_step`` factory under ``name``."""
    def deco(factory):
        _TRAINERS[name] = AlgorithmEntry(
            name=name, factory=factory, hparams=dict(hparams or {}),
            cfg_fields=tuple(cfg_fields), sections=tuple(sections),
            description=description)
        return factory
    return deco


def _ensure_registered() -> None:
    # the trainers self-register at import; importing here (not at module
    # top) keeps spec validation importable without pulling the model stack
    # in a fixed order and avoids an import cycle
    if not _TRAINERS:
        importlib.import_module("repro.federation.trainer")


def get(name: str) -> AlgorithmEntry:
    _ensure_registered()
    if name not in _TRAINERS:
        raise KeyError(f"no trainer registered for algorithm {name!r}; "
                       f"registered: {sorted(_TRAINERS)}")
    return _TRAINERS[name]


def algorithms() -> Tuple[str, ...]:
    """Registered model-scale algorithm names (the valid
    ``Experiment.algorithm.name`` values)."""
    _ensure_registered()
    return tuple(sorted(_TRAINERS))


# ---------------------------------------------------------------------------
# Core problem-level algorithms (absorbed from repro.core.api)
# ---------------------------------------------------------------------------

def _core_factories() -> Dict[str, Callable]:
    from repro.core.baselines import (make_commfedbio, make_fednest,
                                      make_mrbo, make_stocbio)
    from repro.core.fedbio import make_fedbio
    from repro.core.fedbioacc import make_fedbioacc
    from repro.core.local_lower import make_fedbio_local, make_fedbioacc_local
    return {
        "fedbio": make_fedbio,
        "fedbioacc": make_fedbioacc,
        "fedbio_local": make_fedbio_local,
        "fedbioacc_local": make_fedbioacc_local,
        "fednest": make_fednest,
        "commfedbio": make_commfedbio,
        "stocbio": make_stocbio,
        "mrbo": make_mrbo,
    }


def make_algorithm(problem, cfg) -> Any:
    """Problem-level algorithm factory (``cfg.algorithm`` names it): the
    reference loops of Algorithms 1-4 plus the Table-1 baselines, on a
    :class:`repro.core.problems.Problem`."""
    factories = _core_factories()
    if cfg.algorithm not in factories:
        raise KeyError(f"unknown algorithm {cfg.algorithm!r}; "
                       f"choose from {sorted(factories)}")
    return factories[cfg.algorithm](problem, cfg)
