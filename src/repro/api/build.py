"""``build(experiment) -> Run`` — the one entrypoint every consumer shares.

``launch.train``, ``launch.dryrun``, ``benchmarks.run`` and checkpoint
resume all construct runs here, so a scenario is a data edit (an
:class:`~repro.api.spec.Experiment`), never a bespoke kwargs pile.  The
returned :class:`Run` exposes the uniform surface:

* ``init(key) -> state`` / ``step(state, batch) -> (state, metrics)`` — the
  exact factory-built pair (bit-identical to calling the
  ``make_*_train_step`` factory by hand with the same knobs);
* ``views(state)`` — the legacy pytree train state (identity on the unfused
  path, ``train_step.views`` on the flat substrate);
* ``shardings(state)`` — ``NamedSharding`` pytree for jit boundaries (None
  off-mesh);
* ``eval_fn(state) -> float`` — client-0 validation loss on a fixed batch;
* ``batch_fn(key)`` / ``place_batch(batch)`` — the synthetic federated
  stream and its mesh placement;
* ``spec`` — the (validated) Experiment itself, so a Run can always be
  reproduced, serialized, or embedded in a checkpoint.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

from repro.api.spec import Experiment
from repro.federation.participation import ParticipationSpec


class Run(NamedTuple):
    """A built Experiment (see the module docstring)."""
    spec: Experiment
    init: Any
    step: Any
    views: Any
    shardings: Any
    eval_fn: Any
    batch_fn: Any
    place_batch: Any
    model: Any
    model_cfg: Any
    fed: Any
    participation: Optional[ParticipationSpec]
    mesh: Any

    @property
    def steps(self) -> int:
        return self.spec.schedule.steps


def _resolve_participation(exp: Experiment) -> ParticipationSpec | None:
    """The ParticipationSpec the factories consume — ``None`` for the full
    sampler (the bit-exact no-participation fast path), with weighted
    samplers inheriting ``problem.client_sizes`` when the participation spec
    itself carries no weights."""
    p = exp.participation
    if p.sampler == "full":
        return None
    if (p.sampler == "weighted" and p.client_weights is None
            and exp.problem.client_sizes is not None):
        p = p._replace(client_weights=exp.problem.client_sizes)
    return p


def _resolve_mesh(exp: Experiment):
    """(mesh, mesh_arg): the jax Mesh of ``execution.mesh`` (None off-mesh)
    and what the factory's ``mesh=`` kwarg receives (a ShardCtx when
    scatter-comm lowering is requested)."""
    ex = exp.execution
    if ex.mesh is None:
        return None, None
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    if ex.mesh == "production":
        mesh = make_production_mesh()
    else:
        mesh = make_debug_mesh(*ex.mesh)
    if ex.scatter_comm:
        from repro.optim.flat import make_shard_ctx
        return mesh, make_shard_ctx(mesh, use_scatter=True)
    return mesh, mesh


def federated_config(exp: Experiment):
    """The :class:`~repro.config.FederatedConfig` an Experiment denotes
    (schedule + problem size + the algorithm's cfg-field hyperparams)."""
    from repro.api import registry
    from repro.config import FederatedConfig

    entry = registry.get(exp.algorithm.name)
    cfg_over, _ = entry.split_params(exp.algorithm.params_dict)
    sch = exp.schedule
    return FederatedConfig(
        algorithm=exp.algorithm.name, num_clients=exp.problem.num_clients,
        local_steps=sch.local_steps, lr_x=sch.lr_x, lr_y=sch.lr_y,
        lr_u=sch.lr_u, hierarchy_period=sch.hierarchy_period,
        hierarchy_groups=sch.hierarchy_groups, neumann_q=sch.neumann_q,
        neumann_tau=sch.neumann_tau, lower_l2=sch.lower_l2, seed=sch.seed,
        **cfg_over)


def build(experiment: Experiment) -> Run:
    """Compile a (validated) Experiment into a :class:`Run`."""
    import jax
    import jax.numpy as jnp

    from repro.api import registry
    from repro.configs import get_config
    from repro.data import make_fed_batch_fn
    from repro.models import build_model

    # canonical spec: sampler promotions applied ONCE here, so one JSON
    # means one run for every consumer (train CLI, dryrun, benchmarks,
    # resume) and Run.spec / embedded checkpoint specs are normal forms
    exp = experiment.validate().normalize()
    prob, ex = exp.problem, exp.execution

    model_cfg = get_config(prob.arch)
    if prob.reduced:
        model_cfg = model_cfg.reduced()
    if prob.param_dtype == "auto":
        dtype = jnp.float32 if prob.reduced else jnp.bfloat16
    else:
        dtype = jnp.dtype(prob.param_dtype)
    model = build_model(model_cfg, dtype=dtype)

    fed = federated_config(exp)
    entry = registry.get(exp.algorithm.name)
    _, factory_kw = entry.split_params(exp.algorithm.params_dict)
    pspec = _resolve_participation(exp)
    mesh, mesh_arg = _resolve_mesh(exp)

    init, step = entry.factory(
        model, fed, n_micro=ex.n_micro, remat=ex.remat,
        use_flash=ex.use_flash, use_lru_kernel=ex.use_lru_kernel,
        fuse_oracles=ex.fuse_oracles, fuse_storm=ex.fuse_storm,
        storm_block=ex.storm_block, participation=pspec,
        mesh=mesh_arg, overlap=ex.overlap,
        comm_every=exp.schedule.comm_every_dict or None,
        faults=exp.faults, robustness=exp.robustness,
        compression=exp.compression, telemetry=exp.telemetry,
        stragglers=exp.stragglers,
        **factory_kw)

    views = step.views if hasattr(step, "views") else (lambda s: s)
    shardings = (step.shardings if getattr(step, "shardings", None)
                 else (lambda s: None))

    batch_fn = make_fed_batch_fn(model_cfg, num_clients=prob.num_clients,
                                 per_client=prob.per_client,
                                 seq_len=prob.seq_len, seed=prob.data_seed)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        b_shard = NamedSharding(mesh, P("data"))

        def place_batch(b):
            return jax.device_put(b, jax.tree.map(lambda _: b_shard, b))
    else:
        place_batch = lambda b: b

    # the eval batch is fixed — generated once, client 0's validation split
    eval_batch = jax.tree.map(lambda v: v[0],
                              batch_fn(jax.random.PRNGKey(123)))

    def eval_fn(state) -> float:
        s = views(state)
        p = (s.params if hasattr(s, "params")
             else {"body": s.x, "head": s.y})
        p0 = jax.tree.map(lambda v: v[0], p)
        l, _ = model.loss(p0, eval_batch["val"])
        return float(l)

    return Run(spec=exp, init=init, step=step, views=views,
               shardings=shardings, eval_fn=eval_fn, batch_fn=batch_fn,
               place_batch=place_batch, model=model, model_cfg=model_cfg,
               fed=fed, participation=pspec, mesh=mesh)
