"""Validate committed Experiment spec files.

    PYTHONPATH=src python -m repro.api.validate experiments/*.json

Each file must parse as a versioned ``repro.api.Experiment`` AND pass
:meth:`Experiment.validate` (registry lookup, arch lookup, consistency).
Exit code 1 if any file fails; prints one line per file.
"""
from __future__ import annotations

import argparse
import sys

from repro.api.spec import Experiment, SpecError


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", metavar="EXP.json")
    args = ap.parse_args(argv)
    failed = 0
    for path in args.paths:
        try:
            exp = Experiment.load(path).validate()
        except (SpecError, OSError) as e:
            print(f"FAIL {path}: {e}")
            failed += 1
            continue
        mesh = exp.execution.mesh
        guards = ""
        if exp.faults is not None:
            fl = exp.faults
            guards += (f", faults[drop={fl.dropout_rate} nan={fl.nan_rate} "
                       f"byz={fl.byzantine_rate}]")
        if exp.robustness is not None:
            rb = exp.robustness
            guards += (f", robust[{rb.aggregator}"
                       f"{' screened' if rb.screen else ''} "
                       f"retries={rb.retry_budget}]")
        if exp.compression is not None:
            cp = exp.compression
            parts = ([cp.quant] if cp.quant else []) \
                + ([f"topk={cp.topk_frac}"
                    f"{'' if cp.error_feedback else ' no-ef'}"]
                   if cp.topk_frac else [])
            guards += f", compress[{' '.join(parts)}]"
        print(f"OK   {path}: {exp.algorithm.name} on {exp.problem.arch}"
              f"{' (reduced)' if exp.problem.reduced else ''}, "
              f"M={exp.problem.num_clients}, steps={exp.schedule.steps}"
              + (f", mesh={mesh}" if mesh is not None else "") + guards)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
