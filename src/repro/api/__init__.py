"""``repro.api`` — one declarative :class:`Experiment` spec, one
:func:`build` entrypoint, one algorithm registry.

    from repro.api import Experiment, AlgorithmSpec, build

    exp = Experiment(algorithm=AlgorithmSpec("fedbioacc"))
    run = build(exp)                       # uniform Run surface
    state = run.init(jax.random.PRNGKey(exp.schedule.seed))
    state, _ = jax.jit(run.step)(state, run.batch_fn(key))
    print(run.eval_fn(state))
    exp2 = Experiment.from_json(exp.to_json())   # round-trips, versioned

Consumers: ``launch.train --experiment exp.json`` (plus flag overrides),
``launch.dryrun --experiment``, ``benchmarks.run`` sweeps (lists of
``exp.edit(...)`` calls), and checkpoint resume (the spec is embedded next
to the arrays; ``--resume ckpt_dir`` rebuilds the exact run).
"""
from repro.api.build import Run, build, federated_config  # noqa: F401
from repro.api.registry import (AlgorithmEntry, algorithms,  # noqa: F401
                                get as get_algorithm, make_algorithm,
                                register)
from repro.api.spec import (SPEC_VERSION, AlgorithmSpec,  # noqa: F401
                            Experiment, ExecutionSpec, ProblemSpec,
                            ScheduleSpec, SpecError)
from repro.federation.faults import (FaultSpec, RobustnessSpec,  # noqa: F401
                                     RollbackError, RollbackGuard)
from repro.federation.participation import ParticipationSpec  # noqa: F401
from repro.federation.stragglers import StragglerSpec  # noqa: F401
from repro.telemetry.spec import TelemetrySpec  # noqa: F401
