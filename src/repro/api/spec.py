"""The declarative :class:`Experiment` spec — one serializable description
of a federated bilevel run.

Every scenario the stack can execute — which algorithm, on which
architecture, with which client-sampling process, on which device mesh,
under which schedule — is a frozen dataclass tree:

    Experiment
    ├── AlgorithmSpec      name + algorithm-specific hyperparams (registry)
    ├── ProblemSpec        arch / reduced / synthetic data / per-client sizes
    ├── ParticipationSpec  client sampling (repro.federation.participation)
    ├── ExecutionSpec      fusion, mesh axes, overlap, scatter-comm
    ├── ScheduleSpec       steps, lrs, cadences, hierarchy, Neumann terms
    ├── FaultSpec?         client failure injection (repro.federation.faults)
    ├── RobustnessSpec?    health screen / robust aggregator / rollback
    ├── CompressionSpec?   quantized / top-k compressed reductions (+EF)
    ├── TelemetrySpec?     in-band metrics + structured event stream
    └── StragglerSpec?     deadline-driven elastic rounds (stragglers)

``Experiment`` round-trips to/from JSON (:meth:`Experiment.to_json` /
:meth:`Experiment.from_json`, versioned via ``version``), validates with
actionable errors (:meth:`Experiment.validate`), and is hashable, so it can
be closed over by jitted code, used as a cache key, embedded in checkpoints
(``repro.checkpoint.save_checkpoint(..., experiment=)``) and swept by
editing fields (:meth:`Experiment.edit` takes dotted paths:
``exp.edit(**{"participation.availability_rate": 0.5})``).

The single entrypoint :func:`repro.api.build` compiles an ``Experiment``
into a :class:`~repro.api.build.Run` — uniform ``init/step/views/shardings/
eval_fn/spec`` across train, dryrun, benchmarks and checkpoint-resume.

JSON schema (version 1)
-----------------------

::

    {
      "version": 1,
      "algorithm":     {"name": str,          # registry key (api.algorithms())
                        "params": {str: num}},# algorithm-specific hyperparams
      "problem":       {"arch": str,          # repro.configs.ARCHS key
                        "reduced": bool,      # CPU-sized same-family variant
                        "num_clients": int,   # M
                        "per_client": int,    # per-client batch size
                        "seq_len": int,
                        "client_sizes": [num] | null,  # per-client data sizes
                        "param_dtype": "auto"|"float32"|"bfloat16",
                        "data_seed": int},    # synthetic client streams
      "participation": {"sampler": "full"|"uniform"|"weighted"|"trace",
                        "clients_per_round": int, "client_weights": [num]|null,
                        "seed": int, "availability_rate": num,
                        "min_clients": int, "stale_discount": num,
                        "trace_path": str|null},
      "execution":     {"fuse_storm": bool, "fuse_oracles": bool,
                        "storm_block": int|null,
                        "mesh": [data, model] | "production" | null,
                        "overlap": bool, "scatter_comm": bool,
                        "n_micro": int, "remat": bool,
                        "use_flash": bool, "use_lru_kernel": bool},
      "schedule":      {"steps": int, "local_steps": int,
                        "lr_x": num, "lr_y": num, "lr_u": num,
                        "hierarchy_period": int, "hierarchy_groups": int,
                        "neumann_q": int, "neumann_tau": num,
                        "lower_l2": num,
                        "comm_every": {section: int},   # async cadences
                        "seed": int},
      "faults":        {"dropout_rate": num, "nan_rate": num,  # | null
                        "byzantine_rate": num, "byzantine_scale": num,
                        "seed": int, "start_round": int},
      "robustness":    {"aggregator": "mean"|"clip"|"trim",    # | null
                        "screen": bool, "z_thresh": num,
                        "clip_factor": num, "trim_frac": num,
                        "spike_factor": num, "retry_budget": int,
                        "ring": int},
      "compression":   {"quant": "bf16"|"int8"|null,        # | null
                        "topk_frac": num,       # 0 disables sparsification
                        "error_feedback": bool,
                        "sections": [str]|null},# null = every comm'd section
      "telemetry":     {"sink": str|null,      # | null; null = driver picks
                        "metrics": [str]|null, # null = every applicable group
                        "trace": bool},        # wall-clock span events
      "stragglers":    {"base_time": num, "tail": num,          # | null
                        "deadline": num, "over_provision": int,
                        "quorum": num,          # fraction of sampled clients
                        "late_policy": "drop"|"carry"|"cancel",
                        "backoff": num, "max_extensions": int,
                        "target_percentile": num, "adapt_rate": num,
                        "seed": int, "start_round": int}
    }

``faults``/``robustness`` (both optional, default null — the bit-identical
unguarded stack) declare the fault-tolerance layer: deterministic per-round
client failure injection and the guard policy against it (health-masked
robust aggregation + the train loop's rollback/retry) — see
``repro.federation.faults``.  Both require ``execution.fuse_storm`` and a
flat (non-hierarchical) schedule.

``compression`` (optional, default null — exact f32 reductions,
bit-identical) declares the compressed-communication policy: quantized
(bf16 / per-tile-scaled int8) and/or top-k sparsified client sends with
per-client error-feedback buffers — see ``repro.federation.compression``.
Requires ``execution.fuse_storm``; top-k additionally requires a flat
(non-hierarchical) schedule and ``error_feedback`` unless explicitly
disabled is the documented divergence row; private sections are never
compressible.

``telemetry`` (optional, default null — no event stream, no in-band
metrics, bit-identical trajectories and unchanged jit cache keys) declares
the observability layer: the drivers write a schema-versioned JSONL event
stream to ``sink`` and the fused engine computes the ``metrics`` groups
(subset of ``repro.telemetry.METRIC_GROUPS``; null = every group the other
layers make applicable) as a side output of every step.  Explicit non-empty
``metrics`` require ``execution.fuse_storm``; the ``"compression"`` group
needs a compression block, the ``"health"`` group needs faults, robustness
or a non-full sampler.

``stragglers`` (optional, default null — synchronous rounds,
bit-identical) declares the elastic-round layer: deterministic
per-(round, client) lognormal compute times, deadline-driven arrivals-only
aggregation with over-provisioned sampling, a quorum floor with capped
deadline backoff, a late-arrival policy (drop / carry / cancel) and the
adaptive deadline EMA — see ``repro.federation.stragglers``.  Requires
``execution.fuse_storm`` and a flat (non-hierarchical) schedule;
``over_provision > 0`` needs a counted (uniform/weighted) sampler.
Composes with faults/robustness and with compression.

Unknown keys, wrong versions, unknown algorithms/hyperparams and
inconsistent combinations (``mesh`` without ``fuse_storm``, ``overlap``
without ``mesh``, ``weighted`` without weights, ...) all fail with errors
that name the offending field and the fix.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field, fields
from typing import Any, Optional, Tuple

from repro.federation.compression import QUANTS, CompressionSpec
from repro.federation.faults import AGGREGATORS, FaultSpec, RobustnessSpec
from repro.federation.participation import SAMPLERS, ParticipationSpec
from repro.federation.stragglers import LATE_POLICIES, StragglerSpec
from repro.telemetry.spec import METRIC_GROUPS, TelemetrySpec

SPEC_VERSION = 1

PARAM_DTYPES = ("auto", "float32", "bfloat16")


class SpecError(ValueError):
    """An Experiment that cannot be built — the message names the field."""


def _err(fieldname: str, msg: str):
    raise SpecError(f"Experiment.{fieldname}: {msg}")


@dataclass(frozen=True)
class AlgorithmSpec:
    """Which algorithm, by registry name, plus its own hyperparams.

    ``params`` holds only *algorithm-specific* knobs (the registry entry
    declares which exist and their defaults — e.g. the STORM constants
    ``c_nu``/``c_omega``/``c_u``/``alpha_delta``/``alpha_u0`` for the
    FedBiOAcc family, ``momentum`` for FedAvg); shared schedule knobs (lrs,
    local steps, Neumann terms) live in :class:`ScheduleSpec`.  Stored as a
    sorted tuple of (key, value) pairs so the spec stays hashable; construct
    with a plain dict.
    """
    name: str = "fedbioacc"
    params: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if isinstance(self.params, dict):
            object.__setattr__(self, "params",
                               tuple(sorted(self.params.items())))
        else:
            object.__setattr__(self, "params",
                               tuple(sorted(tuple(p) for p in self.params)))

    @property
    def params_dict(self) -> dict:
        return dict(self.params)


@dataclass(frozen=True)
class ProblemSpec:
    """What is being trained: the assigned architecture and its synthetic
    heterogeneous client streams.  ``client_sizes`` (per-client data sizes)
    seed the weighted participation sampler and its weighted reductions when
    the participation spec itself carries no weights."""
    arch: str = "mamba2-130m"
    reduced: bool = True
    num_clients: int = 4
    per_client: int = 2
    seq_len: int = 128
    client_sizes: Optional[Tuple[float, ...]] = None
    param_dtype: str = "auto"      # auto: float32 if reduced else bfloat16
    data_seed: int = 0

    def __post_init__(self):
        if self.client_sizes is not None:
            object.__setattr__(self, "client_sizes",
                               tuple(float(v) for v in self.client_sizes))


@dataclass(frozen=True)
class ExecutionSpec:
    """How the step executes: fusion switches, the device mesh and the
    communication lowering — never *what* is computed (fused and unfused
    trajectories match to float rounding; sharded matches single-device).
    ``overlap`` is the one documented deviation (see ``optim.sequences``)."""
    fuse_storm: bool = False
    fuse_oracles: bool = False
    storm_block: Optional[int] = None
    mesh: Any = None               # (data, model) sizes | "production" | None
    overlap: bool = False
    scatter_comm: bool = False
    n_micro: int = 1
    remat: bool = False
    use_flash: bool = False
    use_lru_kernel: bool = False

    def __post_init__(self):
        if isinstance(self.mesh, (list, tuple)):
            object.__setattr__(self, "mesh",
                               tuple(int(v) for v in self.mesh))


@dataclass(frozen=True)
class ScheduleSpec:
    """When things happen: step counts, learning rates, communication
    cadences and the solver constants shared by every algorithm."""
    steps: int = 100
    local_steps: int = 4
    lr_x: float = 0.02
    lr_y: float = 0.05
    lr_u: float = 0.05
    hierarchy_period: int = 0
    hierarchy_groups: int = 2
    neumann_q: int = 8
    neumann_tau: float = 0.5
    lower_l2: float = 1e-2
    comm_every: Tuple[Tuple[str, int], ...] = ()   # per-section async cadence
    seed: int = 0

    def __post_init__(self):
        if isinstance(self.comm_every, dict):
            object.__setattr__(self, "comm_every",
                               tuple(sorted(self.comm_every.items())))
        else:
            object.__setattr__(self, "comm_every",
                               tuple(sorted(tuple(p) for p in self.comm_every)))

    @property
    def comm_every_dict(self) -> dict:
        return dict(self.comm_every)


@dataclass(frozen=True)
class Experiment:
    """One declarative, serializable federated bilevel run."""
    algorithm: AlgorithmSpec = field(default_factory=AlgorithmSpec)
    problem: ProblemSpec = field(default_factory=ProblemSpec)
    participation: ParticipationSpec = ParticipationSpec()
    execution: ExecutionSpec = field(default_factory=ExecutionSpec)
    schedule: ScheduleSpec = field(default_factory=ScheduleSpec)
    faults: Optional[FaultSpec] = None
    robustness: Optional[RobustnessSpec] = None
    compression: Optional[CompressionSpec] = None
    telemetry: Optional[TelemetrySpec] = None
    stragglers: Optional[StragglerSpec] = None
    version: int = SPEC_VERSION

    # -- validation ---------------------------------------------------------

    def normalize(self) -> "Experiment":
        """Canonical form (idempotent): the sampler promotions every
        consumer shares — a recorded ``trace_path`` or a nonzero
        ``clients_per_round`` on the default ``full`` sampler select the
        trace resp. uniform sampler.  :func:`repro.api.build` normalizes, so
        one JSON means one run everywhere (train CLI, dryrun, benchmarks,
        resume)."""
        p = self.participation
        if p.trace_path is not None and p.sampler == "full":
            return self.edit(**{"participation.sampler": "trace"})
        if p.sampler == "full" and p.clients_per_round:
            return self.edit(**{"participation.sampler": "uniform"})
        return self

    def validate(self) -> "Experiment":
        """Check the spec against the registry and the stack's invariants;
        raises :class:`SpecError` naming the field and the fix.  Returns
        ``self`` so it chains (``build(exp.validate())``)."""
        from repro.api import registry

        if self.version != SPEC_VERSION:
            _err("version", f"unsupported spec version {self.version!r} "
                 f"(this build reads version {SPEC_VERSION})")
        if self.algorithm.name not in registry.algorithms():
            _err("algorithm.name",
                 f"unknown algorithm {self.algorithm.name!r}; registered: "
                 f"{sorted(registry.algorithms())}")
        entry = registry.get(self.algorithm.name)
        unknown = set(self.algorithm.params_dict) - set(entry.hparams)
        if unknown:
            _err("algorithm.params",
                 f"{sorted(unknown)} are not hyperparams of "
                 f"{self.algorithm.name!r} (it takes {sorted(entry.hparams)})")

        from repro.configs import ARCHS
        if self.problem.arch not in ARCHS:
            _err("problem.arch", f"unknown arch {self.problem.arch!r}; "
                 f"choose from {sorted(ARCHS)}")
        if self.problem.num_clients < 1:
            _err("problem.num_clients", "need at least one client")
        if self.problem.param_dtype not in PARAM_DTYPES:
            _err("problem.param_dtype",
                 f"{self.problem.param_dtype!r} not in {PARAM_DTYPES}")
        cs = self.problem.client_sizes
        if cs is not None and len(cs) != self.problem.num_clients:
            _err("problem.client_sizes",
                 f"{len(cs)} sizes for num_clients={self.problem.num_clients}")

        p = self.normalize().participation
        if p.sampler not in SAMPLERS:
            _err("participation.sampler",
                 f"unknown sampler {p.sampler!r}; choose from {SAMPLERS}")
        if (p.sampler == "weighted" and p.client_weights is None
                and cs is None):
            _err("participation",
                 "sampler='weighted' needs client_weights (or "
                 "problem.client_sizes to inherit from)")
        if p.clients_per_round > self.problem.num_clients:
            _err("participation.clients_per_round",
                 f"{p.clients_per_round} > num_clients="
                 f"{self.problem.num_clients}")
        if p.trace_path is not None and p.sampler != "trace":
            _err("participation.trace_path",
                 f"a recorded availability log is a sampler='trace' knob — "
                 f"it conflicts with sampler={p.sampler!r} (drop one)")
        if p.sampler == "trace" and p.clients_per_round:
            _err("participation.clients_per_round",
                 "the trace sampler draws participation from the "
                 "availability process/log — clients_per_round has no "
                 "effect; unset it or use uniform/weighted")

        ex = self.execution
        if (ex.mesh is not None or ex.overlap) and not ex.fuse_storm:
            _err("execution",
                 "mesh/overlap need fuse_storm=true — the sharded substrate "
                 "and the overlap schedule are fused-engine features")
        if ex.overlap and ex.mesh is None:
            _err("execution.overlap",
                 "overlap needs a mesh: the schedule exists to hide the "
                 "data-axis collective behind the new-iterate oracle")
        if ex.scatter_comm and ex.mesh is None:
            _err("execution.scatter_comm", "scatter_comm needs a mesh")
        if ex.mesh is not None and not (
                ex.mesh == "production"
                or (isinstance(ex.mesh, tuple) and len(ex.mesh) == 2
                    and all(int(v) >= 1 for v in ex.mesh))):
            _err("execution.mesh",
                 f"{ex.mesh!r} is neither [data, model] sizes nor "
                 f"'production'")
        if isinstance(ex.mesh, tuple) \
                and self.problem.num_clients % ex.mesh[0]:
            _err("execution.mesh",
                 f"num_clients={self.problem.num_clients} not divisible by "
                 f"the mesh data axis ({ex.mesh[0]})")

        sch = self.schedule
        if sch.steps < 1 or sch.local_steps < 1:
            _err("schedule", "steps and local_steps must be >= 1")
        sections = entry.sections
        for sec, k in sch.comm_every:
            if sec not in sections:
                _err("schedule.comm_every",
                     f"{sec!r} is not a section of {self.algorithm.name!r} "
                     f"(sections: {sections})")
            if int(k) < 1:
                _err("schedule.comm_every", f"cadence for {sec!r} must be "
                     f">= 1, got {k}")

        fl, rb = self.faults, self.robustness
        if fl is not None or rb is not None:
            which = "faults" if fl is not None else "robustness"
            if not ex.fuse_storm:
                _err(which, "needs execution.fuse_storm=true — fault "
                     "injection and the robust reductions are features of "
                     "the fused sequence-spec engine")
            if sch.hierarchy_period > 0:
                _err(which, "does not compose with the hierarchical grouped "
                     "mean (schedule.hierarchy_period > 0) — the robust "
                     "reductions and the fault model are global")
        if fl is not None:
            for name in ("dropout_rate", "nan_rate", "byzantine_rate"):
                r = getattr(fl, name)
                if not 0.0 <= float(r) <= 1.0:
                    _err(f"faults.{name}", f"{r} is not in [0, 1]")
            if fl.start_round < 0:
                _err("faults.start_round", f"{fl.start_round} must be >= 0")
        if rb is not None:
            if rb.aggregator not in AGGREGATORS:
                _err("robustness.aggregator",
                     f"unknown aggregator {rb.aggregator!r}; choose from "
                     f"{AGGREGATORS}")
            if not 0.0 <= float(rb.trim_frac) < 0.5:
                _err("robustness.trim_frac",
                     f"{rb.trim_frac} is not in [0, 0.5) — trimming both "
                     f"ends must leave at least one row")
            if float(rb.clip_factor) <= 0:
                _err("robustness.clip_factor",
                     f"{rb.clip_factor} must be > 0")
            if float(rb.spike_factor) <= 1.0:
                _err("robustness.spike_factor",
                     f"{rb.spike_factor} must be > 1 (a loss equal to the "
                     f"last good one is healthy)")
            if rb.retry_budget < 0 or rb.ring < 1:
                _err("robustness",
                     "retry_budget must be >= 0 and ring >= 1")

        cp = self.compression
        if cp is not None:
            if not ex.fuse_storm:
                _err("compression",
                     "needs execution.fuse_storm=true — the compressed "
                     "reductions are a feature of the fused sequence-spec "
                     "engine")
            if fl is not None or rb is not None:
                _err("compression",
                     "does not compose with faults/robustness — the robust "
                     "aggregators and health screens are calibrated on "
                     "exact sends (drop one layer)")
            if cp.quant not in QUANTS:
                _err("compression.quant",
                     f"unknown quant {cp.quant!r}; choose from {QUANTS}")
            if not 0.0 <= float(cp.topk_frac) < 1.0:
                _err("compression.topk_frac",
                     f"{cp.topk_frac} is not in [0, 1) — 1.0 means 'keep "
                     f"everything'; unset topk_frac instead")
            if cp.quant is None and not float(cp.topk_frac) > 0.0:
                _err("compression",
                     "no compressor selected — set quant ('bf16'|'int8') "
                     "and/or topk_frac > 0, or drop the compression block")
            if float(cp.topk_frac) > 0.0 and sch.hierarchy_period > 0:
                _err("compression.topk_frac",
                     "top-k sparsification does not compose with the "
                     "hierarchical grouped mean "
                     "(schedule.hierarchy_period > 0) — error feedback "
                     "against two different means is ill-defined; use "
                     "quant-only compression or a flat schedule")
            if cp.sections is not None:
                if len(cp.sections) == 0:
                    _err("compression.sections",
                         "[] compresses nothing — use null for every "
                         "communicated section, or drop the block")
                from repro.optim.sequences import PRIVATE, SPECS
                aspec = SPECS[self.algorithm.name]
                private = tuple(q.section for q in aspec.sequences
                                if q.comm == PRIVATE)
                unknown = [s for s in cp.sections if s not in sections]
                if unknown:
                    _err("compression.sections",
                         f"{unknown} are not sections of "
                         f"{self.algorithm.name!r} (sections: {sections})")
                bad = [s for s in cp.sections if s in private]
                if bad:
                    _err("compression.sections",
                         f"{bad} are PRIVATE sections of "
                         f"{self.algorithm.name!r} — private state never "
                         f"enters a reduction, so it cannot be compressed")

        tl = self.telemetry
        if tl is not None:
            if tl.sink is not None and not isinstance(tl.sink, str):
                _err("telemetry.sink",
                     f"{tl.sink!r} is not a path (string) or null")
            if tl.metrics is not None:
                unknown = [g for g in tl.metrics if g not in METRIC_GROUPS]
                if unknown:
                    _err("telemetry.metrics",
                         f"unknown metric groups {unknown}; choose from "
                         f"{METRIC_GROUPS}")
                if tl.metrics and not ex.fuse_storm:
                    _err("telemetry.metrics",
                         "in-band metrics need execution.fuse_storm=true — "
                         "they are a side output of the fused sequence-spec "
                         "engine; use metrics=[] for an events-only stream")
                if "compression" in tl.metrics and cp is None:
                    _err("telemetry.metrics",
                         "the 'compression' group needs a compression block "
                         "— there is no EF residual or quantization error "
                         "to report")
                if "health" in tl.metrics and (fl is None and rb is None
                        and self.normalize().participation.sampler
                        == "full"):
                    _err("telemetry.metrics",
                         "the 'health' group needs faults, robustness or a "
                         "non-full participation sampler — there is nothing "
                         "to screen")
                if "stragglers" in tl.metrics and self.stragglers is None:
                    _err("telemetry.metrics",
                         "the 'stragglers' group needs a stragglers block — "
                         "there is no deadline or arrival set to report")
        sg = self.stragglers
        if sg is not None:
            if not ex.fuse_storm:
                _err("stragglers",
                     "needs execution.fuse_storm=true — deadline-driven "
                     "elastic rounds are a feature of the fused "
                     "sequence-spec engine")
            if sch.hierarchy_period > 0:
                _err("stragglers",
                     "does not compose with the hierarchical grouped mean "
                     "(schedule.hierarchy_period > 0) — the deadline/quorum "
                     "decision is global; set hierarchy_period=0")
            if sg.late_policy not in LATE_POLICIES:
                _err("stragglers.late_policy",
                     f"unknown policy {sg.late_policy!r}; choose from "
                     f"{LATE_POLICIES}")
            if not float(sg.base_time) > 0.0:
                _err("stragglers.base_time", f"{sg.base_time} must be > 0")
            if float(sg.tail) < 0.0:
                _err("stragglers.tail", f"{sg.tail} must be >= 0")
            if not float(sg.deadline) > 0.0:
                _err("stragglers.deadline", f"{sg.deadline} must be > 0 "
                     f"(simulated seconds)")
            if int(sg.over_provision) < 0:
                _err("stragglers.over_provision",
                     f"{sg.over_provision} must be >= 0")
            if int(sg.over_provision) > 0 and (
                    self.normalize().participation.sampler
                    not in ("uniform", "weighted")):
                _err("stragglers.over_provision",
                     "needs a counted (uniform/weighted) m-of-M sampler to "
                     "request extra clients — the full/trace samplers do "
                     "not take a count; set over_provision=0 or switch "
                     "samplers")
            if not 0.0 < float(sg.quorum) <= 1.0:
                _err("stragglers.quorum",
                     f"{sg.quorum} must be in (0, 1] — a fraction of the "
                     f"round's sampled clients")
            if float(sg.backoff) < 1.0:
                _err("stragglers.backoff", f"{sg.backoff} must be >= 1")
            if int(sg.max_extensions) < 0:
                _err("stragglers.max_extensions",
                     f"{sg.max_extensions} must be >= 0")
            if not 0.0 < float(sg.target_percentile) <= 1.0:
                _err("stragglers.target_percentile",
                     f"{sg.target_percentile} must be in (0, 1]")
            if not 0.0 <= float(sg.adapt_rate) <= 1.0:
                _err("stragglers.adapt_rate",
                     f"{sg.adapt_rate} must be in [0, 1]")
            if int(sg.start_round) < 0:
                _err("stragglers.start_round",
                     f"{sg.start_round} must be >= 0")
        return self

    # -- JSON ---------------------------------------------------------------

    def to_json(self, *, indent: int | None = 1) -> str:
        d = dataclasses.asdict(self)
        d["algorithm"]["params"] = self.algorithm.params_dict
        # dataclasses.asdict reconstructs NamedTuples (json would emit
        # lists) — serialize them as objects explicitly
        d["participation"] = self.participation._asdict()
        d["faults"] = self.faults._asdict() if self.faults else None
        d["robustness"] = (self.robustness._asdict()
                           if self.robustness else None)
        d["compression"] = (self.compression._asdict()
                            if self.compression else None)
        if self.compression and self.compression.sections is not None:
            d["compression"]["sections"] = list(self.compression.sections)
        d["telemetry"] = self.telemetry._asdict() if self.telemetry else None
        if self.telemetry and self.telemetry.metrics is not None:
            d["telemetry"]["metrics"] = list(self.telemetry.metrics)
        d["stragglers"] = (self.stragglers._asdict()
                           if self.stragglers else None)
        d["schedule"]["comm_every"] = self.schedule.comm_every_dict
        # version first — the one key a reader must dispatch on
        d = {"version": d.pop("version"), **d}
        return json.dumps(d, indent=indent, sort_keys=False)

    @classmethod
    def from_json(cls, text: str) -> "Experiment":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"Experiment JSON does not parse: {e}") from e
        if not isinstance(d, dict):
            raise SpecError("Experiment JSON must be an object")
        version = d.pop("version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise SpecError(f"Experiment.version: unsupported spec version "
                            f"{version!r} (this build reads {SPEC_VERSION})")
        parts = {}
        groups = {"algorithm": AlgorithmSpec, "problem": ProblemSpec,
                  "execution": ExecutionSpec, "schedule": ScheduleSpec}
        for key, klass in groups.items():
            sub = d.pop(key, {})
            if not isinstance(sub, dict):
                raise SpecError(f"Experiment.{key}: expected an object")
            known = {f.name for f in fields(klass)}
            unknown = set(sub) - known
            if unknown:
                raise SpecError(f"Experiment.{key}: unknown keys "
                                f"{sorted(unknown)} (knows {sorted(known)})")
            parts[key] = klass(**sub)
        sub = d.pop("participation", {})
        known = set(ParticipationSpec._fields)
        unknown = set(sub) - known
        if unknown:
            raise SpecError(f"Experiment.participation: unknown keys "
                            f"{sorted(unknown)} (knows {sorted(known)})")
        if sub.get("client_weights") is not None:
            sub["client_weights"] = tuple(sub["client_weights"])
        parts["participation"] = ParticipationSpec(**sub)
        for key, klass in (("faults", FaultSpec),
                           ("robustness", RobustnessSpec),
                           ("compression", CompressionSpec),
                           ("telemetry", TelemetrySpec),
                           ("stragglers", StragglerSpec)):
            sub = d.pop(key, None)
            if sub is None:
                parts[key] = None
                continue
            if not isinstance(sub, dict):
                raise SpecError(f"Experiment.{key}: expected an object or "
                                f"null")
            known = set(klass._fields)
            unknown = set(sub) - known
            if unknown:
                raise SpecError(f"Experiment.{key}: unknown keys "
                                f"{sorted(unknown)} (knows {sorted(known)})")
            if sub.get("sections") is not None:
                sub["sections"] = tuple(sub["sections"])
            if sub.get("metrics") is not None:
                sub["metrics"] = tuple(sub["metrics"])
            parts[key] = klass(**sub)
        if d:
            raise SpecError(f"Experiment: unknown top-level keys {sorted(d)}")
        return cls(version=version, **parts)

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())
            fh.write("\n")

    @classmethod
    def load(cls, path: str) -> "Experiment":
        with open(path) as fh:
            return cls.from_json(fh.read())

    # -- sweeps -------------------------------------------------------------

    def edit(self, **changes: Any) -> "Experiment":
        """A new Experiment with dotted-path fields replaced — the sweep
        primitive (a scenario list is ``[base.edit(**e) for e in edits]``):

            exp.edit(**{"participation.availability_rate": 0.5,
                        "schedule.steps": 32})
        """
        out = self
        for path, value in changes.items():
            head, _, rest = path.partition(".")
            if not hasattr(out, head):
                _err(head, f"no such field (editing {path!r})")
            if not rest:
                out = dataclasses.replace(out, **{head: value})
                continue
            sub = getattr(out, head)
            if sub is None and head in ("faults", "robustness",
                                        "compression", "telemetry",
                                        "stragglers"):
                # sweeping a guard knob on an unguarded base spec enables
                # the layer with defaults — `edit(**{"faults.nan_rate": .1})`
                sub = {"faults": FaultSpec, "robustness": RobustnessSpec,
                       "compression": CompressionSpec,
                       "telemetry": TelemetrySpec,
                       "stragglers": StragglerSpec}[head]()
            if isinstance(sub, (ParticipationSpec, FaultSpec,
                                RobustnessSpec, CompressionSpec,
                                TelemetrySpec, StragglerSpec)):
                if rest not in type(sub)._fields:
                    _err(path, "no such field")
                # NamedTuple _replace skips the dataclasses' __post_init__
                # normalization — coerce list edits so the spec stays
                # hashable and JSON-round-trip-equal
                if isinstance(value, list):
                    value = tuple(value)
                sub = sub._replace(**{rest: value})
            else:
                if rest not in {f.name for f in fields(sub)}:
                    _err(path, "no such field")
                sub = dataclasses.replace(sub, **{rest: value})
            out = dataclasses.replace(out, **{head: sub})
        return out
