"""Mamba-2 130M — SSD state-space duality, attention-free [arXiv:2405.21060]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50_280,
    ssm_state=128, ssm_heads=24, ssm_head_dim=64, ssm_chunk=256, conv_width=4,
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
)
