"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from repro.configs import (gemma2_2b, granite_3_8b, granite_8b,
                           granite_moe_1b_a400m, hubert_xlarge, internvl2_76b,
                           llama3_405b, mamba2_130m, olmoe_1b_7b,
                           recurrentgemma_9b)

ARCHS = {
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "gemma2-2b": gemma2_2b.CONFIG,
    "mamba2-130m": mamba2_130m.CONFIG,
    "llama3-405b": llama3_405b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "granite-3-8b": granite_3_8b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b_a400m.CONFIG,
    "internvl2-76b": internvl2_76b.CONFIG,
    "granite-8b": granite_8b.CONFIG,
}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]
