"""Granite-8B code — llama-arch dense decoder [arXiv:2405.04324]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=49_152,
    source="arXiv:2405.04324 (Granite Code)",
)
