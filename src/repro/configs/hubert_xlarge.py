"""HuBERT X-Large — encoder-only audio transformer [arXiv:2106.07447].

The conv/mel frontend is a stub per the brief: ``input_specs`` feeds
precomputed 512-dim frame embeddings.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, causal=False, frontend_dim=512,
    source="arXiv:2106.07447 (HuBERT)",
)
