"""Llama-3 405B — GQA dense decoder, 128k vocab [arXiv:2407.21783]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128_256, head_dim=128,
    rope_theta=500_000.0,
    source="arXiv:2407.21783 (Llama 3)",
)
