"""Gemma-2 2B — local+global alternating attention, logit softcap [arXiv:2408.00118]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256_000, head_dim=256,
    attention_pattern="local_global", window_size=4096,
    logit_softcap=30.0, attn_softcap=50.0, scale_embed=True,
    source="arXiv:2408.00118 (Gemma 2)",
)
