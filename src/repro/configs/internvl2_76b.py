"""InternVL2-76B — VLM: LM decoder backbone + ViT stub frontend [arXiv:2404.16821].

The InternViT tower + projector is a stub per the brief: ``input_specs``
feeds 3200-dim patch embeddings (256 patches per image) which a learned
projector maps into the LM embedding space, interleaved before the tokens.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128_256, head_dim=128,
    num_patches=256, frontend_dim=3200, rope_theta=500_000.0,
    source="arXiv:2404.16821 (InternVL2)",
)
