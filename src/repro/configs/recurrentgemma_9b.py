"""RecurrentGemma-9B — RG-LRU + local attention hybrid [arXiv:2402.19427]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256_000, head_dim=256,
    lru_width=4096, attention_pattern="rg", window_size=2048,
    conv_width=4, scale_embed=True,
    source="arXiv:2402.19427 (Griffin/RecurrentGemma)",
)
