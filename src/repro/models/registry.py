"""Top-level model API.

``build_model(cfg)`` returns a :class:`Model` bundle of pure functions:

* ``init(key)``                        -> params ``{"body": ..., "head": ...}``
* ``forward(params, batch, ...)``      -> logits ``[B, S_total, V]``
* ``loss(params, batch, ...)``         -> (scalar, aux dict)
* ``prefill(params, batch, cache_len)``-> (last_logits, caches)
* ``decode_step(params, caches, tokens, pos)`` -> (logits, caches)
* ``init_cache(batch, cache_len)``     -> zeroed cache pytree

The body/head split is the bilevel split used by the FedBiO problems: the
*upper* variable x is the body, the *lower* variable y is the output head
(see DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import stack as stk
from repro.models.layers import (dense_init, embed, embedding_init, head_init,
                                 rmsnorm, rmsnorm_init, _softcap)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


# ---------------------------------------------------------------------------

def _embed_inputs(body, batch: Dict[str, Any], cfg: ModelConfig):
    """Returns (x [B, S_total, d], positions [B, S_total], label_offset)."""
    if cfg.family == "audio":
        x = batch["frames"] @ body["frontend_proj"]
        offset = 0
    elif cfg.family == "vlm":
        tok = embed(body["embed"], batch["tokens"])
        patches = batch["patches"] @ body["patch_proj"]
        x = jnp.concatenate([patches.astype(tok.dtype), tok], axis=1)
        offset = patches.shape[1]
    else:
        x = embed(body["embed"], batch["tokens"])
        offset = 0
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return x, positions, offset


def build_model(cfg: ModelConfig, dtype=jnp.bfloat16) -> Model:
    def init(key):
        kb, kh, ke, kf = jax.random.split(key, 4)
        body: Dict[str, Any] = {
            "stages": stk.init_stack(kb, cfg, dtype),
            "final_ln": rmsnorm_init(cfg.d_model, dtype),
        }
        if cfg.family == "audio":
            body["frontend_proj"] = dense_init(kf, (cfg.frontend_dim, cfg.d_model), dtype)
        else:
            body["embed"] = embedding_init(ke, cfg, dtype)
            if cfg.family == "vlm":
                body["patch_proj"] = dense_init(kf, (cfg.frontend_dim, cfg.d_model), dtype)
        head = head_init(kh, cfg, dtype)
        return {"body": body, "head": head}

    def _run(params, x, positions, *, caches=None, cache_index=None,
             remat=False, use_flash=False, use_lru_kernel=False):
        body, head = params["body"], params["head"]
        x, new_caches, aux = stk.apply_stack(
            body["stages"], x, cfg, positions=positions, caches=caches,
            cache_index=cache_index, remat=remat, use_flash=use_flash,
            use_lru_kernel=use_lru_kernel)
        x = rmsnorm(body["final_ln"], x, cfg.norm_eps)
        logits = x @ head["w"]
        logits = _softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        return logits, new_caches, aux

    def forward(params, batch, *, remat=False, use_flash=False,
                use_lru_kernel=False):
        x, positions, offset = _embed_inputs(params["body"], batch, cfg)
        logits, _, aux = _run(params, x, positions, remat=remat,
                              use_flash=use_flash, use_lru_kernel=use_lru_kernel)
        return logits[:, offset:, :], aux

    def loss(params, batch, *, remat=False, use_flash=False,
             use_lru_kernel=False, aux_weight: float = 0.01):
        """Masked CE: positions with ``labels < 0`` are ignored (padding /
        prompt-only spans)."""
        logits, aux = forward(params, batch, remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = ce + aux_weight * aux
        return total, {"ce": ce, "moe_aux": aux}

    def init_cache(batch_size: int, cache_len: int):
        return stk.init_cache(cfg, batch_size, cache_len, dtype)

    def prefill(params, batch, cache_len: int, *, use_flash=False,
                use_lru_kernel=False):
        x, positions, offset = _embed_inputs(params["body"], batch, cfg)
        B, S = positions.shape
        logits, seq_caches, _ = _run(params, x, positions, use_flash=use_flash,
                                     use_lru_kernel=use_lru_kernel)
        # convert full-sequence kv into decode ring buffers
        caches = init_cache(B, cache_len)
        new = []
        for (unit, reps), zero_stage, seq_stage in zip(
                stk.stages_for(cfg), caches, seq_caches):
            stage_out = {}
            for i, kind in enumerate(unit):
                name = f"{i}_{kind}"
                if kind in ("rec", "ssm"):
                    stage_out[name] = seq_stage[name]
                    continue
                zk, zv = zero_stage[name]
                sk, sv = seq_stage[name]          # [reps, B, S, hkv, hd]
                L = zk.shape[2]
                Lt = min(S, L)
                tail_k, tail_v = sk[:, :, S - Lt:], sv[:, :, S - Lt:]
                pad = L - Lt
                if pad:
                    tail_k = jnp.pad(tail_k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                    tail_v = jnp.pad(tail_v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                shift = (S - Lt) % L
                stage_out[name] = (jnp.roll(tail_k, shift, axis=2),
                                   jnp.roll(tail_v, shift, axis=2))
            new.append(stage_out)
        return logits[:, -1, :], new

    def decode_step(params, caches, tokens, pos, *, use_lru_kernel=False):
        """tokens: [B, 1] int32; pos: scalar int32 or [B] vector of 0-based
        next positions (continuous batching)."""
        body = params["body"]
        if cfg.family == "audio":
            raise ValueError("encoder-only model has no decode step")
        x = embed(body["embed"], tokens)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        B = x.shape[0]
        pos = jnp.asarray(pos)
        positions = (jnp.broadcast_to(pos[None, None], (B, 1)) if pos.ndim == 0
                     else pos[:, None])
        logits, new_caches, _ = _run(params, x, positions, caches=caches,
                                     cache_index=pos,
                                     use_lru_kernel=use_lru_kernel)
        return logits[:, 0, :], new_caches

    return Model(cfg=cfg, init=init, forward=forward, loss=loss,
                 prefill=prefill, decode_step=decode_step,
                 init_cache=init_cache)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
