"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The block is: RMSNorm -> two linear branches (recurrent + gate); the recurrent
branch passes through a short causal conv then the RG-LRU gated linear
recurrence; output = W_out(lru_out * GeLU(gate_branch)).

RG-LRU recurrence (per channel):
    r_t = sigmoid(x_t W_a + b_a)           # recurrence gate
    i_t = sigmoid(x_t W_x + b_x)           # input gate
    log a_t = -c * softplus(Lambda) * r_t  # c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan over time (O(log T) depth) or the
Pallas time-tiled scan kernel (`repro.kernels.lru`); decode is a single fused
update carrying ``h``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

_C = 8.0   # Griffin's fixed recurrence sharpness constant


def init_rec(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.resolved_lru_width
    ks = jax.random.split(key, 6)
    return {
        "ln": rmsnorm_init(d, dtype),
        "in_x": dense_init(ks[0], (d, w), dtype),
        "in_gate": dense_init(ks[1], (d, w), dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": dense_init(ks[3], (w, w), dtype),
        "ba": jnp.zeros((w,), dtype),
        "wx": dense_init(ks[4], (w, w), dtype),
        "bx": jnp.zeros((w,), dtype),
        # Lambda init so that a = exp(-c*softplus(L)) spans (0.9, 0.999)
        "Lambda": jnp.linspace(-2.0, 1.0, w).astype(jnp.float32),
        "out": dense_init(ks[5], (w, d), dtype, scale=1.0 / math.sqrt(w)),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out + b


def rg_lru_gates(params, x):
    """Compute per-step (log_a, beta) for h_t = a_t h_{t-1} + beta_t."""
    r = jax.nn.sigmoid((x @ params["wa"]).astype(jnp.float32) + params["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["wx"]).astype(jnp.float32) + params["bx"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["Lambda"]) * r            # [B,S,W]
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * x.astype(jnp.float32))
    return a, beta


def linear_scan(a, b, h0=None, use_kernel: bool = False):
    """h_t = a_t * h_{t-1} + b_t along axis 1. a, b: [B, S, W] (fp32)."""
    if use_kernel:
        from repro.kernels.lru import ops as lru_ops
        return lru_ops.lru_scan(a, b, h0)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rec(params, x, cfg: ModelConfig, cache=None, use_kernel: bool = False):
    """Griffin recurrent block. cache = {h:[B,W], conv:[B, Wc-1, W]} for decode."""
    B, S, _ = x.shape
    Wc = params["conv_w"].shape[0]
    h_in = rmsnorm(params["ln"], x, cfg.norm_eps)
    xr = h_in @ params["in_x"]
    gate = h_in @ params["in_gate"]

    if cache is None:
        xr_pre = xr                                                # pre-conv inputs
        xr = _causal_conv(xr, params["conv_w"], params["conv_b"])
        a, beta = rg_lru_gates(params, xr)
        h = linear_scan(a, beta, use_kernel=use_kernel)            # [B,S,W] fp32
        h_last = h[:, -1, :]
        conv_tail = xr_pre[:, max(S - (Wc - 1), 0):, :]
        if S < Wc - 1:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (Wc - 1 - S, 0), (0, 0)))
        new_cache = {"h": h_last, "conv": conv_tail.astype(x.dtype)}
    else:
        conv_buf = jnp.concatenate([cache["conv"], xr.astype(x.dtype)], axis=1)
        xr = jnp.einsum("bwc,wc->bc", conv_buf, params["conv_w"]) + params["conv_b"]
        xr = xr[:, None, :]
        a, beta = rg_lru_gates(params, xr)
        h_new = a[:, 0] * cache["h"] + beta[:, 0]
        h = h_new[:, None, :]
        new_cache = {"h": h_new, "conv": conv_buf[:, 1:, :]}

    out = (h.astype(x.dtype) * jax.nn.gelu(gate, approximate=True)) @ params["out"]
    return out, new_cache


def init_rec_cache(cfg: ModelConfig, batch: int, dtype):
    w = cfg.resolved_lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }
