"""Shared neural building blocks (pure-JAX, pytree params, functional apply).

Conventions
-----------
* Params are nested dicts of ``jnp.ndarray``; per-layer stacks carry a leading
  repetition axis and are consumed with ``lax.scan`` (keeps HLO small for
  126-layer models and compiles fast under 512-way SPMD).
* Compute dtype follows the input; normalisation / softmax statistics in fp32.
* ``cache`` pytrees hold decode state (KV ring buffers / recurrent states).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig

# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.arange(half, dtype=jnp.float32)
    inv = 1.0 / (theta ** (freq / half))
    ang = positions.astype(jnp.float32)[..., None] * inv          # [..., S, half]
    ang = ang[..., None, :]                                       # [..., S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / softcap / bidirectional)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, hq * hd), dtype),
        "wk": dense_init(k2, (d, hkv * hd), dtype),
        "wv": dense_init(k3, (d, hkv * hd), dtype),
        "wo": dense_init(k4, (hq * hd, d), dtype, scale=1.0 / math.sqrt(hq * hd)),
    }


def _softcap(x, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(x / cap) * cap
    return x


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int):
    """Additive mask bias [..., Sq, Sk] in fp32."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        ok &= diff >= 0
    if window and window > 0:
        ok &= diff < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(params, x, cfg: ModelConfig, *, window: int, positions,
              kv_cache=None, cache_index=None, use_flash: bool = False):
    """Self attention.

    Training / prefill: ``kv_cache is None`` -> full sequence, returns (out, (k, v)).
    Decode: ``kv_cache = (k, v)`` ring/linear buffers of length S_cache and
    ``cache_index`` scalar -> single-token query, returns (out, (k, v) updated).
    """
    B, S, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = hq // hkv

    q = (x @ params["wq"]).reshape(B, S, hq, hd)
    k = (x @ params["wk"]).reshape(B, S, hkv, hd)
    v = (x @ params["wv"]).reshape(B, S, hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(hd)

    if kv_cache is None:
        if use_flash:
            from repro.kernels.flash import ops as flash_ops
            kf = jnp.repeat(k, rep, axis=2)
            vf = jnp.repeat(v, rep, axis=2)
            out = flash_ops.flash_attention(
                q, kf, vf, causal=cfg.causal, window=window,
                softcap=cfg.attn_softcap, scale=scale)
        else:
            # grouped GQA einsum: never materialises the rep-expanded kv
            qg = q.reshape(B, S, hkv, rep, hd)
            logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32) * scale
            logits = _softcap(logits, cfg.attn_softcap)
            bias = _mask_bias(positions, positions, causal=cfg.causal, window=window)
            logits = logits + bias[:, None, None, :, :]
            probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
            out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v).reshape(B, S, hq, hd)
        out = out.reshape(B, S, hq * hd) @ params["wo"]
        return out, (k, v)

    # ----- decode: single token, update cache -----
    # ``cache_index`` may be a scalar (uniform position) or a [B] vector
    # (continuous batching: each request at its own position).
    from repro.sharding.hints import hint
    ck, cv = kv_cache                       # [B, S_cache, hkv, hd]
    S_cache = ck.shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(cache_index), (B,))
    slot = pos_b % S_cache                  # ring-buffer slot per request
    barange = jnp.arange(B)
    ck = ck.at[barange, slot].set(k[:, 0])
    cv = cv.at[barange, slot].set(v[:, 0])
    # keep the cache B×S sharded; replicate the (tiny) q instead — forces the
    # partial-softmax plan rather than an all-gather of the cache (§Perf)
    ck = hint(ck, "data", "model", None, None)
    cv = hint(cv, "data", "model", None, None)
    qg = hint(q.reshape(B, S, hkv, rep, hd), "data", None, None, None, None)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, ck).astype(jnp.float32) * scale
    logits = _softcap(logits, cfg.attn_softcap)
    # true position held in each ring slot: newest token sits at `slot`
    slots = jnp.arange(S_cache)
    k_pos = pos_b[:, None] - ((slot[:, None] - slots[None, :]) % S_cache)
    valid = (k_pos >= 0) & (k_pos <= pos_b[:, None])
    if window and window > 0:
        valid &= k_pos > (pos_b[:, None] - window)
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)   # [B, S_cache]
    logits = logits + bias[:, None, None, None, :]
    logits = hint(logits, "data", None, None, None, "model")
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, cv).reshape(B, S, hq, hd)
    out = hint(out, "data", None, None, None)
    out = out.reshape(B, S, hq * hd) @ params["wo"]
    return out, (ck, cv)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU / plain GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, dtype, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": dense_init(k1, (d_model, d_ff), dtype),
        "wo": dense_init(k3, (d_ff, d_model), dtype, scale=1.0 / math.sqrt(d_ff)),
    }
    if gated:
        p["wg"] = dense_init(k2, (d_model, d_ff), dtype)
    return p


def mlp(params, x, activation: str = "silu"):
    h = x @ params["wi"]
    if "wg" in params:
        g = x @ params["wg"]
        act = jax.nn.gelu(g, approximate=True) if activation == "gelu" else jax.nn.silu(g)
        h = act * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Mixture of Experts (dense dispatch — TPU-friendly, capacity-free)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, dtype):
    d, dff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d, E), dtype, scale=0.02),
        "wi": dense_init(k1, (E, d, dff), dtype),
        "wg": dense_init(k2, (E, d, dff), dtype),
        "wo": dense_init(k3, (E, dff, d), dtype, scale=1.0 / math.sqrt(dff)),
    }


MOE_DENSE_TOKEN_LIMIT = 8192   # below this token count use the exact dense path


def moe_mlp(params, x, cfg: ModelConfig, capacity_factor: float = 1.25):
    """Top-k routed expert MLP.

    Two TPU-friendly paths, selected statically by token count:

    * **dense combine** (small T, smoke tests): every expert runs on every
      token, weighted combine — exact, no drops, O(T·E·d_ff) FLOPs.
    * **capacity dispatch** (production shapes): tokens are scattered into
      per-expert buffers ``[E, C, d]`` with ``C = T·k/E·cf``; overflow drops
      (standard Switch/GShard semantics). Expert matmuls are batched einsums
      with the expert axis model-sharded; under SPMD the scatter/gather lower
      to all-to-all traffic, which is what the roofline should see.

    Returns (out, aux) where aux is the Switch load-balancing loss.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    logits = (x @ params["router"]).astype(jnp.float32)            # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = lax.top_k(probs, k)                           # [B,S,k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=(0, 1))                              # [E]
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=-2),
                  axis=(0, 1)) / k
    aux = E * jnp.sum(me * ce)

    if T <= MOE_DENSE_TOKEN_LIMIT:
        combine = jnp.sum(
            jax.nn.one_hot(top_idx, E, dtype=x.dtype) * top_w[..., None].astype(x.dtype),
            axis=-2)                                               # [B,S,E]
        h = jnp.einsum("bsd,edf->bsef", x, params["wi"])
        g = jnp.einsum("bsd,edf->bsef", x, params["wg"])
        h = jax.nn.silu(g) * h
        y = jnp.einsum("bsef,efd->bsed", h, params["wo"])
        out = jnp.einsum("bsed,bse->bsd", y, combine)
        return out, aux

    # ---- capacity-based dispatch ----
    C = int(T * k // E * capacity_factor) or 1
    xf = x.reshape(T, d)
    e_idx = top_idx.reshape(T, k)                                  # expert per slot
    w = top_w.reshape(T, k).astype(x.dtype)
    # position of each (token, slot) within its expert, in token order
    onehot = jax.nn.one_hot(e_idx.reshape(T * k), E, dtype=jnp.int32)   # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1                  # [T*k, E]
    pos = jnp.sum(pos * onehot, axis=-1).reshape(T, k)             # [T, k]
    keep = (pos >= 0) & (pos < C)
    pos_c = jnp.where(keep, pos, 0)
    e_c = jnp.where(keep, e_idx, 0)
    contrib = xf[:, None, :] * keep[..., None].astype(x.dtype)     # [T,k,d]
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[e_c.reshape(-1), pos_c.reshape(-1)].add(
        contrib.reshape(T * k, d))
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, params["wo"])
    gathered = y[e_c.reshape(-1), pos_c.reshape(-1)].reshape(T, k, d)
    out = jnp.sum(gathered * (w * keep.astype(x.dtype))[..., None], axis=1)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig, dtype):
    return {"table": embed_init(key, (cfg.vocab_size, cfg.d_model), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(head_params, x):
    return x @ head_params["w"]


def head_init(key, cfg: ModelConfig, dtype):
    return {"w": dense_init(key, (cfg.d_model, cfg.vocab_size), dtype)}
