from repro.models.registry import build_model, Model  # noqa: F401
