"""Generic layer stack.

A model is a sequence of **stages**; each stage repeats a **unit** (a short
tuple of layer kinds, e.g. ``("local", "attn")`` for gemma2's alternating
pattern or ``("rec", "rec", "attn")`` for recurrentgemma) ``reps`` times.
Per-stage parameters are stacked on a leading reps axis and consumed with
``lax.scan`` — a 126-layer llama lowers to a single scanned block, keeping the
HLO tiny and SPMD compile times manageable.

Layer kinds:
    attn   full-context GQA attention + FFN (dense MLP or MoE)
    local  sliding-window GQA attention + FFN
    rec    Griffin RG-LRU recurrent block + FFN
    ssm    Mamba-2 SSD block (self-contained, no FFN)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import griffin, ssm
from repro.models.layers import (attention, attn_init, mlp, mlp_init, moe_init,
                                 moe_mlp, rmsnorm, rmsnorm_init)

Stage = Tuple[Tuple[str, ...], int]


def stages_for(cfg: ModelConfig) -> List[Stage]:
    kinds = list(cfg.layer_kinds())
    if cfg.family == "hybrid":
        unit: Tuple[str, ...] = ("rec", "rec", "attn")
    elif cfg.attention_pattern == "local_global":
        unit = ("local", "attn")
    else:
        unit = (kinds[0],)
    stages: List[Stage] = []
    i, u = 0, len(unit)
    full = 0
    while i + u <= len(kinds) and tuple(kinds[i:i + u]) == unit:
        full += 1
        i += u
    if full:
        stages.append((unit, full))
    # remainder: consecutive same-kind runs
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        stages.append(((kinds[i],), j - i))
        i = j
    return stages


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, kind: str, cfg: ModelConfig, dtype):
    if kind == "ssm":
        return {"ssm": ssm.init_ssm(key, cfg, dtype)}
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {}
    if kind == "rec":
        p["mix"] = griffin.init_rec(k1, cfg, dtype)
    else:
        p["ln1"] = rmsnorm_init(cfg.d_model, dtype)
        p["mix"] = attn_init(k1, cfg, dtype)
    p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.num_experts:
        p["ffn"] = moe_init(k2, cfg, dtype)
    else:
        gated = cfg.family != "audio"
        p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, gated=gated)
    return p


def _init_unit(key, unit: Tuple[str, ...], cfg: ModelConfig, dtype):
    keys = jax.random.split(key, len(unit))
    return {f"{i}_{kind}": _init_layer(keys[i], kind, cfg, dtype)
            for i, kind in enumerate(unit)}


def init_stack(key, cfg: ModelConfig, dtype):
    stages = stages_for(cfg)
    params = []
    for si, (unit, reps) in enumerate(stages):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, reps)
        stacked = jax.vmap(lambda k: _init_unit(k, unit, cfg, dtype))(keys)
        params.append(stacked)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int, dtype):
    if kind == "ssm":
        return ssm.init_ssm_cache(cfg, batch, dtype)
    if kind == "rec":
        return griffin.init_rec_cache(cfg, batch, dtype)
    length = cache_len
    if kind == "local" and cfg.window_size:
        length = min(cfg.window_size, cache_len)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return (jnp.zeros((batch, length, hkv, hd), dtype),
            jnp.zeros((batch, length, hkv, hd), dtype))


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    caches = []
    for unit, reps in stages_for(cfg):
        unit_cache = {f"{i}_{kind}": _layer_cache(kind, cfg, batch, cache_len, dtype)
                      for i, kind in enumerate(unit)}
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), unit_cache)
        caches.append(stacked)
    return caches


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _apply_layer(kind, p, x, cfg, positions, cache, cache_index, use_flash,
                 use_lru_kernel):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        out, nc = ssm.apply_ssm(p["ssm"], x, cfg, cache)
        return x + out, nc, aux
    if kind == "rec":
        out, nc = griffin.apply_rec(p["mix"], x, cfg, cache, use_kernel=use_lru_kernel)
        x = x + out
    else:
        window = cfg.window_size if kind == "local" else 0
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        out, nc = attention(p["mix"], h, cfg, window=window, positions=positions,
                            kv_cache=cache, cache_index=cache_index,
                            use_flash=use_flash)
        x = x + out
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.num_experts:
        out, aux = moe_mlp(p["ffn"], h, cfg)
    else:
        act = "gelu" if cfg.family in ("audio",) or cfg.logit_softcap else "silu"
        out = mlp(p["ffn"], h, activation=act)
    return x + out, nc, aux


def apply_stack(params, x, cfg: ModelConfig, *, positions, caches=None,
                cache_index=None, remat: bool = False, use_flash: bool = False,
                use_lru_kernel: bool = False):
    """Run all stages. Returns (x, new_caches, aux_sum).

    When ``caches is None`` in training mode, per-layer caches are still
    returned as ``None`` (no state tracked) — prefill passes fresh zero caches
    built by :func:`init_cache` filled via the no-cache path's returned kv.
    """
    stages = stages_for(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []

    for si, (unit, reps) in enumerate(stages):
        stage_params = params[si]
        stage_cache = caches[si] if caches is not None else None

        def unit_body(carry_x, xs, unit=unit):
            p, c = xs
            aux_acc = jnp.zeros((), jnp.float32)
            ncs = {}
            xcur = carry_x
            for i, kind in enumerate(unit):
                name = f"{i}_{kind}"
                lcache = c[name] if c is not None else None
                xcur, nc, aux = _apply_layer(
                    kind, p[name], xcur, cfg, positions, lcache, cache_index,
                    use_flash, use_lru_kernel)
                ncs[name] = nc
                aux_acc = aux_acc + aux
            return xcur, (ncs, aux_acc)

        body = jax.checkpoint(unit_body) if remat else unit_body

        if caches is not None:
            x, (ncs, auxs) = lax.scan(body, x, (stage_params, stage_cache))
        else:
            x, (ncs, auxs) = lax.scan(body, x, (stage_params, None))
        new_caches.append(ncs)
        aux_total = aux_total + jnp.sum(auxs)
    return x, new_caches, aux_total
