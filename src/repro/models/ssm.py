"""Mamba-2 (SSD — state-space duality) mixer block, chunked-scan formulation.

Follows the minimal SSD reference (Dao & Gu, 2024, arXiv:2405.21060): the
sequence is split into chunks of length Q; within a chunk the output is a
masked quadratic (attention-like) form, across chunks a linear recurrence on
the per-chunk states. The cross-chunk scan is a `lax.scan`; everything else is
einsums that map directly onto the MXU.

Decode maintains the O(1) recurrent state ``h: [B, H, P, N]`` plus a causal
conv ring of the last (conv_width-1) inputs — this is what makes `long_500k`
decoding feasible for the ssm family.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_heads * cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N           # x, B, C share the conv (ngroups=1)
    return d_inner, N, conv_dim


def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_inner, N, conv_dim = _dims(cfg)
    H = cfg.ssm_heads
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_inner + 2 * N + H   # z, x, B, C, dt
    return {
        "ln": rmsnorm_init(d, dtype),
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_ln": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype, scale=1.0 / math.sqrt(d_inner)),
    }


def _split_proj(cfg, proj):
    d_inner, N, _ = _dims(cfg)
    H = cfg.ssm_heads
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    return z, xs, Bm, Cm, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv over time. xBC: [B, L, C]; w: [W, C]."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(W):
        out = out + pad[:, i:i + xBC.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _segsum(a):
    """a: [..., Q] -> lower-triangular pairwise segment sums [..., Q, Q]."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """SSD forward.

    x:  [B, L, H, P]   dt: [B, L, H]   A: [H] (negative)
    Bm: [B, L, N]      Cm: [B, L, N]
    Returns (y [B, L, H, P], h_final [B, H, P, N]).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    Lp = ((L + Q - 1) // Q) * Q
    if Lp != L:
        # zero-pad: dt==0 -> unit decay, zero input; state and outputs unchanged
        pad = Lp - L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    L_orig, L = L, Lp
    C = L // Q
    xdt = x * dt[..., None]                                   # input weighting
    a = (dt * A).astype(jnp.float32)                          # log decay per step

    xc = xdt.reshape(Bsz, C, Q, H, P)
    ac = a.reshape(Bsz, C, Q, H).transpose(0, 3, 1, 2)        # [B,H,C,Q]
    Bc = Bm.reshape(Bsz, C, Q, N)
    Cc = Cm.reshape(Bsz, C, Q, N)

    A_cumsum = jnp.cumsum(ac, axis=-1)                        # [B,H,C,Q]
    # 1) intra-chunk (quadratic) term
    Lmat = jnp.exp(_segsum(ac))                               # [B,H,C,Q,Q]
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cc, Bc, Lmat.astype(x.dtype), xc)
    # 2) per-chunk final states
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)     # [B,H,C,Q]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        Bc, decay_states.astype(x.dtype), xc)  # [B,C,H,P,N]
    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(A_cumsum[..., -1])                  # [B,H,C]
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), x.dtype)

    def step(h, inp):
        st, dec = inp                                         # st: [B,H,P,N], dec: [B,H]
        h_new = h * dec[..., None, None].astype(x.dtype) + st
        return h_new, h

    (h_final, prev_states) = lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # [B,C,H,P,N]
    # 4) inter-chunk output contribution
    state_decay = jnp.exp(A_cumsum)                           # [B,H,C,Q]
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       Cc, prev_states, state_decay.astype(x.dtype))
    y = (Y_diag + Y_off).reshape(Bsz, L, H, P)[:, :L_orig]
    return y, h_final


def apply_ssm(params, x, cfg: ModelConfig, cache=None):
    """Mamba-2 block. Training/prefill when cache is None (returns new cache
    holding final recurrent + conv state); decode when cache is given (S==1).
    """
    B, S, _ = x.shape
    d_inner, N, conv_dim = _dims(cfg)
    H, P = cfg.ssm_heads, cfg.ssm_head_dim

    h_in = rmsnorm(params["ln"], x, cfg.norm_eps)
    proj = h_in @ params["in_proj"]
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)
    xBC = jnp.concatenate([xs, Bm, Cm], axis=-1)

    A = -jnp.exp(params["A_log"])                              # [H], negative
    Wc = params["conv_w"].shape[0]

    if cache is None:
        conv_out = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
        xh = xs.reshape(B, S, H, P)
        y, h_final = ssd_chunked(xh, dtv.astype(x.dtype), A.astype(x.dtype),
                                 Bm, Cm, min(cfg.ssm_chunk, S))
        new_cache = {"h": h_final, "conv": xBC[:, S - (Wc - 1):, :] if S >= Wc - 1
                     else jnp.pad(xBC, ((0, 0), (Wc - 1 - S, 0), (0, 0)))}
    else:
        # decode: S == 1
        conv_buf = jnp.concatenate([cache["conv"], xBC], axis=1)   # [B, Wc, C]
        conv_out = jnp.einsum("bwc,wc->bc", conv_buf, params["conv_w"])
        conv_out = jax.nn.silu(conv_out + params["conv_b"])[:, None, :]
        xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # [B,H]
        xh = xs.reshape(B, H, P)
        dec = jnp.exp(dtv * A).astype(x.dtype)                     # [B,H]
        h = cache["h"] * dec[..., None, None]
        h = h + jnp.einsum("bh,bn,bhp->bhpn", dtv.astype(x.dtype), Bm[:, 0], xh)
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h).reshape(B, 1, H, P)
        h_final = h
        new_cache = {"h": h_final, "conv": conv_buf[:, 1:, :]}

    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xs.reshape(B, S, H, P)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(params["out_ln"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    d_inner, N, conv_dim = _dims(cfg)
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, N), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }
