from repro.data.synthetic import (make_fed_batch_fn, make_model_batch,  # noqa: F401
                                  dirichlet_partition)
