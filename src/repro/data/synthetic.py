"""Synthetic data pipeline.

No datasets ship offline, so the pipeline generates structured synthetic
streams with **controllable client heterogeneity** — the quantity the paper's
assumptions (ζ bounds, Assumption 5/6) are about:

* token streams: each client samples from its own unigram distribution drawn
  from a Dirichlet over the vocabulary (lower concentration → more
  heterogeneous clients);
* audio frames / vision patches: client-specific Gaussian feature shifts;
* classification data: Dirichlet label partition (standard FL benchmark
  protocol).

Everything is pure-jax so batch generation can live inside jitted steps or be
lowered as ShapeDtypeStruct inputs for the dry-run.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import InputShape, ModelConfig


def _client_unigram_logits(key, num_clients: int, vocab: int, alpha: float):
    """Per-client unigram logits from a Dirichlet(alpha) prior (bucketised to
    keep memory bounded for 256k vocabs)."""
    buckets = min(vocab, 1024)
    g = jax.random.gamma(key, alpha, (num_clients, buckets))
    probs = g / jnp.sum(g, axis=1, keepdims=True)
    return jnp.log(probs + 1e-9), buckets


def make_fed_batch_fn(cfg: ModelConfig, *, num_clients: int, per_client: int,
                      seq_len: int, hetero_alpha: float = 0.5, seed: int = 0):
    """Returns ``batch_fn(key) -> {"train": model_batch, "val": model_batch}``
    with leading client axis M on every leaf."""
    base = jax.random.PRNGKey(seed)
    logits, buckets = _client_unigram_logits(base, num_clients, cfg.vocab_size, hetero_alpha)
    bucket_size = max(cfg.vocab_size // buckets, 1)

    def _tokens(key):
        ks = jax.random.split(key, num_clients)

        def one(k, lg):
            kb, ko = jax.random.split(k)
            b = jax.random.categorical(kb, lg, shape=(per_client, seq_len))
            off = jax.random.randint(ko, (per_client, seq_len), 0, bucket_size)
            return jnp.minimum(b * bucket_size + off, cfg.vocab_size - 1).astype(jnp.int32)

        return jax.vmap(one)(ks, logits)

    def _frames(key):
        ks = jax.random.split(key, num_clients)

        def one(k, m):
            shift = 0.3 * jax.random.normal(jax.random.fold_in(base, m),
                                            (cfg.frontend_dim,))
            return (0.5 * jax.random.normal(k, (per_client, seq_len, cfg.frontend_dim))
                    + shift).astype(jnp.bfloat16)

        return jax.vmap(one)(ks, jnp.arange(num_clients))

    def _patches(key):
        ks = jax.random.split(key, num_clients)
        return jax.vmap(lambda k: (0.5 * jax.random.normal(
            k, (per_client, cfg.num_patches, cfg.frontend_dim))).astype(jnp.bfloat16))(ks)

    def one_stream(key):
        if cfg.family == "audio":
            kf, kl = jax.random.split(key)
            frames = _frames(kf)
            labels = jax.random.randint(kl, (num_clients, per_client, seq_len),
                                        0, cfg.vocab_size).astype(jnp.int32)
            return {"frames": frames, "labels": labels}
        toks = _tokens(key)
        batch = {"tokens": toks,
                 "labels": jnp.concatenate([toks[..., 1:], toks[..., :1]], -1)}
        if cfg.family == "vlm":
            batch["patches"] = _patches(jax.random.fold_in(key, 7))
        return batch

    def batch_fn(key):
        kt, kv = jax.random.split(key)
        return {"train": one_stream(kt), "val": one_stream(kv)}

    return batch_fn


def make_model_batch(cfg: ModelConfig, shape: InputShape, *, num_clients: int = 0,
                     dtype=jnp.bfloat16) -> Dict:
    """Concrete (materialised) batch for smoke tests; mirrors
    ``launch.dryrun.input_specs`` shapes."""
    B, S = shape.global_batch, shape.seq_len
    lead = (num_clients, B // num_clients) if num_clients else (B,)
    key = jax.random.PRNGKey(0)
    if cfg.family == "audio":
        return {"frames": jnp.zeros(lead + (S, cfg.frontend_dim), dtype),
                "labels": jnp.zeros(lead + (S,), jnp.int32)}
    batch = {"tokens": jax.random.randint(key, lead + (S,), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, lead + (S,), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros(lead + (cfg.num_patches, cfg.frontend_dim), dtype)
    return batch


def dirichlet_partition(key, labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5):
    """Standard Dirichlet non-iid label partition. Returns a list of index
    arrays, one per client."""
    labels = np.asarray(labels)
    classes = int(labels.max()) + 1
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))  # analysis: ignore[L302] key-seeded
    idx_by_class = [np.where(labels == c)[0] for c in range(classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx = [[] for _ in range(num_clients)]
    for c in range(classes):
        props = rng.dirichlet([alpha] * num_clients)
        splits = (np.cumsum(props) * len(idx_by_class[c])).astype(int)[:-1]
        for m, part in enumerate(np.split(idx_by_class[c], splits)):
            client_idx[m].append(part)
    return [np.concatenate(parts) for parts in client_idx]
