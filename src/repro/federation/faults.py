"""Fault engine: deterministic failure injection, robustness policy, rollback.

The reproduction's trajectories assumed every client update is finite,
honest, and delivered — one NaN row entering the all-reduced mean silently
poisons all clients.  Real federated deployments (the non-IID analyses of
arXiv:2302.05412 and the compressed-hypergradient path of arXiv:2302.04969)
face exactly those failures, and the STORM u-sequence is the most
numerically fragile path in the system.  This module gives the stack a
declarative fault model and the guard machinery around it:

* :class:`FaultSpec` — **what goes wrong**: per-round, per-client dropout
  (the client computes but never delivers), NaN/Inf-corrupted updates, and
  scaled ("byzantine") updates.  Compiled by :func:`make_faults` into masks
  that are a *pure function* of ``fold_in(fold_in(seed, round), retry)`` —
  resume-exact like participation, and re-drawable on rollback retries (the
  ``retry`` counter rides :class:`~repro.optim.sequences.FlatState` and is
  bumped by the rollback guard, so a retried round sees a fresh draw).

  Injection compiles down to (a) an extra per-round client *keep* mask
  multiplied into the participation mask/weights (dropout == the client
  missed the round), and (b) a corruption transform applied to client rows
  of the communicated segments **inside the reduction**
  (``flat.client_mean_masked(..., corrupt=)``) — corruption models what the
  client *sends*, so private sections, cadence-skipped sections and
  non-participant rows are never touched, and with guards off the corrupted
  mean demonstrably poisons every participant (the failure this PR exists
  to catch).

* :class:`RobustnessSpec` — **what the server does about it**: a per-client
  health screen (non-finite check + update-norm z-score over the round's
  participants, ``flat.health_mask``) whose mask composes with the
  participation weights; a robust aggregator (plain participants-only
  ``mean``, per-client norm ``clip`` before the mean, or coordinate-wise
  ``trim`` med mean); and the trainer-level rollback policy
  (``spike_factor`` / ``retry_budget`` / ``ring``) driven by
  :class:`RollbackGuard`.  Screened-out participants are *recovered*: they
  receive the robust aggregate instead of keeping their corrupted row, so a
  faulted client rejoins the consensus iterate at the next round boundary.

* :class:`RollbackGuard` — **last-known-good rollback**: the train loop
  snapshots (step, state, key, loss) at healthy round boundaries into a
  small ring; a non-finite eval loss or a spike beyond
  ``spike_factor x last-good`` rolls the run back to the newest good
  snapshot, folds the retry counter into the batch key (and into the fault
  draws via ``FlatState.retry``), and counts down ``retry_budget`` before
  failing loudly with :class:`RollbackError`.

Both specs are plain hashable NamedTuples, ride
:class:`repro.api.Experiment` (``experiment.faults`` /
``experiment.robustness``), round-trip through JSON and are
``edit()``-sweepable.  With both absent every trajectory is bit-identical
to the unguarded stack.
"""
from __future__ import annotations

import collections
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

AGGREGATORS = ("mean", "clip", "trim")


class FaultSpec(NamedTuple):
    """Declarative per-round client fault process (hashable, JSON-friendly).

    Each rate is an independent per-(round, client) Bernoulli probability;
    draws are pure functions of ``fold_in(fold_in(seed, round), retry)``.
    A dropped client sends nothing (it is masked out like a non-participant
    for the round); a corrupted client's *communicated* rows are replaced
    with NaN; a byzantine client's are scaled by ``byzantine_scale``.
    ``start_round`` delays injection (clean warmup rounds).
    """
    dropout_rate: float = 0.0
    nan_rate: float = 0.0
    byzantine_rate: float = 0.0
    byzantine_scale: float = 10.0
    seed: int = 0
    start_round: int = 0


class RobustnessSpec(NamedTuple):
    """Declarative guard policy (hashable, JSON-friendly).

    ``screen`` enables the per-client health mask (non-finite check +
    update-norm z-score with threshold ``z_thresh`` over the round's
    participants; ``z_thresh = 0`` keeps the finite check only).
    ``aggregator`` picks the reduction: ``"mean"`` (participants-only
    weighted mean — bit-identical to the unguarded mean when every client is
    healthy), ``"clip"`` (per-client norm clipping to ``clip_factor`` x the
    healthy-mean norm before the mean; local under ``shard_map``) or
    ``"trim"`` (coordinate-wise ``trim_frac``-trimmed mean; gather-based on
    the sharded path — see ``optim.flat``).  ``spike_factor`` /
    ``retry_budget`` / ``ring`` parameterize :class:`RollbackGuard`.
    """
    aggregator: str = "mean"
    screen: bool = True
    z_thresh: float = 3.0
    clip_factor: float = 2.0
    trim_frac: float = 0.2
    spike_factor: float = 10.0
    retry_budget: int = 3
    ring: int = 2


class Faults(NamedTuple):
    """A compiled :class:`FaultSpec`: ``round_masks(round, retry)`` returns
    the round's ``(keep, nan, byz)`` — each [M] f32 in {0, 1}, jit-traceable
    in both indices and independent across (round, retry) pairs."""
    spec: FaultSpec
    num_clients: int
    round_masks: Any


def make_faults(spec: FaultSpec | None, num_clients: int) -> Faults | None:
    """Compile ``spec`` for ``num_clients`` clients (None passes through —
    the no-faults fast path keeps the unguarded code exact)."""
    if spec is None:
        return None
    for name in ("dropout_rate", "nan_rate", "byzantine_rate"):
        r = getattr(spec, name)
        if not 0.0 <= r <= 1.0:
            raise ValueError(f"FaultSpec.{name}={r} must be in [0, 1]")
    M = num_clients
    key0 = jax.random.PRNGKey(spec.seed)

    def round_masks(round_idx, retry=0):
        k = jax.random.fold_in(key0, jnp.asarray(round_idx, jnp.int32))
        k = jax.random.fold_in(k, jnp.asarray(retry, jnp.int32))
        u = jax.random.uniform(k, (3, M))
        active = (jnp.asarray(round_idx, jnp.int32)
                  >= spec.start_round).astype(jnp.float32)
        drop = (u[0] < spec.dropout_rate).astype(jnp.float32) * active
        keep = 1.0 - drop
        # a dropped client sends nothing, so it cannot also corrupt; a NaN
        # client's rows are already garbage, so byzantine scaling is moot
        nan = (u[1] < spec.nan_rate).astype(jnp.float32) * active * keep
        byz = ((u[2] < spec.byzantine_rate).astype(jnp.float32)
               * active * keep * (1.0 - nan))
        return keep, nan, byz

    return Faults(spec, M, round_masks)


def expected_fault_fraction(faults: Faults | None, num_rounds: int = 64,
                            retry: int = 0) -> dict:
    """Measured mean fault rates over the first ``num_rounds`` rounds —
    what the recorded fault process actually injects (benchmarks)."""
    if faults is None:
        return {"dropout": 0.0, "nan": 0.0, "byzantine": 0.0}
    keep, nan, byz = jax.vmap(
        lambda r: faults.round_masks(r, retry))(jnp.arange(num_rounds))
    return {"dropout": round(float(jnp.mean(1.0 - keep)), 4),  # analysis: ignore[L303] reporting
            "nan": round(float(jnp.mean(nan)), 4),  # analysis: ignore[L303] reporting
            "byzantine": round(float(jnp.mean(byz)), 4)}  # analysis: ignore[L303] reporting


# ---------------------------------------------------------------------------
# Rollback: last-known-good ring + retry budget
# ---------------------------------------------------------------------------

class RollbackError(RuntimeError):
    """The run cannot make progress: retry budget exhausted (or no good
    state to roll back to).  The message names the offending round."""


class RollbackGuard:
    """Host-side rollback driver for the train loop.

    At each healthy round boundary the loop calls :meth:`observe` with the
    eval loss; the guard either snapshots (returning ``None``) or — on a
    non-finite loss or a spike beyond ``spike_factor x`` the last good loss
    — rolls back, returning ``(step, state, key)`` to resume from.  The
    returned state carries the bumped retry counter (``FlatState.retry``,
    when the fault engine is attached) and the returned key has the retry
    folded in, so the retried rounds re-draw both their batches and their
    fault masks.  Raises :class:`RollbackError` when the budget runs out.
    """

    def __init__(self, spec: RobustnessSpec):
        if spec.retry_budget < 0:
            raise ValueError(f"retry_budget={spec.retry_budget} must be >= 0")
        self.spec = spec
        self._good = collections.deque(maxlen=max(int(spec.ring), 1))
        self.retries = 0            # total rollbacks taken (monotone)
        self.rollback_steps: list = []   # steps at which we rolled back

    def is_healthy(self, loss: float) -> bool:
        if not jnp.isfinite(jnp.asarray(loss)):
            return False
        if not self._good:
            return True
        return float(loss) <= self.spec.spike_factor * self._good[-1][3]

    def observe(self, step: int, state, key, loss: float):
        """Snapshot a healthy (step, state, key, loss) and return ``None``,
        or roll back and return the ``(step, state, key)`` to resume from."""
        if self.is_healthy(loss):
            self._good.append((int(step), state, key, float(loss)))
            return None
        return self._rollback(step, loss)

    def _rollback(self, step: int, loss: float):
        round_no = self.rollback_steps  # for the error message below
        if not self._good:
            raise RollbackError(
                f"eval loss {loss} at step {step} is unhealthy and no "
                f"known-good state exists to roll back to (the run was bad "
                f"from the start) — fix the spec, or relax "
                f"RobustnessSpec.spike_factor")
        if self.retries >= self.spec.retry_budget:
            raise RollbackError(
                f"eval loss {loss} at step {step} after exhausting the "
                f"retry budget ({self.spec.retry_budget}; rollbacks at "
                f"steps {round_no}) — the fault process is overwhelming "
                f"the guards; raise retry_budget, enable/strengthen the "
                f"health screen, or lower the fault rate")
        self.retries += 1
        self.rollback_steps.append(int(step))
        good_step, state, key, _ = self._good[-1]
        # fresh randomness for the retried rounds: fold the retry counter
        # into the batch key AND the fault draws (via the state's retry slot)
        key = jax.random.fold_in(key, self.retries)
        if hasattr(state, "retry") and not isinstance(state.retry, tuple):
            state = state._replace(
                retry=jnp.asarray(self.retries, jnp.int32))
        return good_step, state, key
