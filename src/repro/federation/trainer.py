"""Model-scale federated train steps (the production `train_step` that the
multi-pod dry-run lowers).

Every federated state tensor carries a leading client axis M. Under pjit the
axis is sharded over the mesh "data" axis (``client_sharded``) or left
replicated with the parameters FSDP-sharded instead (``client_replicated``,
for the memory-giant archs — see DESIGN.md §4). ``client_mean`` under
``lax.cond(step % I == 0)`` is the paper's communication round.

Memory discipline (what makes llama3-405b lowerable):

* FedBiO keeps **one** body-sized persistent tensor per client (x); the ν
  direction is transient.
* FedBiOAcc keeps two (x and its STORM momentum ν). The STORM correction
  needs the *previous* iterate — instead of storing a third body copy we
  evaluate the old-iterate oracle **before** applying the update, so XLA can
  free it (documented deviation: at communication steps the pre-averaging
  local iterate is used as the "old" point, exactly as Alg. 2 lines 10-12).

Fused STORM substrate (``fuse_storm=True`` on ``make_fedbioacc_train_step``):
the (x, y, u) trees and their three momenta are flattened ONCE at init into
contiguous per-dtype [M, N] buffers (``repro.optim.flat``); the per-step
9-pass ``jax.tree.map`` chain (partial momentum ×3, variable step ×3,
correction add ×3) collapses to ONE triple-sequence Pallas launch plus one
elementwise add, and each ``client_mean`` becomes one reduction per dtype
buffer instead of one per leaf. The train state is then a
``FlatFedBiOAccTrainState``; pytree views are materialized only at oracle
boundaries inside the step and via ``train_step.views(state)`` for
eval/checkpoint. Momenta live in f32 buffers regardless of the parameter
dtype — the unfused arithmetic promotes them the same way, and the STORM
correction g_new − g_old is a small difference bf16 would destroy. The
fused trajectory matches the unfused one to float rounding for f32 states
and to bf16 rounding for bf16 states (test-asserted in
tests/test_flat_substrate.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.model_problem import make_model_bilevel
from repro.core.tree_util import client_mean, client_mean_grouped, tree_zeros_like
from repro.models.registry import Model
from repro.optim import flat


class FedBiOTrainState(NamedTuple):
    x: Any               # [M, ...] body
    y: Any               # [M, ...] head (lower variable)
    u: Any               # [M, ...] Eq. (4) auxiliary
    step: jnp.ndarray


class FedBiOAccTrainState(NamedTuple):
    x: Any
    y: Any
    u: Any
    omega: Any           # y-momentum
    nu: Any              # x-momentum (body-sized)
    q: Any               # u-momentum
    step: jnp.ndarray


class FlatFedBiOAccTrainState(NamedTuple):
    """FedBiOAcc state on the flat-buffer substrate (``fuse_storm=True``).

    ``vars``/``mom`` are tuples of per-dtype [M, N] buffers holding the
    x|y|u (resp. ν|ω|q) sections, tile-padded per ``repro.optim.flat``.
    """
    vars: Any
    mom: Any
    step: jnp.ndarray


class FedAvgTrainState(NamedTuple):
    params: Any
    mom: Any
    step: jnp.ndarray


def _bcast(tree, m):
    return jax.tree.map(lambda v: jnp.broadcast_to(v[None], (m,) + v.shape), tree)


def _cond_mean(pred, tree):
    return lax.cond(pred, client_mean, lambda t: t, tree)


def _comm(cfg: FederatedConfig, step, tree):
    """Communication schedule: averaging every I steps; with
    ``hierarchy_period = k > 0`` only every k-th round crosses pod groups
    (pod-local grouped mean otherwise) — the beyond-paper hierarchical
    schedule for the multi-pod mesh (cross-pod traffic ÷ k)."""
    is_comm = (step + 1) % cfg.local_steps == 0
    if cfg.hierarchy_period <= 0:
        return _cond_mean(is_comm, tree)
    round_idx = (step + 1) // cfg.local_steps
    is_global = round_idx % cfg.hierarchy_period == 0

    def do_comm(t):
        return lax.cond(is_global, client_mean,
                        lambda tt: client_mean_grouped(tt, cfg.hierarchy_groups),
                        t)

    return lax.cond(is_comm, do_comm, lambda t: t, tree)


def _alpha(cfg: FederatedConfig, t):
    return cfg.alpha_delta / (cfg.alpha_u0 + t.astype(jnp.float32)) ** (1.0 / 3.0)


# ---------------------------------------------------------------------------
# FedBiO (Algorithm 1) at model scale
# ---------------------------------------------------------------------------

def make_fedbio_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           use_flash: bool = False, use_lru_kernel: bool = False,
                           fuse_oracles: bool = False):
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def init(key):
        p = model.init(key)
        x, y = p["body"], p["head"]
        return FedBiOTrainState(_bcast(x, M), _bcast(y, M),
                                _bcast(tree_zeros_like(y), M),
                                jnp.zeros((), jnp.int32))

    def local(x, y, u, batch):
        if fuse_oracles:
            omega, mu, p = hg.fused_oracles(g, f, x, y, u, batch)
            nu = mu
            u_new = jax.tree.map(lambda v, r: v - cfg.lr_u * r.astype(v.dtype),
                                 u, p)
        else:
            omega = hg.grad_y(g, x, y, batch)
            nu = hg.nu_direction(g, f, x, y, u, batch, batch)
            u_new = hg.u_step(g, f, x, y, u, batch, batch, cfg.lr_u)
        y_new = jax.tree.map(lambda v, o: v - cfg.lr_y * o.astype(v.dtype), y, omega)
        x_new = jax.tree.map(lambda v, o: v - cfg.lr_x * o.astype(v.dtype), x, nu)
        return x_new, y_new, u_new

    vlocal = jax.vmap(local)

    def train_step(state: FedBiOTrainState, batch):
        x, y, u = vlocal(state.x, state.y, state.u, batch)
        x = _comm(cfg, state.step, x)
        y = _comm(cfg, state.step, y)
        u = _comm(cfg, state.step, u)
        new = FedBiOTrainState(x, y, u, state.step + 1)
        # cheap progress metric: lower loss on the train stream of client 0
        return new, {"step": new.step}

    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc (Algorithm 2) at model scale
# ---------------------------------------------------------------------------

def make_fedbioacc_train_step(model: Model, cfg: FederatedConfig, *,
                              n_micro: int = 1, remat: bool = True,
                              use_flash: bool = False,
                              use_lru_kernel: bool = False,
                              fuse_storm: bool = False,
                              fuse_oracles: bool = False,
                              storm_block: int | None = None):
    """FedBiOAcc (Alg. 2) train step.

    ``fuse_oracles`` shares one forward-over-reverse linearization across the
    three oracle directions (see ``hypergrad.fused_oracles``).

    ``fuse_storm`` switches the state to the flat-buffer substrate: the init
    flattens (x, y, u) and the three momenta into per-dtype [M, N] buffers
    and the step advances all three STORM sequences with one triple-sequence
    Pallas launch + one add. The returned ``train_step`` then consumes and
    produces ``FlatFedBiOAccTrainState`` and exposes
    ``train_step.views(state) -> FedBiOAccTrainState`` (pytree views for
    eval/checkpoint) and ``train_step.spec`` (the buffer layout).
    ``storm_block`` overrides the kernel tile size (testing/small models).
    """
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def oracles(x, y, u, batch):
        if fuse_oracles:
            return hg.fused_oracles(g, f, x, y, u, batch)
        omega = hg.grad_y(g, x, y, batch)
        mu = hg.nu_direction(g, f, x, y, u, batch, batch)
        p = hg.u_residual(g, f, x, y, u, batch, batch)
        return omega, mu, p

    voracles = jax.vmap(oracles)

    def init_trees(key):
        p = model.init(key)
        x, y = _bcast(p["body"], M), _bcast(p["head"], M)
        u = _bcast(tree_zeros_like(p["head"]), M)
        return x, y, u

    if fuse_storm:
        return _make_fedbioacc_flat(model, cfg, voracles, init_trees,
                                    storm_block)

    def init(key):
        x, y, u = init_trees(key)
        return FedBiOAccTrainState(
            x, y, u, tree_zeros_like(y), tree_zeros_like(x), tree_zeros_like(u),
            jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOAccTrainState, batch):
        t = state.step
        a = _alpha(cfg, t)
        decay = 1.0 - cfg.c_nu * a * a     # shared c for the fused path
        # 1) old-iterate oracle FIRST (frees the old body afterwards)
        o_old, m_old, p_old = voracles(state.x, state.y, state.u, batch)
        # 2) partial momentum: m ← (1-cα²)(m − o_old)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, o_old)
        nu = jax.tree.map(lambda m, o: decay * (m - o), state.nu, m_old)
        q = jax.tree.map(lambda m, o: (1.0 - cfg.c_u * a * a) * (m - o),
                         state.q, p_old)
        # 3) variable update with the *entering* momenta (Alg. 2 line 4)
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        u = jax.tree.map(lambda v, m: v - (cfg.lr_u * a * m).astype(v.dtype),
                         state.u, state.q)
        x, y, u = _comm(cfg, t, x), _comm(cfg, t, y), _comm(cfg, t, u)
        # 4) new-iterate oracle, same batch (STORM correction)
        o_new, m_new, p_new = voracles(x, y, u, batch)
        omega = jax.tree.map(jnp.add, omega, o_new)
        nu = jax.tree.map(jnp.add, nu, m_new)
        q = jax.tree.map(jnp.add, q, p_new)
        omega = _comm(cfg, t, omega)
        nu = _comm(cfg, t, nu)
        q = _comm(cfg, t, q)
        new = FedBiOAccTrainState(x, y, u, omega, nu, q, t + 1)
        return new, {"step": new.step}

    return init, train_step


def _make_fedbioacc_flat(model: Model, cfg: FederatedConfig, voracles,
                         init_trees, storm_block):
    """fuse_storm=True path: flat-buffer state + triple-sequence kernel."""
    tmpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # u shares the head's structure/dtypes (tree_zeros_like at init)
    spec = flat.make_spec(
        {"x": tmpl["body"], "y": tmpl["head"], "u": tmpl["head"]},
        sections=("x", "y", "u"),
        block=storm_block if storm_block else flat.BLOCK)

    def init(key):
        x, y, u = init_trees(key)
        vars_b = flat.flatten_tree(spec, {"x": x, "y": y, "u": u},
                                   batch_dims=1)
        # momenta live in f32 buffers regardless of the variable dtype —
        # the unfused path promotes them the same way (f32 schedule scalar ×
        # momentum), and the STORM correction g_new − g_old is a small
        # difference bf16 would largely destroy
        mom_b = tuple(jnp.zeros(b.shape, jnp.float32) for b in vars_b)
        return FlatFedBiOAccTrainState(vars_b, mom_b,
                                       jnp.zeros((), jnp.int32))

    def train_step(state: FlatFedBiOAccTrainState, batch):
        t = state.step
        a = _alpha(cfg, t)
        # 1) old-iterate oracle on transient pytree views
        vt = flat.unflatten_tree(spec, state.vars)
        o_old, m_old, p_old = voracles(vt["x"], vt["y"], vt["u"], batch)
        g_old = flat.flatten_tree(spec, {"x": m_old, "y": o_old, "u": p_old},
                                  batch_dims=1, dtype=jnp.float32)
        # 2+3) partial momentum + variable step: ONE fused launch per dtype
        # (scalar order matches the unfused expressions bit-for-bit)
        lrs = (cfg.lr_x * a, cfg.lr_y * a, cfg.lr_u * a)
        decays = (1.0 - cfg.c_nu * a * a, 1.0 - cfg.c_omega * a * a,
                  1.0 - cfg.c_u * a * a)
        vars_b, mom_b = flat.storm_partial_step(spec, state.vars, state.mom,
                                                g_old, lrs, decays)
        vars_b = _comm(cfg, t, vars_b)      # one all-reduce per dtype buffer
        # 4) new-iterate oracle, same batch; STORM correction is one add
        vt2 = flat.unflatten_tree(spec, vars_b)
        o_new, m_new, p_new = voracles(vt2["x"], vt2["y"], vt2["u"], batch)
        g_new = flat.flatten_tree(spec, {"x": m_new, "y": o_new, "u": p_new},
                                  batch_dims=1, dtype=jnp.float32)
        mom_b = flat.buffers_add(mom_b, g_new)
        mom_b = _comm(cfg, t, mom_b)
        new = FlatFedBiOAccTrainState(vars_b, mom_b, t + 1)
        return new, {"step": new.step}

    def views(state: FlatFedBiOAccTrainState) -> FedBiOAccTrainState:
        vt = flat.unflatten_tree(spec, state.vars)
        mt = flat.unflatten_tree(spec, state.mom)
        return FedBiOAccTrainState(vt["x"], vt["y"], vt["u"], mt["y"],
                                   mt["x"], mt["u"], state.step)

    train_step.spec = spec
    train_step.views = views
    init.spec = spec
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiO with local lower level (Algorithm 3) at model scale — Eq. (5):
# per-client PRIVATE heads (personalisation); only the body is averaged.
# ---------------------------------------------------------------------------

def make_fedbio_local_train_step(model: Model, cfg: FederatedConfig, *,
                                 n_micro: int = 1, remat: bool = True,
                                 use_flash: bool = False,
                                 use_lru_kernel: bool = False):
    """Each client solves its own lower problem y^(m) (its private head); the
    unbiased local hyper-gradient is estimated with the truncated Neumann
    series (Eq. 6, Q = cfg.neumann_q HVPs); only x (body) is communicated."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def init(key):
        keys = jax.random.split(key, M + 1)
        p = model.init(keys[0])
        # heads start from per-client inits (they are never synchronised)
        heads = jax.tree.map(
            lambda *vs: jnp.stack(vs),
            *[model.init(k)["head"] for k in keys[1:]])
        return FedBiOTrainState(_bcast(p["body"], M), heads,
                                _bcast(tree_zeros_like(p["head"]), M),
                                jnp.zeros((), jnp.int32))

    def local(x, y, batch):
        omega = hg.grad_y(g, x, y, batch)
        nu = hg.neumann_hypergrad(g, f, x, y, batch, batch,
                                  cfg.neumann_q, cfg.neumann_tau)
        y_new = jax.tree.map(lambda v, o: v - cfg.lr_y * o.astype(v.dtype), y, omega)
        x_new = jax.tree.map(lambda v, o: v - cfg.lr_x * o.astype(v.dtype), x, nu)
        return x_new, y_new

    vlocal = jax.vmap(local)

    def train_step(state: FedBiOTrainState, batch):
        x, y = vlocal(state.x, state.y, batch)
        is_comm = (state.step + 1) % cfg.local_steps == 0
        x = _cond_mean(is_comm, x)             # ONLY the body is averaged
        new = FedBiOTrainState(x, y, state.u, state.step + 1)
        return new, {"step": new.step}

    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc with local lower level (Algorithm 4) at model scale
# ---------------------------------------------------------------------------

class FedBiOAccLocalTrainState(NamedTuple):
    x: Any
    y: Any               # private per-client heads
    omega: Any           # y-momentum (private)
    nu: Any              # x-momentum (averaged with x)
    step: jnp.ndarray


def make_fedbioacc_local_train_step(model: Model, cfg: FederatedConfig, *,
                                    n_micro: int = 1, remat: bool = True,
                                    use_flash: bool = False,
                                    use_lru_kernel: bool = False):
    """Algorithm 4: STORM momenta on (y, Φ); only x and ν are communicated."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def oracles(x, y, batch):
        omega = hg.grad_y(g, x, y, batch)
        nu = hg.neumann_hypergrad(g, f, x, y, batch, batch,
                                  cfg.neumann_q, cfg.neumann_tau)
        return omega, nu

    voracles = jax.vmap(oracles)

    def init(key):
        keys = jax.random.split(key, M + 1)
        p = model.init(keys[0])
        heads = jax.tree.map(
            lambda *vs: jnp.stack(vs),
            *[model.init(k)["head"] for k in keys[1:]])
        x = _bcast(p["body"], M)
        return FedBiOAccLocalTrainState(
            x, heads, tree_zeros_like(heads), tree_zeros_like(x),
            jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOAccLocalTrainState, batch):
        t = state.step
        a = _alpha(cfg, t)
        o_old, n_old = voracles(state.x, state.y, batch)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, o_old)
        nu = jax.tree.map(lambda m, o: (1.0 - cfg.c_nu * a * a) * (m - o),
                          state.nu, n_old)
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        is_comm = (t + 1) % cfg.local_steps == 0
        x = _cond_mean(is_comm, x)              # x averaged, y private
        o_new, n_new = voracles(x, y, batch)
        omega = jax.tree.map(jnp.add, omega, o_new)
        nu = jax.tree.map(jnp.add, nu, n_new)
        nu = _cond_mean(is_comm, nu)            # ν averaged too (Alg. 4 l.14)
        new = FedBiOAccLocalTrainState(x, y, omega, nu, t + 1)
        return new, {"step": new.step}

    return init, train_step


# ---------------------------------------------------------------------------
# FedAvg (single-level local-SGD baseline substrate)
# ---------------------------------------------------------------------------

def make_fedavg_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           momentum: float = 0.9, use_flash: bool = False,
                           use_lru_kernel: bool = False):
    from repro.core.model_problem import _microbatch_mean

    def loss_fn(params, batch):
        def one(mb):
            l, _ = model.loss(params, mb, remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
            return l.astype(jnp.float32)
        return _microbatch_mean(one, batch, n_micro)

    M = cfg.num_clients

    def init(key):
        p = model.init(key)
        return FedAvgTrainState(_bcast(p, M), _bcast(tree_zeros_like(p), M),
                                jnp.zeros((), jnp.int32))

    vgrad = jax.vmap(jax.grad(loss_fn))

    def train_step(state: FedAvgTrainState, batch):
        grads = vgrad(state.params, batch["train"])
        mom = jax.tree.map(lambda m, gr: momentum * m + gr.astype(m.dtype),
                           state.mom, grads)
        params = jax.tree.map(lambda p, m: p - (cfg.lr_x * m).astype(p.dtype),
                              state.params, mom)
        is_comm = (state.step + 1) % cfg.local_steps == 0
        params = _cond_mean(is_comm, params)
        mom = _cond_mean(is_comm, mom)
        new = FedAvgTrainState(params, mom, state.step + 1)
        return new, {"step": new.step}

    return init, train_step
