"""Model-scale federated train steps (the production `train_step` that the
multi-pod dry-run lowers).

Every federated state tensor carries a leading client axis M. Under pjit the
axis is sharded over the mesh "data" axis (``client_sharded``) or left
replicated with the parameters FSDP-sharded instead (``client_replicated``,
for the memory-giant archs — see DESIGN.md §4).

Every factory here is a thin declaration over the **sequence-spec engine**
(``repro.optim.sequences``): the algorithm is a tuple of named sequences —
(variable section, momentum, lr key, STORM-constant key, comm policy) — and
the per-sequence communication policies drive BOTH code paths:

* unfused (default): per-leaf tree-map updates, communication through
  ``sequences.comm_tree`` — so ``cfg.hierarchy_period`` is honored uniformly
  by all five algorithms (fedbio_local/fedbioacc_local/fedavg previously
  bypassed the hierarchical schedule);
* ``fuse_storm=True``: the state lives on the flat-buffer substrate
  (``repro.optim.flat``) as per-dtype [M, N] buffers; each step is one fused
  triple-sequence Pallas launch (+ one correction add for the STORM kind)
  and each communication is one *section-masked* reduction per dtype buffer
  — PRIVATE sections (the local-lower y/ω) are sliced around the reduction
  and pass through bit-identical, so private state provably never enters an
  all-reduce.  The returned ``train_step`` then consumes/produces a
  ``sequences.FlatState`` and exposes ``train_step.views(state)`` (legacy
  pytree state for eval/checkpoint) and ``train_step.spec`` (the layout).

Every factory accepts ``participation=`` (a
``repro.federation.participation.ParticipationSpec``): per-round client
sampling (uniform/weighted/trace-driven availability) threads the mask
through BOTH paths — unfused steps freeze non-participants bit-exact with a
``where`` select and average participants only
(``tree_util.client_mean_weighted``); fused steps gate the Pallas launches
(masked lr, pinned decay) and run participation-weighted masked reductions.
The compiled engine is recorded on ``train_step.participation`` (its
``.spec`` is the declarative scenario).  Staleness-discounted reductions
(α^staleness aging of returning clients) additionally need the per-client
counters on ``FlatState.stale`` and are therefore fused-path only.

Every factory also accepts ``mesh=`` (a jax ``Mesh`` with ("data", "model")
axes, or a prebuilt ``optim.flat.ShardCtx`` for the non-default knobs —
``use_scatter`` picks the ``psum_scatter``+``all_gather`` all-reduce
decomposition) and ``overlap=`` — fused-path only: the flat [M, N] buffers
are partitioned client-axis-over-"data" / packed-axis-over-"model"
(``sharding.rules.flat_state_specs``), the fused launches and masked
reductions run under ``shard_map`` with the participant mean lowered to true
``lax.psum``/``psum_scatter`` collectives, and ``overlap=True`` issues the
variable-section reduction concurrently with the new-iterate oracle (see
``repro.optim.sequences``).  ``train_step.shardings(state)`` returns the
``NamedSharding`` pytree for jit boundaries.

Memory discipline (what makes llama3-405b lowerable): the STORM correction
needs the *previous* iterate — instead of storing another body copy we
evaluate the old-iterate oracle **before** applying the update, so XLA can
free it (documented deviation: at communication steps the pre-averaging
local iterate is used as the "old" point, exactly as Alg. 2 lines 10-12).
Momenta live in f32 buffers regardless of the parameter dtype — the unfused
arithmetic promotes them the same way, and the STORM correction
g_new − g_old is a small difference bf16 would destroy.  Fused trajectories
match unfused ones to float rounding (test-asserted in
tests/test_flat_substrate.py and tests/test_sequences.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.model_problem import make_model_bilevel
from repro.core.tree_util import tree_zeros_like
from repro.federation.participation import (Participation, ParticipationSpec,
                                            make_participation)
from repro.models.registry import Model
from repro.optim import sequences as seqs
from repro.optim.sequences import FlatState


class FedBiOTrainState(NamedTuple):
    x: Any               # [M, ...] body
    y: Any               # [M, ...] head (lower variable)
    u: Any               # [M, ...] Eq. (4) auxiliary
    step: jnp.ndarray


class FedBiOAccTrainState(NamedTuple):
    x: Any
    y: Any
    u: Any
    omega: Any           # y-momentum
    nu: Any              # x-momentum (body-sized)
    q: Any               # u-momentum
    step: jnp.ndarray


class FedBiOAccLocalTrainState(NamedTuple):
    x: Any
    y: Any               # private per-client heads
    omega: Any           # y-momentum (private)
    nu: Any              # x-momentum (averaged with x)
    step: jnp.ndarray


class FedAvgTrainState(NamedTuple):
    params: Any
    mom: Any
    step: jnp.ndarray


# Back-compat alias: the fuse_storm=True state of every algorithm is the
# engine's FlatState (vars/mom buffer tuples + step).
FlatFedBiOAccTrainState = FlatState


def _bcast(tree, m):
    return jax.tree.map(lambda v: jnp.broadcast_to(v[None], (m,) + v.shape), tree)


def _sgd(v, g, lr):
    return jax.tree.map(lambda a, b: a - lr * b.astype(a.dtype), v, g)


def _comm_seqs(cfg, step, aspec, trees: dict, weights=None):
    """Communicate trees keyed by SECTION name under the sections' policies
    (momenta are passed under their sequence's section too — e.g. ν under
    "x"); returns the same keys so pairings stay structural.  ``weights``:
    per-client participation weights [M] (participants-only mean)."""
    by_sec = {q.section: q for q in aspec.sequences}
    return {name: seqs.comm_tree(cfg, step, t, by_sec[name].comm,
                                 weights=weights,
                                 comm_every=by_sec[name].comm_every)
            for name, t in trees.items()}


def _freeze(mask, new, old):
    """Bit-exact participation freeze for the unfused tree paths:
    non-participant rows keep their entering value (``jnp.where`` selects
    the branch verbatim; all-ones masks select ``new`` everywhere)."""
    if mask is None:
        return new

    def one(n, o):
        col = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(col > 0, n, o)

    return jax.tree.map(one, new, old)


def _participation_setup(cfg: FederatedConfig, aspec,
                         participation: ParticipationSpec | None,
                         fuse_storm: bool):
    """Compile the participation spec and return (part, round_ctx) — the
    unfused paths derive (mask, weights) per step from ``round_ctx``.
    Staleness discounting needs the engine's per-client counters
    (``FlatState.stale``), so it is fused-path only."""
    part = make_participation(participation, cfg.num_clients)
    if part is not None and not fuse_storm:
        alphas = seqs.effective_staleness(aspec, part)
        if any(a != 1.0 for a in alphas):
            raise NotImplementedError(
                "staleness discounting (stale_discount/Sequence.staleness != "
                "1) requires the fused engine — pass fuse_storm=True")

    def round_ctx(step):
        if part is None:
            return None, None
        return part.round_weights(step // cfg.local_steps)

    return part, round_ctx


def _private_heads_init(model: Model, key, m: int):
    """Per-client head inits for the local-lower algorithms (the private
    lower variables are never synchronised, so they must not start equal)."""
    keys = jax.random.split(key, m + 1)
    p = model.init(keys[0])
    heads = jax.tree.map(lambda *vs: jnp.stack(vs),
                         *[model.init(k)["head"] for k in keys[1:]])
    return p, heads


def _global_lower_setup(model: Model, cfg: FederatedConfig, f, g,
                        fuse_oracles: bool):
    """(voracle, templates, init_trees) shared by fedbio/fedbioacc: the
    three global-lower oracle directions (μ, ω, u-residual p) keyed by
    section, x|y|u section templates, and the broadcast client init."""
    M = cfg.num_clients

    def oracle(v, batch):
        x, y, u = v["x"], v["y"], v["u"]
        if fuse_oracles:
            omega, mu, p = hg.fused_oracles(g, f, x, y, u, batch)
        else:
            omega = hg.grad_y(g, x, y, batch)
            mu = hg.nu_direction(g, f, x, y, u, batch, batch)
            p = hg.u_residual(g, f, x, y, u, batch, batch)
        return {"x": mu, "y": omega, "u": p}

    tmpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    templates = {"x": tmpl["body"], "y": tmpl["head"], "u": tmpl["head"]}

    def init_trees(key):
        p = model.init(key)
        return {"x": _bcast(p["body"], M), "y": _bcast(p["head"], M),
                "u": _bcast(tree_zeros_like(p["head"]), M)}

    return jax.vmap(oracle), templates, init_trees


def _local_lower_setup(model: Model, cfg: FederatedConfig, f, g,
                       fuse_oracles: bool):
    """(voracle, templates, init_trees) shared by the local-lower variants:
    the (Φ, ω) oracle pair keyed by section, x|y templates, and the
    broadcast-body / private-heads client init."""
    M = cfg.num_clients

    def oracle(v, batch):
        x, y = v["x"], v["y"]
        if fuse_oracles:
            omega, nu = hg.fused_local_oracles(g, f, x, y, batch,
                                               cfg.neumann_q, cfg.neumann_tau)
        else:
            omega = hg.grad_y(g, x, y, batch)
            nu = hg.neumann_hypergrad(g, f, x, y, batch, batch,
                                      cfg.neumann_q, cfg.neumann_tau)
        return {"x": nu, "y": omega}

    tmpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    templates = {"x": tmpl["body"], "y": tmpl["head"]}

    def init_trees(key):
        p, heads = _private_heads_init(model, key, M)
        return {"x": _bcast(p["body"], M), "y": heads}

    return jax.vmap(oracle), templates, init_trees


def _shard_setup(mesh, overlap: bool, fuse_storm: bool):
    """Compile the mesh knob into a :class:`flat.ShardCtx` (None without a
    mesh).  ``mesh`` may also be a prebuilt :class:`flat.ShardCtx` — the way
    to reach the non-default knobs (``use_scatter``, custom axis names).
    The sharded substrate and the overlap schedule live on the fused engine
    only — reject the unfused tree paths loudly."""
    from repro.optim import flat as _flat
    if (mesh is not None or overlap) and not fuse_storm:
        raise ValueError(
            "mesh=/overlap= require fuse_storm=True — the sharded flat "
            "substrate and the comm/compute overlap schedule are features "
            "of the fused sequence-spec engine")
    if mesh is None:
        return None
    if isinstance(mesh, _flat.ShardCtx):
        return mesh
    return _flat.make_shard_ctx(mesh)


def _make_flat_pair(cfg: FederatedConfig, aspec, templates, voracle,
                    init_trees, storm_block, to_state,
                    part: Participation | None = None,
                    shard=None, overlap: bool = False):
    """fuse_storm=True path shared by all factories: compile the sequence
    spec into the flat-substrate engine and wrap it as (init, train_step)."""
    engine = seqs.make_engine(cfg, aspec, templates, voracle,
                              block=storm_block, participation=part,
                              shard=shard, overlap=overlap)

    def init(key):
        return engine.init_state(init_trees(key))

    def train_step(state: FlatState, batch):
        new = engine.step(state, batch)
        return new, {"step": new.step}

    def views(state: FlatState):
        vt, mt = engine.views(state)
        return to_state(vt, mt, state.step)

    for fn in (init, train_step):
        fn.spec = engine.spec
        fn.views = views
        fn.participation = part
        fn.shardings = engine.shardings
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiO (Algorithm 1) at model scale
# ---------------------------------------------------------------------------

def make_fedbio_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           use_flash: bool = False, use_lru_kernel: bool = False,
                           fuse_oracles: bool = False,
                           fuse_storm: bool = False,
                           storm_block: int | None = None,
                           participation: ParticipationSpec | None = None,
                           mesh=None, overlap: bool = False):
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = seqs.SPECS["fedbio"]
    voracle, templates, init_trees = _global_lower_setup(model, cfg, f, g,
                                                         fuse_oracles)
    part, round_ctx = _participation_setup(cfg, aspec, participation,
                                           fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedBiOTrainState(vt["x"], vt["y"], vt["u"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap)

    def init(key):
        tr = init_trees(key)
        return FedBiOTrainState(tr["x"], tr["y"], tr["u"],
                                jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOTrainState, batch):
        mask, w = round_ctx(state.step)
        gd = voracle({"x": state.x, "y": state.y, "u": state.u}, batch)
        x = _freeze(mask, _sgd(state.x, gd["x"], cfg.lr_x), state.x)
        y = _freeze(mask, _sgd(state.y, gd["y"], cfg.lr_y), state.y)
        u = _freeze(mask, _sgd(state.u, gd["u"], cfg.lr_u), state.u)
        cd = _comm_seqs(cfg, state.step, aspec, {"x": x, "y": y, "u": u},
                        weights=w)
        new = FedBiOTrainState(cd["x"], cd["y"], cd["u"], state.step + 1)
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc (Algorithm 2) at model scale
# ---------------------------------------------------------------------------

def make_fedbioacc_train_step(model: Model, cfg: FederatedConfig, *,
                              n_micro: int = 1, remat: bool = True,
                              use_flash: bool = False,
                              use_lru_kernel: bool = False,
                              fuse_storm: bool = False,
                              fuse_oracles: bool = False,
                              storm_block: int | None = None,
                              participation: ParticipationSpec | None = None,
                              mesh=None, overlap: bool = False):
    """FedBiOAcc (Alg. 2) train step.

    ``fuse_oracles`` shares one forward-over-reverse linearization across the
    three oracle directions (see ``hypergrad.fused_oracles``).  ``fuse_storm``
    switches to the flat-substrate engine (see the module docstring);
    ``storm_block`` overrides the kernel tile size (testing/small models).
    ``participation`` samples m ≪ M clients per round (see the module
    docstring) — the spec is recorded on ``train_step.participation``.
    ``mesh`` shards the flat substrate over the mesh ("data", "model") axes
    with real ``psum`` collectives under ``shard_map``; ``overlap`` enables
    the comm/compute overlap schedule (both need ``fuse_storm=True``).
    """
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = seqs.SPECS["fedbioacc"]
    voracle, templates, init_trees = _global_lower_setup(model, cfg, f, g,
                                                         fuse_oracles)
    part, round_ctx = _participation_setup(cfg, aspec, participation,
                                           fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedBiOAccTrainState(vt["x"], vt["y"], vt["u"], mt["omega"],
                                       mt["nu"], mt["q"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap)

    def init(key):
        tr = init_trees(key)
        return FedBiOAccTrainState(
            tr["x"], tr["y"], tr["u"], tree_zeros_like(tr["y"]),
            tree_zeros_like(tr["x"]), tree_zeros_like(tr["u"]),
            jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOAccTrainState, batch):
        t = state.step
        mask, w = round_ctx(t)
        a = seqs.alpha_schedule(cfg, t)
        # 1) old-iterate oracle FIRST (frees the old body afterwards)
        gd = voracle({"x": state.x, "y": state.y, "u": state.u}, batch)
        # 2) partial momentum: m ← (1-cα²)(m − g_old)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, gd["y"])
        nu = jax.tree.map(lambda m, o: (1.0 - cfg.c_nu * a * a) * (m - o),
                          state.nu, gd["x"])
        q = jax.tree.map(lambda m, o: (1.0 - cfg.c_u * a * a) * (m - o),
                         state.q, gd["u"])
        # 3) variable update with the *entering* momenta (Alg. 2 line 4);
        #    non-participants are frozen before communication, so their
        #    pass-through value — and the iterate gd2 sees — is the entering
        #    one (matching the fused engine's gated launch)
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        u = jax.tree.map(lambda v, m: v - (cfg.lr_u * a * m).astype(v.dtype),
                         state.u, state.q)
        x, y, u = (_freeze(mask, x, state.x), _freeze(mask, y, state.y),
                   _freeze(mask, u, state.u))
        cd = _comm_seqs(cfg, t, aspec, {"x": x, "y": y, "u": u}, weights=w)
        x, y, u = cd["x"], cd["y"], cd["u"]
        # 4) new-iterate oracle, same batch (STORM correction)
        gd2 = voracle({"x": x, "y": y, "u": u}, batch)
        omega = _freeze(mask, jax.tree.map(jnp.add, omega, gd2["y"]),
                        state.omega)
        nu = _freeze(mask, jax.tree.map(jnp.add, nu, gd2["x"]), state.nu)
        q = _freeze(mask, jax.tree.map(jnp.add, q, gd2["u"]), state.q)
        md = _comm_seqs(cfg, t, aspec, {"x": nu, "y": omega, "u": q},
                        weights=w)
        new = FedBiOAccTrainState(x, y, u, md["y"], md["x"], md["u"], t + 1)
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiO with local lower level (Algorithm 3) at model scale — Eq. (5):
# per-client PRIVATE heads (personalisation); only the body is averaged.
# ---------------------------------------------------------------------------

def make_fedbio_local_train_step(model: Model, cfg: FederatedConfig, *,
                                 n_micro: int = 1, remat: bool = True,
                                 use_flash: bool = False,
                                 use_lru_kernel: bool = False,
                                 fuse_oracles: bool = False,
                                 fuse_storm: bool = False,
                                 storm_block: int | None = None,
                                 participation: ParticipationSpec | None = None,
                                 mesh=None, overlap: bool = False):
    """Each client solves its own lower problem y^(m) (its private head); the
    unbiased local hyper-gradient is estimated with the truncated Neumann
    series (Eq. 6, Q = cfg.neumann_q HVPs); only x (body) is communicated —
    the y sequence is declared PRIVATE and never enters a reduction."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = seqs.SPECS["fedbio_local"]
    voracle, templates, init_trees = _local_lower_setup(model, cfg, f, g,
                                                        fuse_oracles)
    part, round_ctx = _participation_setup(cfg, aspec, participation,
                                           fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            # legacy state carries an (unused) u slot — zeros, like init
            return FedBiOTrainState(vt["x"], vt["y"],
                                    tree_zeros_like(vt["y"]), step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap)

    def init(key):
        tr = init_trees(key)
        return FedBiOTrainState(tr["x"], tr["y"], tree_zeros_like(tr["y"]),
                                jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOTrainState, batch):
        mask, w = round_ctx(state.step)
        gd = voracle({"x": state.x, "y": state.y}, batch)
        x = _freeze(mask, _sgd(state.x, gd["x"], cfg.lr_x), state.x)
        y = _freeze(mask, _sgd(state.y, gd["y"], cfg.lr_y), state.y)
        cd = _comm_seqs(cfg, state.step, aspec, {"x": x, "y": y}, weights=w)
        new = FedBiOTrainState(cd["x"], cd["y"], state.u, state.step + 1)
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc with local lower level (Algorithm 4) at model scale
# ---------------------------------------------------------------------------

def make_fedbioacc_local_train_step(model: Model, cfg: FederatedConfig, *,
                                    n_micro: int = 1, remat: bool = True,
                                    use_flash: bool = False,
                                    use_lru_kernel: bool = False,
                                    fuse_oracles: bool = False,
                                    fuse_storm: bool = False,
                                    storm_block: int | None = None,
                                    participation: ParticipationSpec | None = None,
                                    mesh=None, overlap: bool = False):
    """Algorithm 4: STORM momenta on (y, Φ); only x and ν are communicated
    (the y/ω sequence is PRIVATE)."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = seqs.SPECS["fedbioacc_local"]
    voracle, templates, init_trees = _local_lower_setup(model, cfg, f, g,
                                                        fuse_oracles)
    part, round_ctx = _participation_setup(cfg, aspec, participation,
                                           fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedBiOAccLocalTrainState(vt["x"], vt["y"], mt["omega"],
                                            mt["nu"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap)

    def init(key):
        tr = init_trees(key)
        return FedBiOAccLocalTrainState(
            tr["x"], tr["y"], tree_zeros_like(tr["y"]),
            tree_zeros_like(tr["x"]), jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOAccLocalTrainState, batch):
        t = state.step
        mask, w = round_ctx(t)
        a = seqs.alpha_schedule(cfg, t)
        gd = voracle({"x": state.x, "y": state.y}, batch)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, gd["y"])
        nu = jax.tree.map(lambda m, o: (1.0 - cfg.c_nu * a * a) * (m - o),
                          state.nu, gd["x"])
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        x, y = _freeze(mask, x, state.x), _freeze(mask, y, state.y)
        cd = _comm_seqs(cfg, t, aspec, {"x": x, "y": y},   # x avg'd, y private
                        weights=w)
        x, y = cd["x"], cd["y"]
        gd2 = voracle({"x": x, "y": y}, batch)
        omega = _freeze(mask, jax.tree.map(jnp.add, omega, gd2["y"]),
                        state.omega)
        nu = _freeze(mask, jax.tree.map(jnp.add, nu, gd2["x"]), state.nu)
        md = _comm_seqs(cfg, t, aspec, {"x": nu, "y": omega},  # ν too (l.14)
                        weights=w)
        new = FedBiOAccLocalTrainState(x, y, md["y"], md["x"], t + 1)
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedAvg (single-level local-SGD baseline substrate)
# ---------------------------------------------------------------------------

def make_fedavg_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           momentum: float = 0.9, use_flash: bool = False,
                           use_lru_kernel: bool = False,
                           fuse_oracles: bool = False,   # no-op: one oracle
                           fuse_storm: bool = False,
                           storm_block: int | None = None,
                           participation: ParticipationSpec | None = None,
                           mesh=None, overlap: bool = False):
    from repro.core.model_problem import _microbatch_mean

    def loss_fn(params, batch):
        def one(mb):
            l, _ = model.loss(params, mb, remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
            return l.astype(jnp.float32)
        return _microbatch_mean(one, batch, n_micro)

    M = cfg.num_clients
    aspec = seqs.SPECS["fedavg"]._replace(beta=momentum)

    def oracle(v, batch):
        return {"params": jax.grad(loss_fn)(v["params"], batch["train"])}

    voracle = jax.vmap(oracle)
    templates = {"params": jax.eval_shape(model.init, jax.random.PRNGKey(0))}

    def init_trees(key):
        return {"params": _bcast(model.init(key), M)}

    part, round_ctx = _participation_setup(cfg, aspec, participation,
                                           fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedAvgTrainState(vt["params"], mt["mom"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap)

    def init(key):
        tr = init_trees(key)
        return FedAvgTrainState(tr["params"], tree_zeros_like(tr["params"]),
                                jnp.zeros((), jnp.int32))

    def train_step(state: FedAvgTrainState, batch):
        mask, w = round_ctx(state.step)
        grads = voracle({"params": state.params}, batch)["params"]
        mom = jax.tree.map(lambda m, gr: momentum * m + gr.astype(m.dtype),
                           state.mom, grads)
        params = jax.tree.map(lambda p, m: p - (cfg.lr_x * m).astype(p.dtype),
                              state.params, mom)
        mom = _freeze(mask, mom, state.mom)
        params = _freeze(mask, params, state.params)
        params = _comm_seqs(cfg, state.step, aspec, {"params": params},
                            weights=w)["params"]
        mom = _comm_seqs(cfg, state.step, aspec, {"params": mom},
                         weights=w)["params"]
        new = FedAvgTrainState(params, mom, state.step + 1)
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step
