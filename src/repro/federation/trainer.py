"""Model-scale federated train steps (the production `train_step` that the
multi-pod dry-run lowers).

Every federated state tensor carries a leading client axis M. Under pjit the
axis is sharded over the mesh "data" axis (``client_sharded``) or left
replicated with the parameters FSDP-sharded instead (``client_replicated``,
for the memory-giant archs — see DESIGN.md §4). ``client_mean`` under
``lax.cond(step % I == 0)`` is the paper's communication round.

Memory discipline (what makes llama3-405b lowerable):

* FedBiO keeps **one** body-sized persistent tensor per client (x); the ν
  direction is transient.
* FedBiOAcc keeps two (x and its STORM momentum ν). The STORM correction
  needs the *previous* iterate — instead of storing a third body copy we
  evaluate the old-iterate oracle **before** applying the update, so XLA can
  free it (documented deviation: at communication steps the pre-averaging
  local iterate is used as the "old" point, exactly as Alg. 2 lines 10-12).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.model_problem import make_model_bilevel
from repro.core.tree_util import client_mean, client_mean_grouped, tree_zeros_like
from repro.models.registry import Model


class FedBiOTrainState(NamedTuple):
    x: Any               # [M, ...] body
    y: Any               # [M, ...] head (lower variable)
    u: Any               # [M, ...] Eq. (4) auxiliary
    step: jnp.ndarray


class FedBiOAccTrainState(NamedTuple):
    x: Any
    y: Any
    u: Any
    omega: Any           # y-momentum
    nu: Any              # x-momentum (body-sized)
    q: Any               # u-momentum
    step: jnp.ndarray


class FedAvgTrainState(NamedTuple):
    params: Any
    mom: Any
    step: jnp.ndarray


def _bcast(tree, m):
    return jax.tree.map(lambda v: jnp.broadcast_to(v[None], (m,) + v.shape), tree)


def _cond_mean(pred, tree):
    return lax.cond(pred, client_mean, lambda t: t, tree)


def _comm(cfg: FederatedConfig, step, tree):
    """Communication schedule: averaging every I steps; with
    ``hierarchy_period = k > 0`` only every k-th round crosses pod groups
    (pod-local grouped mean otherwise) — the beyond-paper hierarchical
    schedule for the multi-pod mesh (cross-pod traffic ÷ k)."""
    is_comm = (step + 1) % cfg.local_steps == 0
    if cfg.hierarchy_period <= 0:
        return _cond_mean(is_comm, tree)
    round_idx = (step + 1) // cfg.local_steps
    is_global = round_idx % cfg.hierarchy_period == 0

    def do_comm(t):
        return lax.cond(is_global, client_mean,
                        lambda tt: client_mean_grouped(tt, cfg.hierarchy_groups),
                        t)

    return lax.cond(is_comm, do_comm, lambda t: t, tree)


def _alpha(cfg: FederatedConfig, t):
    return cfg.alpha_delta / (cfg.alpha_u0 + t.astype(jnp.float32)) ** (1.0 / 3.0)


# ---------------------------------------------------------------------------
# FedBiO (Algorithm 1) at model scale
# ---------------------------------------------------------------------------

def make_fedbio_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           use_flash: bool = False, use_lru_kernel: bool = False,
                           fuse_oracles: bool = False):
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def init(key):
        p = model.init(key)
        x, y = p["body"], p["head"]
        return FedBiOTrainState(_bcast(x, M), _bcast(y, M),
                                _bcast(tree_zeros_like(y), M),
                                jnp.zeros((), jnp.int32))

    def local(x, y, u, batch):
        if fuse_oracles:
            omega, mu, p = hg.fused_oracles(g, f, x, y, u, batch)
            nu = mu
            u_new = jax.tree.map(lambda v, r: v - cfg.lr_u * r.astype(v.dtype),
                                 u, p)
        else:
            omega = hg.grad_y(g, x, y, batch)
            nu = hg.nu_direction(g, f, x, y, u, batch, batch)
            u_new = hg.u_step(g, f, x, y, u, batch, batch, cfg.lr_u)
        y_new = jax.tree.map(lambda v, o: v - cfg.lr_y * o.astype(v.dtype), y, omega)
        x_new = jax.tree.map(lambda v, o: v - cfg.lr_x * o.astype(v.dtype), x, nu)
        return x_new, y_new, u_new

    vlocal = jax.vmap(local)

    def train_step(state: FedBiOTrainState, batch):
        x, y, u = vlocal(state.x, state.y, state.u, batch)
        x = _comm(cfg, state.step, x)
        y = _comm(cfg, state.step, y)
        u = _comm(cfg, state.step, u)
        new = FedBiOTrainState(x, y, u, state.step + 1)
        # cheap progress metric: lower loss on the train stream of client 0
        return new, {"step": new.step}

    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc (Algorithm 2) at model scale
# ---------------------------------------------------------------------------

def make_fedbioacc_train_step(model: Model, cfg: FederatedConfig, *,
                              n_micro: int = 1, remat: bool = True,
                              use_flash: bool = False,
                              use_lru_kernel: bool = False,
                              fuse_storm: bool = False,
                              fuse_oracles: bool = False):
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def oracles(x, y, u, batch):
        if fuse_oracles:
            return hg.fused_oracles(g, f, x, y, u, batch)
        omega = hg.grad_y(g, x, y, batch)
        mu = hg.nu_direction(g, f, x, y, u, batch, batch)
        p = hg.u_residual(g, f, x, y, u, batch, batch)
        return omega, mu, p

    voracles = jax.vmap(oracles)

    def init(key):
        p = model.init(key)
        x, y = _bcast(p["body"], M), _bcast(p["head"], M)
        u = _bcast(tree_zeros_like(p["head"]), M)
        return FedBiOAccTrainState(
            x, y, u, tree_zeros_like(y), tree_zeros_like(x), tree_zeros_like(u),
            jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOAccTrainState, batch):
        t = state.step
        a = _alpha(cfg, t)
        decay = 1.0 - cfg.c_nu * a * a     # shared c for the fused path
        # 1) old-iterate oracle FIRST (frees the old body afterwards)
        o_old, m_old, p_old = voracles(state.x, state.y, state.u, batch)
        # 2) partial momentum: m ← (1-cα²)(m − o_old)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, o_old)
        nu = jax.tree.map(lambda m, o: decay * (m - o), state.nu, m_old)
        q = jax.tree.map(lambda m, o: (1.0 - cfg.c_u * a * a) * (m - o),
                         state.q, p_old)
        # 3) variable update with the *entering* momenta (Alg. 2 line 4)
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        u = jax.tree.map(lambda v, m: v - (cfg.lr_u * a * m).astype(v.dtype),
                         state.u, state.q)
        x, y, u = _comm(cfg, t, x), _comm(cfg, t, y), _comm(cfg, t, u)
        # 4) new-iterate oracle, same batch (STORM correction)
        o_new, m_new, p_new = voracles(x, y, u, batch)
        omega = jax.tree.map(jnp.add, omega, o_new)
        nu = jax.tree.map(jnp.add, nu, m_new)
        q = jax.tree.map(jnp.add, q, p_new)
        omega = _comm(cfg, t, omega)
        nu = _comm(cfg, t, nu)
        q = _comm(cfg, t, q)
        new = FedBiOAccTrainState(x, y, u, omega, nu, q, t + 1)
        return new, {"step": new.step}

    return init, train_step


# ---------------------------------------------------------------------------
# FedBiO with local lower level (Algorithm 3) at model scale — Eq. (5):
# per-client PRIVATE heads (personalisation); only the body is averaged.
# ---------------------------------------------------------------------------

def make_fedbio_local_train_step(model: Model, cfg: FederatedConfig, *,
                                 n_micro: int = 1, remat: bool = True,
                                 use_flash: bool = False,
                                 use_lru_kernel: bool = False):
    """Each client solves its own lower problem y^(m) (its private head); the
    unbiased local hyper-gradient is estimated with the truncated Neumann
    series (Eq. 6, Q = cfg.neumann_q HVPs); only x (body) is communicated."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def init(key):
        keys = jax.random.split(key, M + 1)
        p = model.init(keys[0])
        # heads start from per-client inits (they are never synchronised)
        heads = jax.tree.map(
            lambda *vs: jnp.stack(vs),
            *[model.init(k)["head"] for k in keys[1:]])
        return FedBiOTrainState(_bcast(p["body"], M), heads,
                                _bcast(tree_zeros_like(p["head"]), M),
                                jnp.zeros((), jnp.int32))

    def local(x, y, batch):
        omega = hg.grad_y(g, x, y, batch)
        nu = hg.neumann_hypergrad(g, f, x, y, batch, batch,
                                  cfg.neumann_q, cfg.neumann_tau)
        y_new = jax.tree.map(lambda v, o: v - cfg.lr_y * o.astype(v.dtype), y, omega)
        x_new = jax.tree.map(lambda v, o: v - cfg.lr_x * o.astype(v.dtype), x, nu)
        return x_new, y_new

    vlocal = jax.vmap(local)

    def train_step(state: FedBiOTrainState, batch):
        x, y = vlocal(state.x, state.y, batch)
        is_comm = (state.step + 1) % cfg.local_steps == 0
        x = _cond_mean(is_comm, x)             # ONLY the body is averaged
        new = FedBiOTrainState(x, y, state.u, state.step + 1)
        return new, {"step": new.step}

    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc with local lower level (Algorithm 4) at model scale
# ---------------------------------------------------------------------------

class FedBiOAccLocalTrainState(NamedTuple):
    x: Any
    y: Any               # private per-client heads
    omega: Any           # y-momentum (private)
    nu: Any              # x-momentum (averaged with x)
    step: jnp.ndarray


def make_fedbioacc_local_train_step(model: Model, cfg: FederatedConfig, *,
                                    n_micro: int = 1, remat: bool = True,
                                    use_flash: bool = False,
                                    use_lru_kernel: bool = False):
    """Algorithm 4: STORM momenta on (y, Φ); only x and ν are communicated."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    M = cfg.num_clients

    def oracles(x, y, batch):
        omega = hg.grad_y(g, x, y, batch)
        nu = hg.neumann_hypergrad(g, f, x, y, batch, batch,
                                  cfg.neumann_q, cfg.neumann_tau)
        return omega, nu

    voracles = jax.vmap(oracles)

    def init(key):
        keys = jax.random.split(key, M + 1)
        p = model.init(keys[0])
        heads = jax.tree.map(
            lambda *vs: jnp.stack(vs),
            *[model.init(k)["head"] for k in keys[1:]])
        x = _bcast(p["body"], M)
        return FedBiOAccLocalTrainState(
            x, heads, tree_zeros_like(heads), tree_zeros_like(x),
            jnp.zeros((), jnp.int32))

    def train_step(state: FedBiOAccLocalTrainState, batch):
        t = state.step
        a = _alpha(cfg, t)
        o_old, n_old = voracles(state.x, state.y, batch)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, o_old)
        nu = jax.tree.map(lambda m, o: (1.0 - cfg.c_nu * a * a) * (m - o),
                          state.nu, n_old)
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        is_comm = (t + 1) % cfg.local_steps == 0
        x = _cond_mean(is_comm, x)              # x averaged, y private
        o_new, n_new = voracles(x, y, batch)
        omega = jax.tree.map(jnp.add, omega, o_new)
        nu = jax.tree.map(jnp.add, nu, n_new)
        nu = _cond_mean(is_comm, nu)            # ν averaged too (Alg. 4 l.14)
        new = FedBiOAccLocalTrainState(x, y, omega, nu, t + 1)
        return new, {"step": new.step}

    return init, train_step


# ---------------------------------------------------------------------------
# FedAvg (single-level local-SGD baseline substrate)
# ---------------------------------------------------------------------------

def make_fedavg_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           momentum: float = 0.9, use_flash: bool = False,
                           use_lru_kernel: bool = False):
    from repro.core.model_problem import _microbatch_mean

    def loss_fn(params, batch):
        def one(mb):
            l, _ = model.loss(params, mb, remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
            return l.astype(jnp.float32)
        return _microbatch_mean(one, batch, n_micro)

    M = cfg.num_clients

    def init(key):
        p = model.init(key)
        return FedAvgTrainState(_bcast(p, M), _bcast(tree_zeros_like(p), M),
                                jnp.zeros((), jnp.int32))

    vgrad = jax.vmap(jax.grad(loss_fn))

    def train_step(state: FedAvgTrainState, batch):
        grads = vgrad(state.params, batch["train"])
        mom = jax.tree.map(lambda m, gr: momentum * m + gr.astype(m.dtype),
                           state.mom, grads)
        params = jax.tree.map(lambda p, m: p - (cfg.lr_x * m).astype(p.dtype),
                              state.params, mom)
        is_comm = (state.step + 1) % cfg.local_steps == 0
        params = _cond_mean(is_comm, params)
        mom = _cond_mean(is_comm, mom)
        new = FedAvgTrainState(params, mom, state.step + 1)
        return new, {"step": new.step}

    return init, train_step
