"""Model-scale federated train steps (the production `train_step` that the
multi-pod dry-run lowers).

Every federated state tensor carries a leading client axis M. Under pjit the
axis is sharded over the mesh "data" axis (``client_sharded``) or left
replicated with the parameters FSDP-sharded instead (``client_replicated``,
for the memory-giant archs — see DESIGN.md §4).

Every factory here is a thin declaration over the **sequence-spec engine**
(``repro.optim.sequences``): the algorithm is a tuple of named sequences —
(variable section, momentum, lr key, STORM-constant key, comm policy) — and
the per-sequence communication policies drive BOTH code paths:

* unfused (default): per-leaf tree-map updates, communication through
  ``sequences.comm_tree`` — so ``cfg.hierarchy_period`` is honored uniformly
  by all five algorithms (fedbio_local/fedbioacc_local/fedavg previously
  bypassed the hierarchical schedule);
* ``fuse_storm=True``: the state lives on the flat-buffer substrate
  (``repro.optim.flat``) as per-dtype [M, N] buffers; each step is one fused
  triple-sequence Pallas launch (+ one correction add for the STORM kind)
  and each communication is one *section-masked* reduction per dtype buffer
  — PRIVATE sections (the local-lower y/ω) are sliced around the reduction
  and pass through bit-identical, so private state provably never enters an
  all-reduce.  The returned ``train_step`` then consumes/produces a
  ``sequences.FlatState`` and exposes ``train_step.views(state)`` (legacy
  pytree state for eval/checkpoint) and ``train_step.spec`` (the layout).

Every factory accepts ``participation=`` (a
``repro.federation.participation.ParticipationSpec``): per-round client
sampling (uniform/weighted/trace-driven availability) threads the mask
through BOTH paths — unfused steps freeze non-participants bit-exact with a
``where`` select and average participants only
(``tree_util.client_mean_weighted``); fused steps gate the Pallas launches
(masked lr, pinned decay) and run participation-weighted masked reductions.
The compiled engine is recorded on ``train_step.participation`` (its
``.spec`` is the declarative scenario).  Staleness-discounted reductions
(α^staleness aging of returning clients) run on BOTH paths with the same
arithmetic: the fused engine carries the per-client counters on
``FlatState.stale``; the unfused tree states carry them on their own
``stale`` slot (the empty tuple — zero pytree leaves — without a
participation engine, so pre-participation checkpoints and jit caches keep
their exact structure).

Every factory is also **self-registered** into ``repro.api.registry`` with
its algorithm-specific hyperparams (the STORM constants for the FedBiOAcc
family, ``momentum`` for FedAvg) and section names — the declarative
``repro.api.Experiment``/``build`` path constructs the exact same factory
calls, so registry-built runs are bit-identical to hand-built ones.
``comm_every=`` (a ``{section: k}`` dict) overrides per-sequence
communication cadences on both paths (``sequences.with_comm_every``).

Every factory also accepts ``faults=`` / ``robustness=`` (declarative
``repro.federation.faults`` specs, fused path only): deterministic per-round
client failure injection (dropout / NaN / byzantine scaling of what clients
send) and the guard policy against it — per-client health screening and
robust aggregation inside the masked reductions, with the rollback policy
consumed by ``launch.train``.  Both are recorded on ``train_step.faults`` /
``train_step.robustness``; both ``None`` (the default) leaves every
trajectory bit-identical to the unguarded stack.

Every factory also accepts ``compression=`` (a declarative
``repro.federation.compression.CompressionSpec``, fused path only): the
masked reductions move quantized (bf16 / per-tile-scaled int8) and/or
top-k sparsified client sends, with per-client error-feedback buffers
carried on ``FlatState.ef`` — recorded on ``train_step.compression``;
``None`` (the default) leaves every trajectory bit-identical.

Every factory also accepts ``mesh=`` (a jax ``Mesh`` with ("data", "model")
axes, or a prebuilt ``optim.flat.ShardCtx`` for the non-default knobs —
``use_scatter`` picks the ``psum_scatter``+``all_gather`` all-reduce
decomposition) and ``overlap=`` — fused-path only: the flat [M, N] buffers
are partitioned client-axis-over-"data" / packed-axis-over-"model"
(``sharding.rules.flat_state_specs``), the fused launches and masked
reductions run under ``shard_map`` with the participant mean lowered to true
``lax.psum``/``psum_scatter`` collectives, and ``overlap=True`` issues the
variable-section reduction concurrently with the new-iterate oracle (see
``repro.optim.sequences``).  ``train_step.shardings(state)`` returns the
``NamedSharding`` pytree for jit boundaries.

Memory discipline (what makes llama3-405b lowerable): the STORM correction
needs the *previous* iterate — instead of storing another body copy we
evaluate the old-iterate oracle **before** applying the update, so XLA can
free it (documented deviation: at communication steps the pre-averaging
local iterate is used as the "old" point, exactly as Alg. 2 lines 10-12).
Momenta live in f32 buffers regardless of the parameter dtype — the unfused
arithmetic promotes them the same way, and the STORM correction
g_new − g_old is a small difference bf16 would destroy.  Fused trajectories
match unfused ones to float rounding (test-asserted in
tests/test_flat_substrate.py and tests/test_sequences.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.api.registry import register
from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.model_problem import make_model_bilevel
from repro.core.tree_util import tree_zeros_like
from repro.federation.participation import (Participation, ParticipationSpec,
                                            make_participation)
from repro.models.registry import Model
from repro.optim import sequences as seqs
from repro.optim.sequences import FlatState

# ``stale`` on every legacy state: per-client staleness counters [M] int32
# (rounds missed since last participation) when a participation engine with
# staleness discounting is possible — the empty tuple (NO pytree leaves)
# otherwise, so pre-participation structures are unchanged bit-for-bit.


class FedBiOTrainState(NamedTuple):
    x: Any               # [M, ...] body
    y: Any               # [M, ...] head (lower variable)
    u: Any               # [M, ...] Eq. (4) auxiliary
    step: jnp.ndarray
    stale: Any = ()


class FedBiOAccTrainState(NamedTuple):
    x: Any
    y: Any
    u: Any
    omega: Any           # y-momentum
    nu: Any              # x-momentum (body-sized)
    q: Any               # u-momentum
    step: jnp.ndarray
    stale: Any = ()


class FedBiOAccLocalTrainState(NamedTuple):
    x: Any
    y: Any               # private per-client heads
    omega: Any           # y-momentum (private)
    nu: Any              # x-momentum (averaged with x)
    step: jnp.ndarray
    stale: Any = ()


class FedAvgTrainState(NamedTuple):
    params: Any
    mom: Any
    step: jnp.ndarray
    stale: Any = ()


# Back-compat alias: the fuse_storm=True state of every algorithm is the
# engine's FlatState (vars/mom buffer tuples + step).
FlatFedBiOAccTrainState = FlatState


def _bcast(tree, m):
    return jax.tree.map(lambda v: jnp.broadcast_to(v[None], (m,) + v.shape), tree)


def _sgd(v, g, lr):
    return jax.tree.map(lambda a, b: a - lr * b.astype(a.dtype), v, g)


def _comm_seqs(cfg, step, aspec, trees: dict, weights=None):
    """Communicate trees keyed by SECTION name under the sections' policies
    (momenta are passed under their sequence's section too — e.g. ν under
    "x"); returns the same keys so pairings stay structural.  ``weights``:
    per-client participation weights [M] (participants-only mean) — one
    shared array, or a dict keyed by section (staleness-discounted
    sequences, where each section's α produces its own aged weights)."""
    by_sec = {q.section: q for q in aspec.sequences}
    w_of = (weights.get if isinstance(weights, dict)
            else lambda name, w=weights: w)
    return {name: seqs.comm_tree(cfg, step, t, by_sec[name].comm,
                                 weights=w_of(name),
                                 comm_every=by_sec[name].comm_every)
            for name, t in trees.items()}


def _freeze(mask, new, old):
    """Bit-exact participation freeze for the unfused tree paths:
    non-participant rows keep their entering value (``jnp.where`` selects
    the branch verbatim; all-ones masks select ``new`` everywhere)."""
    if mask is None:
        return new

    def one(n, o):
        col = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(col > 0, n, o)

    return jax.tree.map(one, new, old)


def _participation_setup(cfg: FederatedConfig, aspec,
                         participation: ParticipationSpec | None,
                         fuse_storm: bool):
    """Compile the participation spec and return ``(part, round_ctx,
    init_stale, next_stale)`` for the unfused tree paths.

    ``round_ctx(step, stale)`` derives the round's ``(mask, weights)`` —
    ``weights`` is one shared [M] array, or a per-SECTION dict when
    staleness discounting is on: each sequence's α ages returning clients'
    weights through the engine-shared ``sequences.staleness_weights`` /
    ``sequences.advance_stale`` helpers (one source for the arithmetic), so
    fused and unfused discounted trajectories agree to float rounding.
    ``init_stale()`` / ``next_stale(step, mask, stale)`` manage the legacy
    states' per-client counters (the empty tuple when no discounting can
    ever bite — keeping pre-participation state structures unchanged)."""
    part = make_participation(participation, cfg.num_clients)
    stale_alpha = seqs.effective_staleness(aspec, part)
    discounted = any(a != 1.0 for a in stale_alpha)

    def round_ctx(step, stale=()):
        if part is None:
            return None, None
        mask, w = part.round_weights(step // cfg.local_steps)
        if not discounted:
            return mask, w
        aged = seqs.staleness_weights(w, stale, stale_alpha)
        return mask, {q.section: a
                      for q, a in zip(aspec.sequences, aged)}

    def init_stale():
        return (jnp.zeros((cfg.num_clients,), jnp.int32)
                if part is not None and discounted else ())

    def next_stale(step, mask, stale):
        if part is None or not discounted:
            return stale
        return seqs.advance_stale(cfg, step, mask, stale)

    return part, round_ctx, init_stale, next_stale


def _private_heads_init(model: Model, key, m: int):
    """Per-client head inits for the local-lower algorithms (the private
    lower variables are never synchronised, so they must not start equal)."""
    keys = jax.random.split(key, m + 1)  # analysis: ignore[L304] init fan
    p = model.init(keys[0])
    heads = jax.tree.map(lambda *vs: jnp.stack(vs),
                         *[model.init(k)["head"] for k in keys[1:]])
    return p, heads


def _global_lower_setup(model: Model, cfg: FederatedConfig, f, g,
                        fuse_oracles: bool):
    """(voracle, templates, init_trees) shared by fedbio/fedbioacc: the
    three global-lower oracle directions (μ, ω, u-residual p) keyed by
    section, x|y|u section templates, and the broadcast client init."""
    M = cfg.num_clients

    def oracle(v, batch):
        x, y, u = v["x"], v["y"], v["u"]
        if fuse_oracles:
            omega, mu, p = hg.fused_oracles(g, f, x, y, u, batch)
        else:
            omega = hg.grad_y(g, x, y, batch)
            mu = hg.nu_direction(g, f, x, y, u, batch, batch)
            p = hg.u_residual(g, f, x, y, u, batch, batch)
        return {"x": mu, "y": omega, "u": p}

    tmpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    templates = {"x": tmpl["body"], "y": tmpl["head"], "u": tmpl["head"]}

    def init_trees(key):
        p = model.init(key)
        return {"x": _bcast(p["body"], M), "y": _bcast(p["head"], M),
                "u": _bcast(tree_zeros_like(p["head"]), M)}

    return jax.vmap(oracle), templates, init_trees


def _local_lower_setup(model: Model, cfg: FederatedConfig, f, g,
                       fuse_oracles: bool):
    """(voracle, templates, init_trees) shared by the local-lower variants:
    the (Φ, ω) oracle pair keyed by section, x|y templates, and the
    broadcast-body / private-heads client init."""
    M = cfg.num_clients

    def oracle(v, batch):
        x, y = v["x"], v["y"]
        if fuse_oracles:
            omega, nu = hg.fused_local_oracles(g, f, x, y, batch,
                                               cfg.neumann_q, cfg.neumann_tau)
        else:
            omega = hg.grad_y(g, x, y, batch)
            nu = hg.neumann_hypergrad(g, f, x, y, batch, batch,
                                      cfg.neumann_q, cfg.neumann_tau)
        return {"x": nu, "y": omega}

    tmpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    templates = {"x": tmpl["body"], "y": tmpl["head"]}

    def init_trees(key):
        p, heads = _private_heads_init(model, key, M)
        return {"x": _bcast(p["body"], M), "y": heads}

    return jax.vmap(oracle), templates, init_trees


def _aspec(name: str, comm_every: dict | None):
    """The algorithm's sequence spec, with per-section communication
    cadences applied (the ``comm_every={section: k}`` factory knob)."""
    aspec = seqs.SPECS[name]
    return seqs.with_comm_every(aspec, comm_every) if comm_every else aspec


def _fault_setup(cfg: FederatedConfig, faults, robustness, fuse_storm: bool):
    """Compile the fault spec (``repro.federation.faults.make_faults``) and
    pass the robustness policy through.  Fault injection and the robust
    reductions live on the fused sequence-spec engine only — the unfused
    tree paths stay byte-for-byte unguarded, so reject them loudly (the
    same contract as ``_shard_setup``)."""
    if faults is None and robustness is None:
        return None, None
    if not fuse_storm:
        raise ValueError(
            "faults=/robustness= require fuse_storm=True — fault injection "
            "and the robust reductions are features of the fused "
            "sequence-spec engine")
    from repro.federation.faults import make_faults
    return make_faults(faults, cfg.num_clients), robustness


def _compress_setup(compression, fuse_storm: bool):
    """Pass the compression spec through to the engine.  The compressed
    reductions live on the fused sequence-spec engine only — the unfused
    tree paths communicate exact f32 — so reject them loudly (the same
    contract as ``_fault_setup`` / ``_shard_setup``)."""
    if compression is None:
        return None
    if not fuse_storm:
        raise ValueError(
            "compression= requires fuse_storm=True — the compressed "
            "reductions are a feature of the fused sequence-spec engine")
    return compression


def _telemetry_setup(telemetry, fuse_storm: bool):
    """Pass the telemetry spec through to the engine.  The in-band metrics
    side output reads the fused engine's flat buffers, so explicit metric
    groups on the unfused path are rejected loudly (the same contract as
    ``_compress_setup``); a metrics-free spec (``metrics=()``) degrades to
    events-only on either path and costs the step nothing."""
    if telemetry is None:
        return None
    metrics = getattr(telemetry, "metrics", None)
    if not fuse_storm:
        if metrics:
            raise ValueError(
                "in-band telemetry metrics require fuse_storm=True — they "
                "are a side output of the fused sequence-spec engine; use "
                "metrics=() for an events-only stream")
        return None     # events-only: nothing for the engine to compute
    return telemetry


def _straggler_setup(cfg: FederatedConfig, stragglers, participation,
                     fuse_storm: bool):
    """Compile the straggler spec
    (``repro.federation.stragglers.make_stragglers``) and over-provision the
    sampler: with ``over_provision = b`` the round requests ``min(M, m + b)``
    clients from a counted sampler, so the deadline can drop stragglers and
    still make quorum.  Deadline-driven elastic rounds live on the fused
    sequence-spec engine only — reject the unfused tree paths loudly (the
    ``_fault_setup`` contract).  Returns ``(compiled, participation')``."""
    if stragglers is None:
        return None, participation
    if not fuse_storm:
        raise ValueError(
            "stragglers= requires fuse_storm=True — deadline-driven "
            "elastic rounds are a feature of the fused sequence-spec "
            "engine")
    from repro.federation.stragglers import make_stragglers, over_provision
    return (make_stragglers(stragglers, cfg.num_clients),
            over_provision(stragglers, participation, cfg.num_clients))


def _shard_setup(mesh, overlap: bool, fuse_storm: bool):
    """Compile the mesh knob into a :class:`flat.ShardCtx` (None without a
    mesh).  ``mesh`` may also be a prebuilt :class:`flat.ShardCtx` — the way
    to reach the non-default knobs (``use_scatter``, custom axis names).
    The sharded substrate and the overlap schedule live on the fused engine
    only — reject the unfused tree paths loudly."""
    from repro.optim import flat as _flat
    if (mesh is not None or overlap) and not fuse_storm:
        raise ValueError(
            "mesh=/overlap= require fuse_storm=True — the sharded flat "
            "substrate and the comm/compute overlap schedule are features "
            "of the fused sequence-spec engine")
    if mesh is None:
        return None
    if isinstance(mesh, _flat.ShardCtx):
        return mesh
    return _flat.make_shard_ctx(mesh)


def _make_flat_pair(cfg: FederatedConfig, aspec, templates, voracle,
                    init_trees, storm_block, to_state,
                    part: Participation | None = None,
                    shard=None, overlap: bool = False,
                    fault=None, robustness=None, compression=None,
                    telemetry=None, stragglers=None):
    """fuse_storm=True path shared by all factories: compile the sequence
    spec into the flat-substrate engine and wrap it as (init, train_step)."""
    engine = seqs.make_engine(cfg, aspec, templates, voracle,
                              block=storm_block, participation=part,
                              shard=shard, overlap=overlap,
                              faults=fault, robustness=robustness,
                              compression=compression, telemetry=telemetry,
                              stragglers=stragglers)
    tel_on = bool(getattr(engine.step, "telemetry_groups", ()))

    def init(key):
        return engine.init_state(init_trees(key))

    if tel_on:
        def train_step(state: FlatState, batch):
            new, met = engine.step(state, batch)
            met["step"] = new.step
            return new, met
    else:
        def train_step(state: FlatState, batch):
            new = engine.step(state, batch)
            return new, {"step": new.step}

    def views(state: FlatState):
        vt, mt = engine.views(state)
        return to_state(vt, mt, state.step)

    for fn in (init, train_step):
        fn.spec = engine.spec
        fn.views = views
        fn.participation = part
        fn.shardings = engine.shardings
        fn.faults = fault
        fn.robustness = robustness
        fn.compression = compression
        fn.telemetry = telemetry
        fn.stragglers = stragglers
        fn.aspec = engine.aspec
        fn.comm_fn = engine.comm_fn
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiO (Algorithm 1) at model scale
# ---------------------------------------------------------------------------

@register("fedbio", sections=("x", "y", "u"),
          description="FedBiO (Alg. 1): alternating SGD on (x, y, u), "
                      "global lower problem")
def make_fedbio_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           use_flash: bool = False, use_lru_kernel: bool = False,
                           fuse_oracles: bool = False,
                           fuse_storm: bool = False,
                           storm_block: int | None = None,
                           participation: ParticipationSpec | None = None,
                           mesh=None, overlap: bool = False,
                           comm_every: dict | None = None,
                           faults=None, robustness=None, compression=None,
                           telemetry=None, stragglers=None):
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = _aspec("fedbio", comm_every)
    voracle, templates, init_trees = _global_lower_setup(model, cfg, f, g,
                                                         fuse_oracles)
    strag, participation = _straggler_setup(cfg, stragglers,
                                            participation, fuse_storm)
    part, round_ctx, init_stale, next_stale = _participation_setup(
        cfg, aspec, participation, fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)
    fault, robust = _fault_setup(cfg, faults, robustness, fuse_storm)
    comp = _compress_setup(compression, fuse_storm)
    tel = _telemetry_setup(telemetry, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedBiOTrainState(vt["x"], vt["y"], vt["u"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap,
                               fault, robust, comp, tel, strag)

    def init(key):
        tr = init_trees(key)
        return FedBiOTrainState(tr["x"], tr["y"], tr["u"],
                                jnp.zeros((), jnp.int32), init_stale())

    def train_step(state: FedBiOTrainState, batch):
        mask, w = round_ctx(state.step, state.stale)
        gd = voracle({"x": state.x, "y": state.y, "u": state.u}, batch)
        x = _freeze(mask, _sgd(state.x, gd["x"], cfg.lr_x), state.x)
        y = _freeze(mask, _sgd(state.y, gd["y"], cfg.lr_y), state.y)
        u = _freeze(mask, _sgd(state.u, gd["u"], cfg.lr_u), state.u)
        cd = _comm_seqs(cfg, state.step, aspec, {"x": x, "y": y, "u": u},
                        weights=w)
        new = FedBiOTrainState(cd["x"], cd["y"], cd["u"], state.step + 1,
                               next_stale(state.step, mask, state.stale))
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc (Algorithm 2) at model scale
# ---------------------------------------------------------------------------

@register("fedbioacc",
          hparams={"c_nu": 1.0, "c_omega": 1.0, "c_u": 1.0,
                   "alpha_delta": 1.0, "alpha_u0": 8.0},
          cfg_fields=("c_nu", "c_omega", "c_u", "alpha_delta", "alpha_u0"),
          sections=("x", "y", "u"),
          description="FedBiOAcc (Alg. 2): STORM-accelerated, global lower "
                      "problem")
def make_fedbioacc_train_step(model: Model, cfg: FederatedConfig, *,
                              n_micro: int = 1, remat: bool = True,
                              use_flash: bool = False,
                              use_lru_kernel: bool = False,
                              fuse_storm: bool = False,
                              fuse_oracles: bool = False,
                              storm_block: int | None = None,
                              participation: ParticipationSpec | None = None,
                              mesh=None, overlap: bool = False,
                              comm_every: dict | None = None,
                              faults=None, robustness=None, compression=None,
                              telemetry=None, stragglers=None):
    """FedBiOAcc (Alg. 2) train step.

    ``fuse_oracles`` shares one forward-over-reverse linearization across the
    three oracle directions (see ``hypergrad.fused_oracles``).  ``fuse_storm``
    switches to the flat-substrate engine (see the module docstring);
    ``storm_block`` overrides the kernel tile size (testing/small models).
    ``participation`` samples m ≪ M clients per round (see the module
    docstring) — the spec is recorded on ``train_step.participation``.
    ``mesh`` shards the flat substrate over the mesh ("data", "model") axes
    with real ``psum`` collectives under ``shard_map``; ``overlap`` enables
    the comm/compute overlap schedule (both need ``fuse_storm=True``).
    ``comm_every`` overrides per-section communication cadences.
    ``faults`` (a ``federation.faults.FaultSpec``) deterministically injects
    per-round client dropout/NaN/byzantine failures; ``robustness`` (a
    ``federation.faults.RobustnessSpec``) health-screens senders and picks
    the robust aggregator (both need ``fuse_storm=True``; recorded on
    ``train_step.faults`` / ``train_step.robustness``).
    ``compression`` (a ``federation.compression.CompressionSpec``) moves the
    reductions quantized (bf16 / per-tile int8) and/or top-k sparsified with
    error feedback (needs ``fuse_storm=True``; recorded on
    ``train_step.compression``).
    """
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = _aspec("fedbioacc", comm_every)
    voracle, templates, init_trees = _global_lower_setup(model, cfg, f, g,
                                                         fuse_oracles)
    strag, participation = _straggler_setup(cfg, stragglers,
                                            participation, fuse_storm)
    part, round_ctx, init_stale, next_stale = _participation_setup(
        cfg, aspec, participation, fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)
    fault, robust = _fault_setup(cfg, faults, robustness, fuse_storm)
    comp = _compress_setup(compression, fuse_storm)
    tel = _telemetry_setup(telemetry, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedBiOAccTrainState(vt["x"], vt["y"], vt["u"], mt["omega"],
                                       mt["nu"], mt["q"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap,
                               fault, robust, comp, tel, strag)

    def init(key):
        tr = init_trees(key)
        return FedBiOAccTrainState(
            tr["x"], tr["y"], tr["u"], tree_zeros_like(tr["y"]),
            tree_zeros_like(tr["x"]), tree_zeros_like(tr["u"]),
            jnp.zeros((), jnp.int32), init_stale())

    def train_step(state: FedBiOAccTrainState, batch):
        t = state.step
        mask, w = round_ctx(t, state.stale)
        a = seqs.alpha_schedule(cfg, t)
        # 1) old-iterate oracle FIRST (frees the old body afterwards)
        gd = voracle({"x": state.x, "y": state.y, "u": state.u}, batch)
        # 2) partial momentum: m ← (1-cα²)(m − g_old)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, gd["y"])
        nu = jax.tree.map(lambda m, o: (1.0 - cfg.c_nu * a * a) * (m - o),
                          state.nu, gd["x"])
        q = jax.tree.map(lambda m, o: (1.0 - cfg.c_u * a * a) * (m - o),
                         state.q, gd["u"])
        # 3) variable update with the *entering* momenta (Alg. 2 line 4);
        #    non-participants are frozen before communication, so their
        #    pass-through value — and the iterate gd2 sees — is the entering
        #    one (matching the fused engine's gated launch)
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        u = jax.tree.map(lambda v, m: v - (cfg.lr_u * a * m).astype(v.dtype),
                         state.u, state.q)
        x, y, u = (_freeze(mask, x, state.x), _freeze(mask, y, state.y),
                   _freeze(mask, u, state.u))
        cd = _comm_seqs(cfg, t, aspec, {"x": x, "y": y, "u": u}, weights=w)
        x, y, u = cd["x"], cd["y"], cd["u"]
        # 4) new-iterate oracle, same batch (STORM correction)
        gd2 = voracle({"x": x, "y": y, "u": u}, batch)
        omega = _freeze(mask, jax.tree.map(jnp.add, omega, gd2["y"]),
                        state.omega)
        nu = _freeze(mask, jax.tree.map(jnp.add, nu, gd2["x"]), state.nu)
        q = _freeze(mask, jax.tree.map(jnp.add, q, gd2["u"]), state.q)
        md = _comm_seqs(cfg, t, aspec, {"x": nu, "y": omega, "u": q},
                        weights=w)
        new = FedBiOAccTrainState(x, y, u, md["y"], md["x"], md["u"], t + 1,
                                  next_stale(t, mask, state.stale))
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiO with local lower level (Algorithm 3) at model scale — Eq. (5):
# per-client PRIVATE heads (personalisation); only the body is averaged.
# ---------------------------------------------------------------------------

@register("fedbio_local", sections=("x", "y"),
          description="FedBiO-Local (Alg. 3): private per-client heads, "
                      "Neumann hyper-gradient")
def make_fedbio_local_train_step(model: Model, cfg: FederatedConfig, *,
                                 n_micro: int = 1, remat: bool = True,
                                 use_flash: bool = False,
                                 use_lru_kernel: bool = False,
                                 fuse_oracles: bool = False,
                                 fuse_storm: bool = False,
                                 storm_block: int | None = None,
                                 participation: ParticipationSpec | None = None,
                                 mesh=None, overlap: bool = False,
                                 comm_every: dict | None = None,
                                 faults=None, robustness=None, compression=None,
                                 telemetry=None, stragglers=None):
    """Each client solves its own lower problem y^(m) (its private head); the
    unbiased local hyper-gradient is estimated with the truncated Neumann
    series (Eq. 6, Q = cfg.neumann_q HVPs); only x (body) is communicated —
    the y sequence is declared PRIVATE and never enters a reduction."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = _aspec("fedbio_local", comm_every)
    voracle, templates, init_trees = _local_lower_setup(model, cfg, f, g,
                                                        fuse_oracles)
    strag, participation = _straggler_setup(cfg, stragglers,
                                            participation, fuse_storm)
    part, round_ctx, init_stale, next_stale = _participation_setup(
        cfg, aspec, participation, fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)
    fault, robust = _fault_setup(cfg, faults, robustness, fuse_storm)
    comp = _compress_setup(compression, fuse_storm)
    tel = _telemetry_setup(telemetry, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            # legacy state carries an (unused) u slot — zeros, like init
            return FedBiOTrainState(vt["x"], vt["y"],
                                    tree_zeros_like(vt["y"]), step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap,
                               fault, robust, comp, tel, strag)

    def init(key):
        tr = init_trees(key)
        return FedBiOTrainState(tr["x"], tr["y"], tree_zeros_like(tr["y"]),
                                jnp.zeros((), jnp.int32), init_stale())

    def train_step(state: FedBiOTrainState, batch):
        mask, w = round_ctx(state.step, state.stale)
        gd = voracle({"x": state.x, "y": state.y}, batch)
        x = _freeze(mask, _sgd(state.x, gd["x"], cfg.lr_x), state.x)
        y = _freeze(mask, _sgd(state.y, gd["y"], cfg.lr_y), state.y)
        cd = _comm_seqs(cfg, state.step, aspec, {"x": x, "y": y}, weights=w)
        new = FedBiOTrainState(cd["x"], cd["y"], state.u, state.step + 1,
                               next_stale(state.step, mask, state.stale))
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedBiOAcc with local lower level (Algorithm 4) at model scale
# ---------------------------------------------------------------------------

@register("fedbioacc_local",
          hparams={"c_nu": 1.0, "c_omega": 1.0,
                   "alpha_delta": 1.0, "alpha_u0": 8.0},
          cfg_fields=("c_nu", "c_omega", "alpha_delta", "alpha_u0"),
          sections=("x", "y"),
          description="FedBiOAcc-Local (Alg. 4): STORM momenta on (y, Φ), "
                      "private lower problems")
def make_fedbioacc_local_train_step(model: Model, cfg: FederatedConfig, *,
                                    n_micro: int = 1, remat: bool = True,
                                    use_flash: bool = False,
                                    use_lru_kernel: bool = False,
                                    fuse_oracles: bool = False,
                                    fuse_storm: bool = False,
                                    storm_block: int | None = None,
                                    participation: ParticipationSpec | None = None,
                                    mesh=None, overlap: bool = False,
                                    comm_every: dict | None = None,
                                    faults=None, robustness=None, compression=None,
                                    telemetry=None, stragglers=None):
    """Algorithm 4: STORM momenta on (y, Φ); only x and ν are communicated
    (the y/ω sequence is PRIVATE — faults/robustness touch only the sent
    x/ν rows; private heads are never corrupted or screened)."""
    f, g = make_model_bilevel(model, lower_l2=cfg.lower_l2, n_micro=n_micro,
                              remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
    aspec = _aspec("fedbioacc_local", comm_every)
    voracle, templates, init_trees = _local_lower_setup(model, cfg, f, g,
                                                        fuse_oracles)
    strag, participation = _straggler_setup(cfg, stragglers,
                                            participation, fuse_storm)
    part, round_ctx, init_stale, next_stale = _participation_setup(
        cfg, aspec, participation, fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)
    fault, robust = _fault_setup(cfg, faults, robustness, fuse_storm)
    comp = _compress_setup(compression, fuse_storm)
    tel = _telemetry_setup(telemetry, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedBiOAccLocalTrainState(vt["x"], vt["y"], mt["omega"],
                                            mt["nu"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap,
                               fault, robust, comp, tel, strag)

    def init(key):
        tr = init_trees(key)
        return FedBiOAccLocalTrainState(
            tr["x"], tr["y"], tree_zeros_like(tr["y"]),
            tree_zeros_like(tr["x"]), jnp.zeros((), jnp.int32), init_stale())

    def train_step(state: FedBiOAccLocalTrainState, batch):
        t = state.step
        mask, w = round_ctx(t, state.stale)
        a = seqs.alpha_schedule(cfg, t)
        gd = voracle({"x": state.x, "y": state.y}, batch)
        omega = jax.tree.map(lambda m, o: (1.0 - cfg.c_omega * a * a) * (m - o),
                             state.omega, gd["y"])
        nu = jax.tree.map(lambda m, o: (1.0 - cfg.c_nu * a * a) * (m - o),
                          state.nu, gd["x"])
        x = jax.tree.map(lambda v, m: v - (cfg.lr_x * a * m).astype(v.dtype),
                         state.x, state.nu)
        y = jax.tree.map(lambda v, m: v - (cfg.lr_y * a * m).astype(v.dtype),
                         state.y, state.omega)
        x, y = _freeze(mask, x, state.x), _freeze(mask, y, state.y)
        cd = _comm_seqs(cfg, t, aspec, {"x": x, "y": y},   # x avg'd, y private
                        weights=w)
        x, y = cd["x"], cd["y"]
        gd2 = voracle({"x": x, "y": y}, batch)
        omega = _freeze(mask, jax.tree.map(jnp.add, omega, gd2["y"]),
                        state.omega)
        nu = _freeze(mask, jax.tree.map(jnp.add, nu, gd2["x"]), state.nu)
        md = _comm_seqs(cfg, t, aspec, {"x": nu, "y": omega},  # ν too (l.14)
                        weights=w)
        new = FedBiOAccLocalTrainState(x, y, md["y"], md["x"], t + 1,
                                       next_stale(t, mask, state.stale))
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step


# ---------------------------------------------------------------------------
# FedAvg (single-level local-SGD baseline substrate)
# ---------------------------------------------------------------------------

@register("fedavg", hparams={"momentum": 0.9}, sections=("params",),
          description="FedAvg baseline: local heavy-ball SGD + periodic "
                      "averaging")
def make_fedavg_train_step(model: Model, cfg: FederatedConfig, *,
                           n_micro: int = 1, remat: bool = True,
                           momentum: float = 0.9, use_flash: bool = False,
                           use_lru_kernel: bool = False,
                           fuse_oracles: bool = False,   # no-op: one oracle
                           fuse_storm: bool = False,
                           storm_block: int | None = None,
                           participation: ParticipationSpec | None = None,
                           mesh=None, overlap: bool = False,
                           comm_every: dict | None = None,
                           faults=None, robustness=None, compression=None,
                           telemetry=None, stragglers=None):
    from repro.core.model_problem import _microbatch_mean

    def loss_fn(params, batch):
        def one(mb):
            l, _ = model.loss(params, mb, remat=remat, use_flash=use_flash,
                              use_lru_kernel=use_lru_kernel)
            return l.astype(jnp.float32)
        return _microbatch_mean(one, batch, n_micro)

    M = cfg.num_clients
    aspec = _aspec("fedavg", comm_every)._replace(beta=momentum)

    def oracle(v, batch):
        return {"params": jax.grad(loss_fn)(v["params"], batch["train"])}

    voracle = jax.vmap(oracle)
    templates = {"params": jax.eval_shape(model.init, jax.random.PRNGKey(0))}

    def init_trees(key):
        return {"params": _bcast(model.init(key), M)}

    strag, participation = _straggler_setup(cfg, stragglers,
                                            participation, fuse_storm)
    part, round_ctx, init_stale, next_stale = _participation_setup(
        cfg, aspec, participation, fuse_storm)
    shard = _shard_setup(mesh, overlap, fuse_storm)
    fault, robust = _fault_setup(cfg, faults, robustness, fuse_storm)
    comp = _compress_setup(compression, fuse_storm)
    tel = _telemetry_setup(telemetry, fuse_storm)

    if fuse_storm:
        def to_state(vt, mt, step):
            return FedAvgTrainState(vt["params"], mt["mom"], step)

        return _make_flat_pair(cfg, aspec, templates, voracle, init_trees,
                               storm_block, to_state, part, shard, overlap,
                               fault, robust, comp, tel, strag)

    def init(key):
        tr = init_trees(key)
        return FedAvgTrainState(tr["params"], tree_zeros_like(tr["params"]),
                                jnp.zeros((), jnp.int32), init_stale())

    def train_step(state: FedAvgTrainState, batch):
        mask, w = round_ctx(state.step, state.stale)
        grads = voracle({"params": state.params}, batch)["params"]
        mom = jax.tree.map(lambda m, gr: momentum * m + gr.astype(m.dtype),
                           state.mom, grads)
        params = jax.tree.map(lambda p, m: p - (cfg.lr_x * m).astype(p.dtype),
                              state.params, mom)
        mom = _freeze(mask, mom, state.mom)
        params = _freeze(mask, params, state.params)
        params = _comm_seqs(cfg, state.step, aspec, {"params": params},
                            weights=w)["params"]
        mom = _comm_seqs(cfg, state.step, aspec, {"params": mom},
                         weights=w)["params"]
        new = FedAvgTrainState(params, mom, state.step + 1,
                               next_stale(state.step, mask, state.stale))
        return new, {"step": new.step}

    train_step.participation = part
    return init, train_step
