"""Straggler engine: deadline-driven elastic rounds over the flat substrate.

Every round of the reproduction so far is a synchronous barrier: the server
waits for *all* sampled clients, so the round clock is the max over a
heavy-tailed per-client compute-time distribution and FedBiOAcc's linear
speedup in the number of clients (arXiv:2302.05412) never materializes in
wall-clock terms.  Real deployments run **elastic rounds**: over-provision
the sample, close the round at a deadline, aggregate whoever arrived, and
decide what to do with the stragglers.  This module makes that policy a
declarative, deterministic spec layer:

* :class:`StragglerSpec` — **who is slow and what the server tolerates**:
  per-(round, client) compute times are lognormal draws
  ``base_time * exp(tail * z)`` with ``z`` standard normal, pure in
  ``fold_in(fold_in(seed, round), client)`` — resume-exact, sweepable, and
  independent across rounds and clients.  The round policy is a deadline
  (``deadline``, simulated seconds), an over-provisioning margin
  (``over_provision = b`` extra clients requested on top of the sampler's
  ``m``), a quorum floor (``quorum`` as a fraction of the round's sampled
  clients), a capped deadline backoff for quorum misses (``backoff`` /
  ``max_extensions`` — the PR 6 retry-budget pattern applied to time), and
  a late-arrival policy ∈ ``{"drop", "carry", "cancel"}``.

* :func:`Stragglers.round_decision` — **the elastic round, pure and
  jit-traceable**: given the round's sampled mask and the current deadline
  scalar it returns the arrival mask (sampled clients whose compute time
  beat the effective deadline), the effective deadline after quorum
  extensions, the extension count, and the adaptively-updated next
  deadline.  Quorum misses extend the deadline through the capped backoff
  ladder ``deadline * backoff**k``; if even the last extension misses, the
  round falls back to the quorum-th order statistic of the arrival times —
  so **arrivals >= quorum holds on every accepted round by construction**
  (the invariant ``repro.telemetry.validate`` checks on the event stream).

* **Late-arrival policies** lower onto existing substrate machinery — no
  new reduction path: the round's weighted mean (``client_mean_masked``)
  always averages *arrivals only* (the arrival mask multiplies into the
  participation weights, exactly how fault dropout composes), and the
  policies differ only in the launch mask and staleness aging:

  - ``"drop"``: the straggler's work is discarded — its row is frozen
    bit-exact like a non-participant, and its staleness counter ages so a
    ``stale_discount < 1`` re-weights it on return (α^staleness).
  - ``"carry"``: the straggler keeps computing — its row advances locally,
    is excluded from this round's mean, and re-enters a later round
    α^staleness-discounted through the existing aging on
    ``FlatState.stale``.
  - ``"cancel"``: the work is aborted and forgotten — row frozen *and* no
    staleness aging (the client is treated as served).

* **Adaptive deadline controller**: the next round's deadline is an EMA
  toward the current round's ``target_percentile`` arrival-time order
  statistic, ``d' = (1 - adapt_rate) * d + adapt_rate * t_p``.  The
  deadline scalar rides :class:`~repro.optim.sequences.FlatState`
  (zero-leaf when stragglers are off), so checkpoints carry it and resume
  is bit-exact.  ``adapt_rate = 0`` keeps the deadline static.

The spec rides :class:`repro.api.Experiment` (``experiment.stragglers``),
round-trips through JSON and is ``edit()``-sweepable.  Stragglers compose
with participation (the arrival set is a subset of the sampled set), with
fault injection + robust aggregation (a client must both arrive and stay
healthy to enter the mean) and with compressed communication (a
non-arrival's error-feedback row freezes bit-exact — the ``w = 0`` path of
the top-k reduction).  With the spec absent every trajectory is
bit-identical to the pre-straggler stack.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

LATE_POLICIES = ("drop", "carry", "cancel")

#: bins of the per-round arrival histogram: arrival time over effective
#: deadline, 8 bins of width 0.25 covering [0, 2x); the last bin is open.
ARRIVAL_HIST_BINS = 8


class StragglerSpec(NamedTuple):
    """Declarative straggler process + elastic-round policy (hashable,
    JSON-friendly).

    ``base_time`` is the median per-client compute time (simulated
    seconds); ``tail`` is the lognormal sigma — 0 makes every client
    identical, >= 1 is heavy-tailed heterogeneity.  ``deadline`` is the
    initial round deadline; ``over_provision`` requests that many extra
    clients from the sampler; ``quorum`` is the minimum accepted fraction
    of the round's sampled clients; ``backoff`` / ``max_extensions`` cap
    the quorum-miss deadline ladder; ``late_policy`` routes stragglers
    (see module docstring); ``target_percentile`` / ``adapt_rate`` drive
    the adaptive deadline EMA (``adapt_rate = 0`` = static deadline);
    ``start_round`` delays the whole layer (clean synchronous warmup).
    """
    base_time: float = 1.0
    tail: float = 1.0
    deadline: float = 2.0
    over_provision: int = 2
    quorum: float = 0.5
    late_policy: str = "drop"
    backoff: float = 1.5
    max_extensions: int = 2
    target_percentile: float = 0.9
    adapt_rate: float = 0.2
    seed: int = 0
    start_round: int = 0


class Stragglers(NamedTuple):
    """A compiled :class:`StragglerSpec`:

    * ``round_times(round)`` — the round's [M] f32 compute times.
    * ``round_decision(round, sampled, deadline)`` — the elastic round:
      ``(arrivals [M] f32 in {0,1}, eff_deadline, extensions,
      next_deadline)``; jit-traceable in every argument.
    * ``quorum_count(sampled)`` — the round's integer quorum floor.
    """
    spec: StragglerSpec
    num_clients: int
    round_times: Any
    round_decision: Any
    quorum_count: Any


def make_stragglers(spec: StragglerSpec | None,
                    num_clients: int) -> Stragglers | None:
    """Compile ``spec`` for ``num_clients`` clients (None passes through —
    the stragglers-off fast path keeps the synchronous engine exact)."""
    if spec is None:
        return None
    if spec.late_policy not in LATE_POLICIES:
        raise ValueError(f"StragglerSpec.late_policy={spec.late_policy!r} "
                         f"must be one of {LATE_POLICIES}")
    if not float(spec.base_time) > 0.0:
        raise ValueError(f"StragglerSpec.base_time={spec.base_time} must be > 0")
    if float(spec.tail) < 0.0:
        raise ValueError(f"StragglerSpec.tail={spec.tail} must be >= 0")
    if not float(spec.deadline) > 0.0:
        raise ValueError(f"StragglerSpec.deadline={spec.deadline} must be > 0")
    if int(spec.over_provision) < 0:
        raise ValueError(f"StragglerSpec.over_provision={spec.over_provision} "
                         f"must be >= 0")
    if not 0.0 < float(spec.quorum) <= 1.0:
        raise ValueError(f"StragglerSpec.quorum={spec.quorum} must be in "
                         f"(0, 1] (a fraction of the round's sampled clients)")
    if float(spec.backoff) < 1.0:
        raise ValueError(f"StragglerSpec.backoff={spec.backoff} must be >= 1")
    if int(spec.max_extensions) < 0:
        raise ValueError(f"StragglerSpec.max_extensions="
                         f"{spec.max_extensions} must be >= 0")
    if not 0.0 < float(spec.target_percentile) <= 1.0:
        raise ValueError(f"StragglerSpec.target_percentile="
                         f"{spec.target_percentile} must be in (0, 1]")
    if not 0.0 <= float(spec.adapt_rate) <= 1.0:
        raise ValueError(f"StragglerSpec.adapt_rate={spec.adapt_rate} must "
                         f"be in [0, 1]")
    if int(spec.start_round) < 0:
        raise ValueError(f"StragglerSpec.start_round={spec.start_round} "
                         f"must be >= 0")
    M = num_clients
    key0 = jax.random.PRNGKey(spec.seed)

    def round_times(round_idx):
        k = jax.random.fold_in(key0, jnp.asarray(round_idx, jnp.int32))
        z = jax.vmap(lambda c: jax.random.normal(jax.random.fold_in(k, c)))(
            jnp.arange(M, dtype=jnp.int32))
        return spec.base_time * jnp.exp(spec.tail * z)

    def quorum_count(sampled):
        n = jnp.sum((sampled > 0).astype(jnp.float32))
        return jnp.maximum(jnp.ceil(spec.quorum * n), 1.0).astype(jnp.int32)

    def round_decision(round_idx, sampled, deadline):
        t = round_times(round_idx)
        on = sampled > 0
        t_eff = jnp.where(on, t, jnp.inf)
        q = quorum_count(sampled)
        sorted_t = jnp.sort(t_eff)
        # the quorum-th order statistic: waiting exactly this long always
        # collects >= quorum arrivals — the exhausted-backoff fallback
        t_quorum = sorted_t[jnp.maximum(q - 1, 0)]
        # capped backoff ladder deadline * backoff**k, k = 0..max_extensions
        cands = deadline * spec.backoff ** jnp.arange(
            spec.max_extensions + 1, dtype=jnp.float32)
        counts = jnp.sum((t_eff[None, :] <= cands[:, None]).astype(jnp.int32),
                         axis=1)
        ok = counts >= q
        any_ok = jnp.any(ok)
        first = jnp.argmax(ok)
        eff = jnp.where(any_ok, cands[first], t_quorum)
        # max_extensions + 1 marks the exhausted ladder (fallback taken)
        ext = jnp.where(any_ok, first, spec.max_extensions + 1)
        ext = ext.astype(jnp.int32)
        arrivals = (t_eff <= eff).astype(jnp.float32)
        # adaptive controller: EMA toward this round's target-percentile
        # arrival time (the simulator knows every sampled client's time)
        n = jnp.sum(on.astype(jnp.float32))
        i_p = jnp.clip(jnp.ceil(spec.target_percentile * n), 1.0, n)
        t_p = sorted_t[i_p.astype(jnp.int32) - 1]
        next_dl = (1.0 - spec.adapt_rate) * deadline + spec.adapt_rate * t_p
        # warmup rounds stay synchronous: everyone sampled "arrives"
        active = jnp.asarray(round_idx, jnp.int32) >= spec.start_round
        arrivals = jnp.where(active, arrivals, sampled)
        eff = jnp.where(active, eff, 0.0)
        ext = jnp.where(active, ext, 0)
        next_dl = jnp.where(active, next_dl, deadline)
        return arrivals, eff, ext, next_dl

    return Stragglers(spec, M, round_times, round_decision, quorum_count)


def over_provision(spec: StragglerSpec, pspec, num_clients: int):
    """The participation spec the elastic round actually requests: with
    ``over_provision = b`` the counted samplers (uniform/weighted) request
    ``min(M, m + b)`` clients so the deadline can drop stragglers and still
    make quorum.  Full/trace samplers pass through — they do not request a
    count (the Experiment validator rejects that pairing up front)."""
    if pspec is None or int(spec.over_provision) <= 0:
        return pspec
    if getattr(pspec, "sampler", None) not in ("uniform", "weighted"):
        return pspec
    m = int(pspec.clients_per_round) or num_clients
    return pspec._replace(
        clients_per_round=min(num_clients, m + int(spec.over_provision)))


def arrival_histogram(times, arrivals_deadline, sampled):
    """[ARRIVAL_HIST_BINS] f32 histogram of the round's sampled compute
    times relative to the effective deadline: bin i counts sampled clients
    with ``t / deadline`` in ``[0.25 i, 0.25 (i + 1))`` (last bin open) —
    the in-band arrival shape behind the ``deadline`` telemetry event."""
    ratio = times / jnp.maximum(arrivals_deadline, 1e-12)
    idx = jnp.clip(jnp.floor(ratio * 4.0), 0, ARRIVAL_HIST_BINS - 1)
    idx = idx.astype(jnp.int32)
    on = (sampled > 0).astype(jnp.float32)
    one_hot = jax.nn.one_hot(idx, ARRIVAL_HIST_BINS, dtype=jnp.float32)
    return jnp.sum(one_hot * on[:, None], axis=0)


def simulate_rounds(strag: Stragglers, part, num_rounds: int) -> list:
    """Host-side replay of the elastic round clock — the same pure
    :func:`round_decision` the engine traces, stepped over rounds with the
    adaptive deadline threaded through.  Per round:

    * ``deadline`` — the effective (post-extension) accept threshold;
    * ``wall_clock`` — the simulated round duration ``min(deadline,
      slowest sampled arrival)`` (a round closes early once everyone is
      in);
    * ``wait_for_slowest`` — what a synchronous barrier would have paid;
    * ``arrivals`` / ``sampled`` / ``quorum`` / ``extensions``.

    Benchmarks sum ``wall_clock`` vs ``wait_for_slowest`` to price the
    elastic round against the synchronous one on identical draws."""
    M = strag.num_clients
    dl = jnp.asarray(strag.spec.deadline, jnp.float32)
    rows = []
    for r in range(num_rounds):
        if part is not None:
            sampled, _ = part.round_weights(r)
        else:
            sampled = jnp.ones((M,), jnp.float32)
        arrivals, eff, ext, next_dl = strag.round_decision(r, sampled, dl)
        t = strag.round_times(r)
        slow = float(jnp.max(jnp.where(sampled > 0, t, -jnp.inf)))  # analysis: ignore[L303] reporting
        eff_f = float(eff)
        active = r >= strag.spec.start_round
        rows.append({
            "round": r,
            "deadline": round(eff_f, 6),
            "wall_clock": round(min(eff_f, slow) if active else slow, 6),
            "wait_for_slowest": round(slow, 6),
            "arrivals": int(jnp.sum(arrivals > 0)),  # analysis: ignore[L303] reporting
            "sampled": int(jnp.sum(sampled > 0)),  # analysis: ignore[L303] reporting
            "quorum": int(strag.quorum_count(sampled)),
            "extensions": int(ext),
        })
        dl = next_dl
    return rows


def expected_arrival_fraction(strag: Stragglers | None, part,
                              num_rounds: int = 64) -> float:
    """Mean fraction of sampled clients that beat the deadline over the
    first ``num_rounds`` elastic rounds (benchmarks / banners)."""
    if strag is None:
        return 1.0
    rows = simulate_rounds(strag, part, num_rounds)
    num = sum(r["arrivals"] for r in rows)
    den = max(sum(r["sampled"] for r in rows), 1)
    return round(num / den, 4)
