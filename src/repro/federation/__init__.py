from repro.federation.trainer import (make_fedavg_train_step,  # noqa: F401
                                      make_fedbio_local_train_step,
                                      make_fedbio_train_step,
                                      make_fedbioacc_local_train_step,
                                      make_fedbioacc_train_step)
from repro.federation.evaluate import eval_federated, perplexity  # noqa: F401
