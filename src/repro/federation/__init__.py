from repro.federation.trainer import (make_fedavg_train_step,  # noqa: F401
                                      make_fedbio_local_train_step,
                                      make_fedbio_train_step,
                                      make_fedbioacc_local_train_step,
                                      make_fedbioacc_train_step)
from repro.federation.evaluate import eval_federated, perplexity  # noqa: F401
from repro.federation.faults import (AGGREGATORS, Faults,  # noqa: F401
                                     FaultSpec, RobustnessSpec,
                                     RollbackError, RollbackGuard,
                                     expected_fault_fraction, make_faults)
from repro.federation.participation import (Participation,  # noqa: F401
                                            ParticipationSpec,
                                            expected_comm_fraction,
                                            make_participation)
