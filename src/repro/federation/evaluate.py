"""Evaluation utilities for federated bilevel training runs.

Per-client and pooled metrics over held-out streams:

* ``perplexity`` — exp(CE) of the LM on a client's validation stream;
* ``personalisation_gain`` — Eq. (5) diagnostics: loss of client m's
  *own* head vs the average head on m's data (positive gain = the private
  lower-level solutions y^(m) are doing real per-client work — the paper's
  motivation for the local-lower formulation).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.registry import Model


def client_loss(model: Model, body, head, batch) -> float:
    loss, _ = model.loss({"body": body, "head": head}, batch)
    return float(loss)


def perplexity(model: Model, body, head, batch) -> float:
    return float(jnp.exp(jnp.minimum(  # analysis: ignore[L303] reporting
        jnp.asarray(client_loss(model, body, head, batch)), 20.0)))


def eval_federated(model: Model, state, batch_fn, key, *,
                   num_clients: int) -> Dict[str, Any]:
    """Evaluate a federated train state (any of the trainer state types).

    Returns pooled and per-client val loss/perplexity, plus the
    personalisation gain when heads are private (local-lower states).

    Both metrics are a single ``vmap`` over the client axis (two batched
    loss traces total) — not O(M) separate per-client ``model.loss`` traces.
    """
    batch = batch_fn(key)
    if hasattr(state, "params"):          # FedAvg
        bodies, heads = state.params["body"], state.params["head"]
    else:
        bodies, heads = state.x, state.y

    def one_loss(body, head, b):
        loss, _ = model.loss({"body": body, "head": head}, b)
        return loss

    # per-client loss of each client's own head on its own stream
    losses = jax.vmap(one_loss)(bodies, heads, batch["val"])
    # average head (what Eq. (1) would deploy) evaluated on each client
    avg_head = jax.tree.map(lambda v: jnp.mean(v, axis=0), heads)
    losses_avg = jax.vmap(lambda body, b: one_loss(body, avg_head, b))(
        bodies, batch["val"])
    gains = losses_avg - losses

    assert losses.shape == (num_clients,), losses.shape
    return {
        "val_loss_mean": float(jnp.mean(losses)),  # analysis: ignore[L303] reporting
        "val_loss_per_client": [round(float(l), 4) for l in losses],
        "perplexity_mean": float(jnp.mean(jnp.exp(jnp.minimum(losses, 20.0)))),  # analysis: ignore[L303] reporting
        "personalisation_gain_mean": float(jnp.mean(gains)),  # analysis: ignore[L303] reporting
    }
