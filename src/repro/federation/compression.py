"""Declarative compressed-communication policies + the analytic bytes model.

The paper's entire pitch is communication complexity; this module makes the
per-round bytes a spec knob.  A :class:`CompressionSpec` rides
``Experiment.compression`` (JSON round-trip, ``edit()``-sweepable) and is
lowered by ``sequences.make_engine`` into the substrate-local
``optim.flat.CompressCfg`` that ``client_mean_masked`` consumes — the same
layering as ``RobustnessSpec`` → ``RobustCfg``, keeping the substrate
import-free of this package.

Two composing mechanisms, both per ``block``-sized tile:

* **quantization** (``quant``): what every client sends — and, on the
  sharded path, the dtype the ``psum`` / ``psum_scatter`` collective
  actually moves — is cast to bf16, or int8 with one f32 scale per tile.
* **top-k sparsification** (``topk_frac``): each client keeps only the
  top ``ceil(topk_frac · block)`` entries of every tile by magnitude and
  accumulates what it dropped in a per-client **error-feedback** buffer
  (added back into the next round's send), carried on
  ``FlatState.ef`` — f32 buffers shaped exactly like the communicated
  buffers, so sharding rules, participation masking and checkpointing
  inherit it for free and compressed runs stay resume-bit-exact.
  Per-tile (block-balanced) selection is a deliberate variant of global
  top-k: it is identical under every mesh partitioning (tiles are the
  shard quantum) and matches the quantizer's scale granularity.

The analytic bytes model below is the one the benchmarks record and the
dryrun HLO stats are checked against.  Two numbers matter:

* **uplink** bytes/element — what one client logically ships to the server
  per communicated element (values + top-k indices + scales).  This is the
  federated-bytes headline: top-k shrinks it by ~1/topk_frac.
* **wire** bytes/element — what one SPMD all-reduce moves per element.
  Partial sums are dense, so sparsity does NOT shrink a psum; only the
  narrow dtype does (int8 ≈ 3.94x at block=256 — strictly < 4x because of
  the per-tile f32 scale exchange).  Backend caveat, audited by the dryrun
  HLO check: the host CPU backend has no native bf16 reduce and re-widens
  bf16 all-reduces to f32 (TPU keeps them bf16); int8 collectives are
  integer and no backend promotes them.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

QUANTS = (None, "bf16", "int8")

_VALUE_BYTES = {None: 4.0, "bf16": 2.0, "int8": 1.0}
_SCALE_BYTES = 4.0      # one f32 scale per tile (int8 only)
_INDEX_BYTES = 2.0      # int16 intra-tile index per surviving top-k entry


class CompressionSpec(NamedTuple):
    """Compressed comm policy of an experiment (``Experiment.compression``).

    ``quant``: ``None`` | ``"bf16"`` | ``"int8"`` — reduction dtype.
    ``topk_frac``: fraction of each tile's entries every client keeps
    (0 disables sparsification; must be < 1).
    ``error_feedback``: accumulate the dropped mass per client and re-send
    it next round (the convergence-critical half of top-k — disabling it
    is allowed but is the documented divergence row of the benchmarks).
    ``sections``: section names to compress (``None`` = every communicated
    section; private sections are never compressed — validated).
    """
    quant: Optional[str] = None
    topk_frac: float = 0.0
    error_feedback: bool = True
    sections: Optional[Tuple[str, ...]] = None


def uplink_bytes_per_elem(spec: CompressionSpec, block: int) -> float:
    """Analytic per-client uplink bytes per communicated element.

    Exact f32 is 4.0.  Top-k ships ``topk_frac`` of the entries, each as a
    (value, int16 intra-tile index) pair; int8 adds one f32 scale per tile
    (4/block per element).  bf16 halves the value bytes."""
    v = _VALUE_BYTES[spec.quant]
    s = _SCALE_BYTES / block if spec.quant == "int8" else 0.0
    f = float(spec.topk_frac or 0.0)
    if f > 0:
        return f * (v + _INDEX_BYTES) + s
    return v + s


def wire_bytes_per_elem(spec: CompressionSpec, block: int) -> float:
    """Bytes per element ONE SPMD all-reduce moves for a compressed run.

    Dense in the reduction dtype — per-shard partial sums of sparsified
    sends are dense, so only ``quant`` shrinks the collective (this is the
    number the dryrun HLO collective stats must agree with)."""
    v = _VALUE_BYTES[spec.quant]
    s = _SCALE_BYTES / block if spec.quant == "int8" else 0.0
    return v + s
