"""Participation engine: client sampling, availability traces, staleness.

Every algorithm on the sequence-spec engine assumed all M clients compute
oracles and enter the all-reduce every round.  Real federated deployments —
and the partial-participation analyses of arXiv:2302.05412 (linear speedup
under non-IID sampling) and arXiv:2204.13299 (momentum FBO) — operate with
m ≪ M sampled clients per round.  This module produces the per-round client
mask the whole stack threads through:

* ``flat.client_mean_masked(..., weights=)`` — the mean is over participants
  only; non-participants pass through bit-identical and never contribute;
* the gated fused updates (``flat.storm_partial_step(..., mask=)`` etc.) —
  non-participants' buffers are frozen bit-exact (masked lr = 0, STORM decay
  pinned to 1, zeroed oracle contributions);
* ``sequences.make_engine(..., participation=)`` — per-round mask + per-client
  staleness counters carried on :class:`sequences.FlatState`.

Samplers (``ParticipationSpec.sampler``)
----------------------------------------

``full``
    Every client, every round (the default — bit-identical to the
    pre-participation stack).
``uniform``
    ``clients_per_round`` clients sampled uniformly WITHOUT replacement.
``weighted``
    ``clients_per_round`` clients sampled without replacement with inclusion
    probability proportional to ``client_weights`` (data sizes) via the
    Gumbel top-k trick; the same weights also drive the weighted reduction.
``trace``
    Availability-trace process: client m is up at round r iff an independent
    uniform draw keyed on ``fold_in(fold_in(seed, r), m)`` clears
    ``availability_rate``; at least ``min_clients`` (the most-available by
    the same draws) are always kept so a round can never be empty.

    With ``trace_path`` set, the synthetic process is replaced by a
    **recorded availability log** replayed deterministically: a JSON file
    holding an [R, M] 0/1 matrix (either a plain list of per-round rows or
    ``{"masks": [...]}``), row r = the clients up in round r.  Rounds beyond
    R wrap around (replay is cyclic), so a short recorded window drives an
    arbitrarily long run reproducibly (``--availability-trace`` on the
    train CLI).

Determinism / resumability: every mask is a pure function of
``fold_in(PRNGKey(seed), round)`` (or a pure table lookup for recorded
traces) — no sampler state is carried, so a resumed run reproduces the
exact same participation sequence bit-for-bit (the round index rides the
train state's step counter).

Staleness: when a client returns after missing k rounds, sequences with a
staleness discount α < 1 weight its contribution by α^k (``stale_discount``
here is the spec-wide default; ``Sequence.staleness`` overrides per
sequence).  The counters live on ``FlatState.stale`` and are advanced by the
engine at each communication step — a checkpointed restore must carry them
back in (``engine.init_state(..., stale=)``) for a discounted trajectory to
continue exactly; the masks themselves need no state at all.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

SAMPLERS = ("full", "uniform", "weighted", "trace")


class ParticipationSpec(NamedTuple):
    """Declarative participation scenario (hashable — safe to close over)."""
    sampler: str = "full"
    clients_per_round: int = 0        # m for uniform/weighted (0 → all M)
    client_weights: tuple | None = None   # per-client data sizes (len M)
    seed: int = 0                     # availability seed (fold_in'd per round)
    availability_rate: float = 0.7    # trace: P(client up in a round)
    min_clients: int = 1              # trace: floor on participants
    stale_discount: float = 1.0       # default α for staleness discounting
    trace_path: str | None = None     # trace: recorded availability log
    #   (JSON [R, M] 0/1 rows, replayed cyclically) instead of the process


class Participation(NamedTuple):
    """A compiled spec: ``mask_fn(round) -> [M] f32`` (jit-traceable in the
    round index) plus the static per-client reduction weights."""
    spec: ParticipationSpec
    num_clients: int
    mask_fn: Any                      # round_idx (traced int32 OK) -> [M] f32
    base_weights: Any                 # [M] f32 — data-size weights (ones)

    def round_weights(self, round_idx):
        """(mask, weights) for a round: weights = mask · base (zero for
        non-participants) — what the weighted reductions consume."""
        mask = self.mask_fn(round_idx)
        return mask, mask * self.base_weights


def _load_trace(path: str, num_clients: int, min_clients: int):
    """Recorded availability log → [R, M] f32 replay table.

    Accepts a JSON list of per-round 0/1 rows or ``{"masks": [...]}``; each
    row must have one entry per client and at least ``min_clients``
    participants (the same floor the synthetic process enforces — an empty
    round would make the participants-only mean undefined)."""
    import json
    with open(path) as fh:
        payload = json.load(fh)
    rows = payload["masks"] if isinstance(payload, dict) else payload
    arr = np.asarray(rows, np.float32)  # analysis: ignore[L303] trace load
    if arr.ndim != 2 or arr.shape[1] != num_clients:
        raise ValueError(
            f"availability trace {path}: expected an [R, {num_clients}] 0/1 "
            f"matrix (one row per round, one entry per client), got shape "
            f"{arr.shape}")
    if not np.all((arr == 0) | (arr == 1)):
        raise ValueError(f"availability trace {path}: entries must be 0/1")
    if not np.all(arr.sum(axis=1) >= min_clients):
        worst = int(np.argmin(arr.sum(axis=1)))
        raise ValueError(
            f"availability trace {path}: round {worst} has "
            f"{int(arr[worst].sum())} participants, below "
            f"min_clients={min_clients}")
    return jnp.asarray(arr)


def _resolve_m(spec: ParticipationSpec, num_clients: int) -> int:
    m = spec.clients_per_round or num_clients
    if not 1 <= m <= num_clients:
        raise ValueError(
            f"clients_per_round={spec.clients_per_round} out of range for "
            f"M={num_clients}")
    return m


def make_participation(spec: ParticipationSpec | None,
                       num_clients: int) -> Participation | None:
    """Compile ``spec`` for ``num_clients`` clients (None passes through —
    the no-participation fast path keeps the pre-participation code exact)."""
    if spec is None:
        return None
    if spec.sampler not in SAMPLERS:
        raise ValueError(f"unknown sampler {spec.sampler!r}; "
                         f"choose from {SAMPLERS}")
    if spec.trace_path is not None and spec.sampler != "trace":
        raise ValueError(
            f"trace_path is a sampler='trace' knob (got {spec.sampler!r})")
    M = num_clients
    if spec.client_weights is not None:
        if len(spec.client_weights) != M:
            raise ValueError(f"client_weights has {len(spec.client_weights)} "
                             f"entries for M={M}")
        base_w = jnp.asarray(np.asarray(spec.client_weights, np.float32))  # analysis: ignore[L303] spec build
        if not bool(jnp.all(base_w > 0)):
            raise ValueError("client_weights must be positive")
    elif spec.sampler == "weighted":
        # without weights the Gumbel top-k degenerates to uniform sampling —
        # refuse rather than silently not doing what "weighted" promises
        raise ValueError("sampler='weighted' requires client_weights "
                         "(per-client data sizes)")
    else:
        base_w = jnp.ones((M,), jnp.float32)
    key0 = jax.random.PRNGKey(spec.seed)

    if spec.sampler == "full":
        def mask_fn(round_idx):
            del round_idx
            return jnp.ones((M,), jnp.float32)

    elif spec.sampler == "uniform":
        m = _resolve_m(spec, M)

        def mask_fn(round_idx):
            k = jax.random.fold_in(key0, jnp.asarray(round_idx, jnp.int32))
            perm = jax.random.permutation(k, M)
            return jnp.zeros((M,), jnp.float32).at[perm[:m]].set(1.0)

    elif spec.sampler == "weighted":
        m = _resolve_m(spec, M)
        logw = jnp.log(base_w)

        def mask_fn(round_idx):
            # Gumbel top-k == weighted sampling without replacement
            k = jax.random.fold_in(key0, jnp.asarray(round_idx, jnp.int32))
            scores = logw + jax.random.gumbel(k, (M,))
            _, idx = jax.lax.top_k(scores, m)
            return jnp.zeros((M,), jnp.float32).at[idx].set(1.0)

    elif spec.sampler == "trace" and spec.trace_path is not None:
        if spec.clients_per_round:
            raise ValueError(
                "a recorded availability log drives participation directly — "
                "clients_per_round has no effect; unset it")
        if not 1 <= spec.min_clients <= M:
            raise ValueError(f"min_clients={spec.min_clients} out of range "
                             f"for M={M}")
        table = _load_trace(spec.trace_path, M, spec.min_clients)

        def mask_fn(round_idx):
            r = jnp.mod(jnp.asarray(round_idx, jnp.int32), table.shape[0])
            return table[r]

    else:  # trace (synthetic availability process)
        if spec.clients_per_round:
            raise ValueError(
                "the trace sampler draws participation from the availability "
                "process (availability_rate / min_clients) — "
                "clients_per_round has no effect; unset it or use "
                "uniform/weighted")
        if not 1 <= spec.min_clients <= M:
            raise ValueError(f"min_clients={spec.min_clients} out of range "
                             f"for M={M}")
        floor = spec.min_clients

        def mask_fn(round_idx):
            k = jax.random.fold_in(key0, jnp.asarray(round_idx, jnp.int32))
            # one independent draw per (round, client): the arrival process
            u = jax.random.uniform(k, (M,))
            up = u < spec.availability_rate
            # floor: the min_clients most-available clients by the same draws
            # are always kept, so the round is never empty — deterministic
            # given (seed, round)
            _, idx = jax.lax.top_k(-u, floor)
            return jnp.maximum(
                up.astype(jnp.float32),
                jnp.zeros((M,), jnp.float32).at[idx].set(1.0))

    return Participation(spec, M, mask_fn, base_w)


def expected_comm_fraction(part: Participation | None,
                           num_rounds: int = 64) -> float:
    """Mean fraction of clients entering the reduction per round — the
    comm-volume model's m/M factor (measured over the first ``num_rounds``
    rounds of the actual trace, not the nominal rate)."""
    if part is None:
        return 1.0
    masks = jax.vmap(part.mask_fn)(jnp.arange(num_rounds))
    return float(jnp.mean(masks))  # analysis: ignore[L303] reporting
