from repro.checkpoint.io import load_checkpoint, save_checkpoint  # noqa: F401
