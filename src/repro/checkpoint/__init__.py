from repro.checkpoint.io import (checkpoint_metadata,  # noqa: F401
                                 load_checkpoint, load_experiment,
                                 save_checkpoint)
