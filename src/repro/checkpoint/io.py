"""Numpy-backed pytree checkpointing (no orbax offline).

Layout: ``<dir>/manifest.json`` (treedef + shapes/dtypes + user metadata) and
``<dir>/arrays.npz`` (flattened leaves, keyed ``a<i>``). bfloat16 leaves are
bit-cast to uint16 for npz compatibility and restored on load.

Passing ``experiment=`` (a :class:`repro.api.Experiment`) additionally
writes ``<dir>/experiment.json`` — the full declarative run spec — so a
checkpoint is self-describing: ``load_experiment(ckpt_dir)`` +
``repro.api.build`` reconstruct the exact run (state structure included,
via ``jax.eval_shape(run.init, ...)``) with zero re-specified flags
(``launch.train --resume ckpt_dir``).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

EXPERIMENT_FILE = "experiment.json"


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(ckpt_dir: str, tree: Any, metadata: Optional[Dict] = None,
                    *, experiment: Any = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    if experiment is not None:
        experiment.save(os.path.join(ckpt_dir, EXPERIMENT_FILE))
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays, manifest_leaves = {}, []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype = "bfloat16"
        arrays[f"a{i}"] = arr
        manifest_leaves.append({"path": _path_str(path), "dtype": dtype,
                                "shape": list(arr.shape)})
    np.savez(os.path.join(ckpt_dir, "arrays.npz"), **arrays)
    with open(os.path.join(ckpt_dir, "manifest.json"), "w") as fh:
        json.dump({"leaves": manifest_leaves, "metadata": metadata or {},
                   "treedef": str(treedef)}, fh, indent=1)


def load_checkpoint(ckpt_dir: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with np.load(os.path.join(ckpt_dir, "arrays.npz")) as data:
        with open(os.path.join(ckpt_dir, "manifest.json")) as fh:
            manifest = json.load(fh)
        leaves, treedef = jax.tree_util.tree_flatten(like)
        if len(leaves) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target structure has {len(leaves)}")
        out = []
        for i, (leaf, meta) in enumerate(zip(leaves, manifest["leaves"])):
            arr = data[f"a{i}"]
            if meta["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)


def checkpoint_metadata(ckpt_dir: str) -> Dict:
    with open(os.path.join(ckpt_dir, "manifest.json")) as fh:
        return json.load(fh)["metadata"]


def load_experiment(ckpt_dir: str):
    """The :class:`repro.api.Experiment` embedded in a checkpoint, or None
    for spec-less (pre-redesign) checkpoints."""
    path = os.path.join(ckpt_dir, EXPERIMENT_FILE)
    if not os.path.exists(path):
        return None
    from repro.api.spec import Experiment
    return Experiment.load(path)
