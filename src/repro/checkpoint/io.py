"""Numpy-backed pytree checkpointing (no orbax offline).

Layout: ``<dir>/manifest.json`` (treedef + shapes/dtypes + user metadata +
the name of the arrays file it points at) and ``<dir>/arrays-<step>.npz``
(flattened leaves, keyed ``a<i>``). bfloat16 leaves are bit-cast to uint16
for npz compatibility and restored on load.

Writes are **atomic at the manifest**: the arrays file is written first
under a fresh token name (temp file + fsync + ``os.replace``), then the
manifest — the single commit point — is swapped in the same way, and only
then are stale arrays files pruned.  A crash at ANY byte of the sequence
leaves the previous (manifest, arrays) pair fully intact, which is what
lets ``launch.train --max-restarts`` kill-and-resume safely mid-write.
Old-style checkpoints (no ``arrays`` key in the manifest) fall back to
``arrays.npz``.

Passing ``experiment=`` (a :class:`repro.api.Experiment`) additionally
writes ``<dir>/experiment.json`` — the full declarative run spec — so a
checkpoint is self-describing: ``load_experiment(ckpt_dir)`` +
``repro.api.build`` reconstruct the exact run (state structure included,
via ``jax.eval_shape(run.init, ...)``) with zero re-specified flags
(``launch.train --resume ckpt_dir``).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

EXPERIMENT_FILE = "experiment.json"


class CheckpointCorruptError(RuntimeError):
    """An arrays file's bytes do not match the sha256 digest recorded in
    the manifest — the checkpoint was corrupted at rest (bit rot, a torn
    copy, tampering).  The message names the corrupt file."""


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as fh:
        for chunk in iter(lambda: fh.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _atomic_replace(path: str, write_fn):
    """Write via ``write_fn(open file)`` into a sibling temp file, fsync it,
    and ``os.replace`` over ``path`` — readers see the old bytes or the new
    bytes, never a partial write."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        write_fn(fh)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def save_checkpoint(ckpt_dir: str, tree: Any, metadata: Optional[Dict] = None,
                    *, experiment: Any = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    if experiment is not None:
        _atomic_replace(os.path.join(ckpt_dir, EXPERIMENT_FILE),
                        lambda fh: fh.write(
                            (experiment.to_json() + "\n").encode()))
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays, manifest_leaves = {}, []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype = "bfloat16"
        arrays[f"a{i}"] = arr
        manifest_leaves.append({"path": _path_str(path), "dtype": dtype,
                                "shape": list(arr.shape)})
    # token-named arrays file first, manifest (the commit point) last; prune
    # superseded arrays files only after the manifest points at the new one
    arrays_name = f"arrays-{int((metadata or {}).get('step', 0)):08d}.npz"
    arrays_path = os.path.join(ckpt_dir, arrays_name)
    _atomic_replace(arrays_path, lambda fh: np.savez(fh, **arrays))
    # integrity digest of the *committed* bytes: load verifies it before
    # deserializing, so silent at-rest corruption fails loudly by file name
    manifest = {"leaves": manifest_leaves, "metadata": metadata or {},
                "treedef": str(treedef), "arrays": arrays_name,
                "sha256": {arrays_name: _sha256(arrays_path)}}
    _atomic_replace(os.path.join(ckpt_dir, "manifest.json"),
                    lambda fh: fh.write(json.dumps(manifest, indent=1)
                                        .encode()))
    for name in os.listdir(ckpt_dir):
        if (name.startswith("arrays") and name != arrays_name
                and (name.endswith(".npz") or name.endswith(".tmp"))):
            os.remove(os.path.join(ckpt_dir, name))


def load_checkpoint(ckpt_dir: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with open(os.path.join(ckpt_dir, "manifest.json")) as fh:
        manifest = json.load(fh)
    arrays_name = manifest.get("arrays", "arrays.npz")
    arrays_path = os.path.join(ckpt_dir, arrays_name)
    # pre-digest manifests (older checkpoints) skip the check
    want = (manifest.get("sha256") or {}).get(arrays_name)
    if want is not None:
        got = _sha256(arrays_path)
        if got != want:
            raise CheckpointCorruptError(
                f"checkpoint arrays file {arrays_path} is corrupt: sha256 "
                f"{got[:16]}... does not match the manifest's "
                f"{want[:16]}... — the file was damaged at rest (bit rot, "
                f"torn copy, tampering); restore it from a replica or "
                f"delete the checkpoint and restart from an earlier one")
    with np.load(arrays_path) as data:
        leaves, treedef = jax.tree_util.tree_flatten(like)
        if len(leaves) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target structure has {len(leaves)}")
        out = []
        for i, (leaf, meta) in enumerate(zip(leaves, manifest["leaves"])):
            arr = data[f"a{i}"]
            if meta["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)


def checkpoint_metadata(ckpt_dir: str) -> Dict:
    with open(os.path.join(ckpt_dir, "manifest.json")) as fh:
        return json.load(fh)["metadata"]


def load_experiment(ckpt_dir: str):
    """The :class:`repro.api.Experiment` embedded in a checkpoint, or None
    for spec-less (pre-redesign) checkpoints."""
    path = os.path.join(ckpt_dir, EXPERIMENT_FILE)
    if not os.path.exists(path):
        return None
    from repro.api.spec import Experiment
    return Experiment.load(path)
