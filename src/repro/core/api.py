"""Algorithm factory: ``make_algorithm(problem, fed_cfg)``."""
from __future__ import annotations

from repro.config import FederatedConfig
from repro.core.baselines import (make_commfedbio, make_fednest, make_mrbo,
                                  make_stocbio)
from repro.core.fedbio import Algorithm, make_fedbio
from repro.core.fedbioacc import make_fedbioacc
from repro.core.local_lower import make_fedbio_local, make_fedbioacc_local
from repro.core.problems import Problem

_FACTORIES = {
    "fedbio": make_fedbio,
    "fedbioacc": make_fedbioacc,
    "fedbio_local": make_fedbio_local,
    "fedbioacc_local": make_fedbioacc_local,
    "fednest": make_fednest,
    "commfedbio": make_commfedbio,
    "stocbio": make_stocbio,
    "mrbo": make_mrbo,
}


def make_algorithm(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    if cfg.algorithm not in _FACTORIES:
        raise KeyError(f"unknown algorithm {cfg.algorithm!r}; "
                       f"choose from {sorted(_FACTORIES)}")
    return _FACTORIES[cfg.algorithm](problem, cfg)
