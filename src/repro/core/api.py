"""DEPRECATED — absorbed into :mod:`repro.api.registry`.

``make_algorithm(problem, fed_cfg)`` (the problem-level factory for the
reference loops and Table-1 baselines) now lives in ``repro.api`` next to
the model-scale trainer registry, so every "which algorithms exist"
question has one answer.  This module remains as an import alias for
existing callers; new code should use ``repro.api.make_algorithm`` (or the
full declarative path: ``repro.api.Experiment`` + ``repro.api.build``).
"""
from __future__ import annotations

from repro.api.registry import make_algorithm  # noqa: F401
from repro.core.fedbio import Algorithm  # noqa: F401  (re-export, back-compat)
