"""Hyper-gradient machinery (matrix-free, pytree-generic).

All second-order quantities the paper needs are Hessian-/Jacobian-vector
products evaluated without materialising any matrix:

* ``hvp_yy(g, x, y, batch, u)``   = ∇²_{yy} g(x,y;ξ) · u       (u-update, Eq. 4)
* ``jvp_xy(g, x, y, batch, u)``   = ∇²_{xy} g(x,y;ξ) · u       (ν-update)
* ``neumann_hypergrad``           = Eq. 6 truncated Neumann-series estimate
  (local-lower-level algorithms 3/4)

On TPU these lower to the same MXU matmuls as the forward/backward pass —
this is the hardware adaptation of the paper's linear algebra (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.core.tree_util import tree_axpy, tree_scale, tree_sub, tree_vdot


def grad_x(f: Callable, x, y, batch):
    return jax.grad(f, argnums=0)(x, y, batch)


def grad_y(f: Callable, x, y, batch):
    return jax.grad(f, argnums=1)(x, y, batch)


def hvp_yy(g: Callable, x, y, batch, u):
    """∇²_{yy} g(x, y; batch) · u via forward-over-reverse."""
    return jax.jvp(lambda yy: jax.grad(g, argnums=1)(x, yy, batch), (y,), (u,))[1]


def jvp_xy(g: Callable, x, y, batch, u):
    """∇²_{xy} g(x, y; batch) · u  =  ∇_x ⟨∇_y g(x, y; batch), u⟩."""
    return jax.grad(lambda xx: tree_vdot(jax.grad(g, argnums=1)(xx, y, batch), u))(x)


def u_step(g: Callable, f: Callable, x, y, u, batch_g, batch_f, tau: float):
    """One local step on the quadratic problem Eq. (4):

        u ← τ ∇_y f + (I − τ ∇²_{yy} g) u  =  u − τ (∇²_{yy} g · u − ∇_y f)
    """
    residual = tree_sub(hvp_yy(g, x, y, batch_g, u), grad_y(f, x, y, batch_f))
    return tree_axpy(-tau, residual, u)


def u_residual(g: Callable, f: Callable, x, y, u, batch_g, batch_f):
    """p = ∇²_{yy} g · u − ∇_y f (the q-momentum target in FedBiOAcc)."""
    return tree_sub(hvp_yy(g, x, y, batch_g, u), grad_y(f, x, y, batch_f))


def nu_direction(g: Callable, f: Callable, x, y, u, batch_g, batch_f):
    """ν = ∇_x f(x,y;B_f) − ∇²_{xy} g(x,y;B_g) · u  (Alg. 1 line 6)."""
    return tree_sub(grad_x(f, x, y, batch_f), jvp_xy(g, x, y, batch_g, u))


def _neumann_ihvp(g: Callable, x, y, batch_g, v0, q_terms: int, tau: float):
    """The truncated series [τ Σ_{k=0}^{Q} (I − τ∇²_{yy}g)^k] v0 — Q HVPs,
    the same minibatch reused across terms (the paper samples independent
    ξ_j — the bias difference is O(τ²σ²), covered by Proposition 2's
    variance bound; noted in DESIGN.md).  Shared by the unfused and fused
    local-lower oracles so the series semantics cannot diverge."""
    v = v0
    acc = v0
    for _ in range(q_terms):
        v = tree_axpy(-tau, hvp_yy(g, x, y, batch_g, v), v)   # v ← (I − τH) v
        acc = jax.tree.map(lambda a, b: a + b, acc, v)
    return tree_scale(tau, acc)


def neumann_hypergrad(g: Callable, f: Callable, x, y, batch_g, batch_f,
                      q_terms: int, tau: float):
    """Eq. (6): Φ(x,y;ξ) = ∇_x f − ∇_xy g · [τ Σ_{k=0}^{Q} (I − τ∇²_{yy}g)^k] ∇_y f."""
    ihvp = _neumann_ihvp(g, x, y, batch_g, grad_y(f, x, y, batch_f),
                         q_terms, tau)
    return tree_sub(grad_x(f, x, y, batch_f), jvp_xy(g, x, y, batch_g, ihvp))


def exact_hypergrad_quadratic(problem, x, y):
    """For tests: closed-form Φ(x, y_x) when the problem exposes it."""
    return problem.exact_hypergrad(x, y)


# ---------------------------------------------------------------------------
# Fused oracles (beyond-paper §Perf optimization)
# ---------------------------------------------------------------------------

def fused_g_oracles(g: Callable, x, y, batch, u):
    """(∇_y g, ∇²_xy g·u, ∇²_yy g·u) from ONE forward-over-reverse
    linearization of ∇_{(x,y)} g with tangent (0, u).

    Mathematically identical to the three separate calls (same minibatch);
    structurally it shares the forward/backward pass, which cuts the number
    of weight-streaming passes (and, under FSDP, weight all-gathers) from 3
    to ~2 per oracle point — the dominant roofline term for the
    client_replicated giants. See EXPERIMENTS.md §Perf.
    """
    from repro.core.tree_util import tree_zeros_like

    def grads(xx, yy):
        return jax.grad(g, argnums=(0, 1))(xx, yy, batch)

    (_, gy), (txy, tyy) = jax.jvp(grads, (x, y), (tree_zeros_like(x), u))
    return gy, txy, tyy


def fused_local_oracles(g: Callable, f: Callable, x, y, batch,
                        q_terms: int, tau: float):
    """Both local-lower-level oracle directions (ω, Neumann hyper-gradient Φ)
    from shared linearizations on ONE minibatch:

        ω = ∇_y g
        Φ = ∇_x f − ∇²_xy g · [τ Σ_{k=0}^{Q} (I − τ∇²_{yy}g)^k] ∇_y f

    vs the unfused pair (``grad_y`` + ``neumann_hypergrad``) this shares
    (a) one ∇_{(x,y)} f call for ∇_x f and the series seed ∇_y f, and
    (b) one forward-over-reverse linearization of ∇_{(x,y)} g for ω together
    with the final ∇²_xy g·ihvp contraction — cutting the full
    weight-streaming passes per oracle point from ~5 to ~3 (the Q series
    HVPs are unchanged).  Mathematically identical on a shared minibatch.
    """
    from repro.core.tree_util import tree_zeros_like

    fx, fy = jax.grad(f, argnums=(0, 1))(x, y, batch)
    ihvp = _neumann_ihvp(g, x, y, batch, fy, q_terms, tau)

    def grads(xx, yy):
        return jax.grad(g, argnums=(0, 1))(xx, yy, batch)

    (_, omega), (txy, _) = jax.jvp(grads, (x, y),
                                   (tree_zeros_like(x), ihvp))
    return omega, tree_sub(fx, txy)


def fused_oracles(g: Callable, f: Callable, x, y, u, batch):
    """All three FedBiO oracle directions (ω, ν-target μ, u-residual p) from
    two linearizations (one of g, one of f) sharing a single minibatch:

        ω = ∇_y g
        μ = ∇_x f − ∇²_xy g·u
        p = ∇²_yy g·u − ∇_y f
    """
    omega, txy, tyy = fused_g_oracles(g, x, y, batch, u)
    fx, fy = jax.grad(f, argnums=(0, 1))(x, y, batch)
    mu = tree_sub(fx, txy)
    p = tree_sub(tyy, fy)
    return omega, mu, p
