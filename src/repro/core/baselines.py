"""Baseline algorithms the paper compares against (Table 1).

* :func:`make_fednest` — FedNest [43]-style: every round solves the inner
  problem and the hyper-gradient quadratic with *per-step averaging* (i.e. the
  full hyper-gradient is evaluated jointly every round) → far more
  communication per round.
* :func:`make_commfedbio` — CommFedBiO [29]-style: hyper-gradient evaluated
  every iteration, communicated every iteration with top-k compression.
* :func:`make_stocbio` — StocBiO [20] (non-federated reference): runs on the
  pooled problem, i.e. clients average after **every** step (equivalent to
  centralized minibatch SGD with M-fold batch).
* :func:`make_mrbo` — MRBO [50] (non-federated momentum-based reference).

All share the :class:`repro.core.fedbio.Algorithm` interface so benchmarks
can sweep them uniformly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.fedbio import Algorithm, FedBiOState, _broadcast_clients
from repro.core.problems import Problem
from repro.core.tree_util import (client_mean, tree_axpy, tree_size,
                                  tree_zeros_like)


# ---------------------------------------------------------------------------
# FedNest-style
# ---------------------------------------------------------------------------

def make_fednest(problem: Problem, cfg: FederatedConfig, *, inner_steps=None,
                 u_steps=None) -> Algorithm:
    """Each round: N_y averaged y-steps, N_u averaged u-steps, 1 averaged
    x-step. Every sub-step is a communication (per-step averaging). This is
    the "solve Eq. (4) exactly every iteration" strategy the paper improves
    on — same oracle calls, ~(N_y + N_u + 1)× the communication."""
    M = problem.num_clients
    f, g = problem.f, problem.g
    N_y = inner_steps or cfg.local_steps
    N_u = u_steps or cfg.local_steps

    def init(key):
        x1, y1 = problem.init_xy(key)
        return FedBiOState(
            _broadcast_clients(x1, M), _broadcast_clients(y1, M),
            _broadcast_clients(tree_zeros_like(y1), M), jnp.zeros((), jnp.int32))

    v_grad_y = jax.vmap(lambda x, y, b: hg.grad_y(g, x, y, b))
    v_ustep = jax.vmap(lambda x, y, u, bg, bf: hg.u_step(g, f, x, y, u, bg, bf, cfg.lr_u))
    v_nu = jax.vmap(lambda x, y, u, bg, bf: hg.nu_direction(g, f, x, y, u, bg, bf))

    def round(state, key):
        x, y, u = state.x, state.y, state.u
        k_y, k_u, k_x = jax.random.split(key, 3)

        def y_body(yc, k):
            omega = v_grad_y(x, yc, problem.sample_batches(k))
            yc = jax.tree.map(lambda v, o: v - cfg.lr_y * o, yc, omega)
            return client_mean(yc), None                      # per-step averaging

        y, _ = lax.scan(y_body, y, jax.random.split(k_y, N_y))

        def u_body(uc, k):
            k1, k2 = jax.random.split(k)
            uc = v_ustep(x, y, uc, problem.sample_batches(k1),
                         problem.sample_batches(k2))
            return client_mean(uc), None                      # per-step averaging

        u, _ = lax.scan(u_body, u, jax.random.split(k_u, N_u))

        k1, k2 = jax.random.split(k_x)
        nu = v_nu(x, y, u, problem.sample_batches(k1), problem.sample_batches(k2))
        nu = client_mean(nu)
        x = jax.tree.map(lambda v, n: v - cfg.lr_x * n, x, nu)
        new = FedBiOState(x, y, u, state.t + 1)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, y1 = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    comm = N_y * tree_size(y1) + N_u * tree_size(y1) + tree_size(x1)
    return Algorithm("fednest", init, round, comm, mean_x)


# ---------------------------------------------------------------------------
# CommFedBiO-style (per-step compressed hyper-gradient communication)
# ---------------------------------------------------------------------------

def _topk_compress(tree, ratio: float):
    """Keep the top-|ratio| fraction of entries (by magnitude) of each leaf."""
    def comp(v):
        flat = v.reshape(-1)
        k = max(1, int(flat.size * ratio))
        thresh = lax.top_k(jnp.abs(flat), k)[0][-1]
        return (jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)).reshape(v.shape)
    return jax.tree.map(comp, tree)


def make_commfedbio(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    M = problem.num_clients
    f, g = problem.f, problem.g

    class S(NamedTuple):
        x: Any
        y: Any
        e: Any            # error-feedback memory for the compressor
        t: jnp.ndarray

    def init(key):
        x1, y1 = problem.init_xy(key)
        return S(_broadcast_clients(x1, M), _broadcast_clients(y1, M),
                 _broadcast_clients(tree_zeros_like(x1), M),
                 jnp.zeros((), jnp.int32))

    v_grad_y = jax.vmap(lambda x, y, b: hg.grad_y(g, x, y, b))
    v_phi = jax.vmap(lambda x, y, bg, bf: hg.neumann_hypergrad(
        g, f, x, y, bg, bf, cfg.neumann_q, cfg.neumann_tau))

    def round(state, key):
        def body(carry, k):
            x, y, e = carry
            k1, k2, k3 = jax.random.split(k, 3)
            omega = v_grad_y(x, y, problem.sample_batches(k1))
            y = jax.tree.map(lambda v, o: v - cfg.lr_y * o, y, omega)
            y = client_mean(y)
            phi = v_phi(x, y, problem.sample_batches(k2), problem.sample_batches(k3))
            # top-k compression with error feedback (EF-SGD style)
            target = jax.tree.map(jnp.add, phi, e)
            comp = _topk_compress(target, cfg.compress_ratio)  # compressed upload
            e = jax.tree.map(jnp.subtract, target, comp)
            comp = client_mean(comp)
            x = jax.tree.map(lambda v, n: v - cfg.lr_x * n, x, comp)
            return (x, y, e), None

        # one "round" = I iterations for parity with FedBiO's round length,
        # but every iteration communicates.
        (x, y, e), _ = lax.scan(body, (state.x, state.y, state.e),
                                jax.random.split(key, cfg.local_steps))
        new = S(x, y, e, state.t + cfg.local_steps)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, y1 = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    comm = cfg.local_steps * (tree_size(y1)
                              + int(tree_size(x1) * cfg.compress_ratio) * 2)
    return Algorithm("commfedbio", init, round, comm, mean_x)


# ---------------------------------------------------------------------------
# Non-federated references (pooled data): StocBiO, MRBO
# ---------------------------------------------------------------------------

def make_stocbio(problem: Problem, cfg: FederatedConfig, *, inner_steps=4) -> Algorithm:
    M = problem.num_clients
    f, g = problem.f, problem.g

    class S(NamedTuple):
        x: Any
        y: Any
        t: jnp.ndarray

    def init(key):
        x1, y1 = problem.init_xy(key)
        return S(_broadcast_clients(x1, M), _broadcast_clients(y1, M),
                 jnp.zeros((), jnp.int32))

    v_grad_y = jax.vmap(lambda x, y, b: hg.grad_y(g, x, y, b))
    v_phi = jax.vmap(lambda x, y, bg, bf: hg.neumann_hypergrad(
        g, f, x, y, bg, bf, cfg.neumann_q, cfg.neumann_tau))

    def round(state, key):
        x, y = state.x, state.y
        k_in, k1, k2 = jax.random.split(key, 3)

        def y_body(yc, k):
            omega = v_grad_y(x, yc, problem.sample_batches(k))
            yc = jax.tree.map(lambda v, o: v - cfg.lr_y * o, yc, omega)
            return client_mean(yc), None

        y, _ = lax.scan(y_body, y, jax.random.split(k_in, inner_steps))
        phi = client_mean(v_phi(x, y, problem.sample_batches(k1),
                                problem.sample_batches(k2)))
        x = jax.tree.map(lambda v, n: v - cfg.lr_x * n, x, phi)
        new = S(x, y, state.t + 1)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, y1 = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    comm = inner_steps * tree_size(y1) + tree_size(x1)
    return Algorithm("stocbio", init, round, comm, mean_x)


def make_mrbo(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    """MRBO-style single-loop momentum bilevel method on the pooled problem
    (per-step averaging ≙ centralized), used as the Non-Fed accelerated row
    of Table 1."""
    M = problem.num_clients
    f, g = problem.f, problem.g

    class S(NamedTuple):
        x: Any
        y: Any
        nu: Any
        omega: Any
        t: jnp.ndarray

    v_grad_y = jax.vmap(lambda x, y, b: hg.grad_y(g, x, y, b))
    v_phi = jax.vmap(lambda x, y, bg, bf: hg.neumann_hypergrad(
        g, f, x, y, bg, bf, cfg.neumann_q, cfg.neumann_tau))

    def alpha(t):
        return cfg.alpha_delta / (cfg.alpha_u0 + t.astype(jnp.float32)) ** (1.0 / 3.0)

    def init(key):
        k1, k2 = jax.random.split(key)
        x1, y1 = problem.init_xy(k1)
        x = _broadcast_clients(x1, M)
        y = _broadcast_clients(y1, M)
        ks = jax.random.split(k2, 3)
        omega = v_grad_y(x, y, problem.sample_batches(ks[0]))
        nu = v_phi(x, y, problem.sample_batches(ks[1]), problem.sample_batches(ks[2]))
        return S(x, y, client_mean(nu), client_mean(omega), jnp.zeros((), jnp.int32))

    def round(state, key):
        x, y, nu, omega, t = state
        a = alpha(t)
        x_new = jax.tree.map(lambda v, m: v - cfg.lr_x * a * m, x, nu)
        y_new = jax.tree.map(lambda v, m: v - cfg.lr_y * a * m, y, omega)
        x_new, y_new = client_mean(x_new), client_mean(y_new)
        ks = jax.random.split(key, 3)
        by = problem.sample_batches(ks[0])
        bg, bf = problem.sample_batches(ks[1]), problem.sample_batches(ks[2])
        o_new = v_grad_y(x_new, y_new, by)
        o_old = v_grad_y(x, y, by)
        p_new = v_phi(x_new, y_new, bg, bf)
        p_old = v_phi(x, y, bg, bf)
        ca2 = a * a
        omega = jax.tree.map(lambda gn, mo, go: gn + (1 - cfg.c_omega * ca2) * (mo - go),
                             o_new, omega, o_old)
        nu = jax.tree.map(lambda gn, mo, go: gn + (1 - cfg.c_nu * ca2) * (mo - go),
                          p_new, nu, p_old)
        omega, nu = client_mean(omega), client_mean(nu)
        return S(x_new, y_new, nu, omega, t + 1), {"t": t + 1}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, y1 = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    comm = 2 * (tree_size(x1) + tree_size(y1))
    return Algorithm("mrbo", init, round, comm, mean_x)
