"""Bilevel problem backed by a model-zoo architecture.

This is the paper's Hyper-Representation formulation scaled to the assigned
architectures: the **upper** variable x is the transformer body, the **lower**
variable y is the output head; the lower objective is the (L2-regularised,
hence μ-strongly-convex in y) training CE, the upper objective is CE on a
held-out validation stream (per client, heterogeneous).

Losses are computed with a remat'd ``lax.scan`` over microbatches so that
``jax.grad`` performs gradient accumulation with one-microbatch activation
memory — this is what makes the 405B train step lowerable.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.registry import Model


def _microbatch_mean(loss_one, batch, n_micro: int):
    """mean over microbatches of ``loss_one(microbatch)`` with remat."""
    if n_micro <= 1:
        return loss_one(batch)
    split = jax.tree.map(
        lambda v: v.reshape((n_micro, v.shape[0] // n_micro) + v.shape[1:]), batch)

    @jax.checkpoint
    def body(acc, mb):
        return acc + loss_one(mb), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), split)
    return total / n_micro


def make_model_bilevel(model: Model, *, lower_l2: float = 1e-2,
                       n_micro: int = 1, remat: bool = True,
                       use_flash: bool = False, use_lru_kernel: bool = False):
    """Returns (f, g): per-client stochastic upper/lower objectives.

    ``batch`` is a dict ``{"train": model_batch, "val": model_batch}`` for one
    client; x = body params, y = head params.
    """

    def _loss(x, y, mb):
        l, _ = model.loss({"body": x, "head": y}, mb, remat=remat,
                          use_flash=use_flash, use_lru_kernel=use_lru_kernel)
        return l.astype(jnp.float32)

    def g(x, y, batch):
        base = _microbatch_mean(lambda mb: _loss(x, y, mb), batch["train"], n_micro)
        reg = 0.5 * lower_l2 * sum(
            jnp.sum(v.astype(jnp.float32) ** 2) for v in jax.tree.leaves(y))
        return base + reg

    def f(x, y, batch):
        return _microbatch_mean(lambda mb: _loss(x, y, mb), batch["val"], n_micro)

    return f, g
