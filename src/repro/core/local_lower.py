"""Algorithms 3 & 4 — Federated Bilevel Optimization with *local* lower level
problems (Eq. 5):

    min_x (1/M) Σ_m f^(m)(x, y_x^(m)),   y_x^(m) = argmin_y g^(m)(x, y)

Each client keeps a private lower variable y^(m) (never communicated); the
local hyper-gradient Φ^(m) is *unbiased* here and is estimated with the
truncated Neumann series (Eq. 6, Q terms). Only the upper variable (Alg. 3)
— plus its STORM momentum (Alg. 4) — is averaged every I steps.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.fedbio import Algorithm, _broadcast_clients
from repro.core.problems import Problem
from repro.core.tree_util import client_mean, tree_axpy, tree_size


class FedBiOLocalState(NamedTuple):
    x: Any
    y: Any
    t: jnp.ndarray


class FedBiOAccLocalState(NamedTuple):
    x: Any
    y: Any
    omega: Any
    nu: Any
    t: jnp.ndarray


def make_fedbio_local(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    M = problem.num_clients
    f, g = problem.f, problem.g

    def init(key):
        x1, y1 = problem.init_xy(key)
        return FedBiOLocalState(
            _broadcast_clients(x1, M), _broadcast_clients(y1, M),
            jnp.zeros((), jnp.int32))

    def local_step(x, y, batches):
        by, bx_g, bx_f = batches
        omega = hg.grad_y(g, x, y, by)
        nu = hg.neumann_hypergrad(g, f, x, y, bx_g, bx_f,
                                  cfg.neumann_q, cfg.neumann_tau)
        return tree_axpy(-cfg.lr_x, nu, x), tree_axpy(-cfg.lr_y, omega, y)

    vstep = jax.vmap(local_step)

    def round(state, key):
        def body(carry, k):
            x, y = carry
            ks = jax.random.split(k, 3)
            batches = tuple(problem.sample_batches(kk) for kk in ks)
            x, y = vstep(x, y, batches)
            return (x, y), None

        keys = jax.random.split(key, cfg.local_steps)
        (x, y), _ = lax.scan(body, (state.x, state.y), keys)
        x = client_mean(x)                      # only x is communicated
        new = FedBiOLocalState(x, y, state.t + cfg.local_steps)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, _ = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    return Algorithm("fedbio_local", init, round, tree_size(x1), mean_x)


def make_fedbioacc_local(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    M = problem.num_clients
    f, g = problem.f, problem.g

    def alpha(t):
        return cfg.alpha_delta / (cfg.alpha_u0 + t.astype(jnp.float32)) ** (1.0 / 3.0)

    def oracles(x, y, batches):
        by, bx_g, bx_f = batches
        omega = hg.grad_y(g, x, y, by)
        nu = hg.neumann_hypergrad(g, f, x, y, bx_g, bx_f,
                                  cfg.neumann_q, cfg.neumann_tau)
        return omega, nu

    voracles = jax.vmap(oracles)

    def init(key):
        k1, k2 = jax.random.split(key)
        x1, y1 = problem.init_xy(k1)
        x = _broadcast_clients(x1, M)
        y = _broadcast_clients(y1, M)
        ks = jax.random.split(k2, 3)
        batches = tuple(problem.sample_batches(kk) for kk in ks)
        omega, nu = voracles(x, y, batches)
        return FedBiOAccLocalState(x, y, omega, nu, jnp.zeros((), jnp.int32))

    def round(state, key):
        def body(carry, inp):
            x, y, omega, nu, t = carry
            k, is_comm = inp
            a = alpha(t)
            x_new = jax.tree.map(lambda v, m: v - cfg.lr_x * a * m, x, nu)
            y_new = jax.tree.map(lambda v, m: v - cfg.lr_y * a * m, y, omega)
            x_new = lax.cond(is_comm, client_mean, lambda v: v, x_new)
            ks = jax.random.split(k, 3)
            batches = tuple(problem.sample_batches(kk) for kk in ks)
            o_new, n_new = voracles(x_new, y_new, batches)
            o_old, n_old = voracles(x, y, batches)
            ca2 = a * a

            def storm(new, mom, old, c):
                return jax.tree.map(
                    lambda gn, mo, go: gn + (1.0 - c * ca2) * (mo - go),
                    new, mom, old)

            omega = storm(o_new, omega, o_old, cfg.c_omega)
            nu = storm(n_new, nu, n_old, cfg.c_nu)
            nu = lax.cond(is_comm, client_mean, lambda v: v, nu)   # ν averaged too
            return (x_new, y_new, omega, nu, t + 1), None

        I = cfg.local_steps
        keys = jax.random.split(key, I)
        is_comm = jnp.arange(1, I + 1) == I
        carry = (state.x, state.y, state.omega, state.nu, state.t)
        carry, _ = lax.scan(body, carry, (keys, is_comm))
        new = FedBiOAccLocalState(*carry)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, _ = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    return Algorithm("fedbioacc_local", init, round, 2 * tree_size(x1), mean_x)
