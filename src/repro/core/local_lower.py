"""Algorithms 3 & 4 — Federated Bilevel Optimization with *local* lower level
problems (Eq. 5):

    min_x (1/M) Σ_m f^(m)(x, y_x^(m)),   y_x^(m) = argmin_y g^(m)(x, y)

Each client keeps a private lower variable y^(m) (never communicated); the
local hyper-gradient Φ^(m) is *unbiased* here and is estimated with the
truncated Neumann series (Eq. 6, Q terms). Only the upper variable (Alg. 3)
— plus its STORM momentum (Alg. 4) — is averaged every I steps.

§Perf fusion flags (FederatedConfig), mirroring ``core.fedbioacc``:

* ``fuse_oracles`` — ω and Φ from shared linearizations on ONE minibatch
  (``hypergrad.fused_local_oracles``): 1 batch/step instead of 3.
* ``fuse_storm`` — the scan carry lives on the flat-buffer substrate via the
  sequence-spec engine (``repro.optim.sequences``).  Both algorithms are
  *dual*-sequence specs with a PRIVATE y sequence: the section-masked
  communication averages only the x (and, for Alg. 4, ν) tiles — the
  private heads never enter a reduction.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.fedbio import Algorithm, _broadcast_clients
from repro.core.problems import Problem
from repro.core.tree_util import client_mean, tree_axpy, tree_size

from repro.optim import sequences as seqs


class FedBiOLocalState(NamedTuple):
    x: Any
    y: Any
    t: jnp.ndarray


class FedBiOAccLocalState(NamedTuple):
    x: Any
    y: Any
    omega: Any
    nu: Any
    t: jnp.ndarray


def _make_local_oracles(problem: Problem, cfg: FederatedConfig):
    """(sample, voracles) pair for the local-lower oracle directions (ω, Φ).

    With ``cfg.fuse_oracles`` one shared minibatch feeds both directions
    through ``hg.fused_local_oracles``; otherwise the paper's three
    independent batches (B_y, B_g, B_f) are drawn.
    """
    f, g = problem.f, problem.g
    if cfg.fuse_oracles:
        def sample(k):
            return problem.sample_batches(k)

        def oracles(x, y, b):
            return hg.fused_local_oracles(g, f, x, y, b,
                                          cfg.neumann_q, cfg.neumann_tau)
    else:
        def sample(k):
            return tuple(problem.sample_batches(kk)
                         for kk in jax.random.split(k, 3))

        def oracles(x, y, batches):
            by, bx_g, bx_f = batches
            omega = hg.grad_y(g, x, y, by)
            nu = hg.neumann_hypergrad(g, f, x, y, bx_g, bx_f,
                                      cfg.neumann_q, cfg.neumann_tau)
            return omega, nu

    return sample, jax.vmap(oracles)


def _make_local_engine(problem: Problem, cfg: FederatedConfig, voracles,
                       algo: str):
    x1s, y1s = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))

    def oracle(vt, batches):
        omega, nu = voracles(vt["x"], vt["y"], batches)
        return {"x": nu, "y": omega}

    # without_hierarchy: the reference loops always use the paper's flat
    # averaging, so fuse_storm stays a pure perf switch for any cfg
    return seqs.make_engine(cfg, seqs.SPECS[algo].without_hierarchy(),
                            {"x": x1s, "y": y1s}, oracle,
                            block=cfg.fuse_storm_block)


def make_fedbio_local(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    M = problem.num_clients
    sample, voracles = _make_local_oracles(problem, cfg)

    def init(key):
        x1, y1 = problem.init_xy(key)
        return FedBiOLocalState(
            _broadcast_clients(x1, M), _broadcast_clients(y1, M),
            jnp.zeros((), jnp.int32))

    engine = (_make_local_engine(problem, cfg, voracles, "fedbio_local")
              if cfg.fuse_storm else None)

    def round(state, key):
        keys = jax.random.split(key, cfg.local_steps)
        if cfg.fuse_storm:
            st = engine.init_state({"x": state.x, "y": state.y}, step=state.t)

            def body_flat(carry, k):
                return engine.step(carry, sample(k)), None

            st, _ = lax.scan(body_flat, st, keys)
            vt, _ = engine.views(st)
            return (FedBiOLocalState(vt["x"], vt["y"], st.step),
                    {"t": st.step})

        def body(carry, k):
            x, y = carry
            omega, nu = voracles(x, y, sample(k))
            return (tree_axpy(-cfg.lr_x, nu, x),
                    tree_axpy(-cfg.lr_y, omega, y)), None

        (x, y), _ = lax.scan(body, (state.x, state.y), keys)
        x = client_mean(x)                      # only x is communicated
        new = FedBiOLocalState(x, y, state.t + cfg.local_steps)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, _ = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    return Algorithm("fedbio_local", init, round, tree_size(x1), mean_x)


def make_fedbioacc_local(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    M = problem.num_clients
    sample, voracles = _make_local_oracles(problem, cfg)

    def alpha(t):
        return seqs.alpha_schedule(cfg, t)

    def init(key):
        k1, k2 = jax.random.split(key)
        x1, y1 = problem.init_xy(k1)
        x = _broadcast_clients(x1, M)
        y = _broadcast_clients(y1, M)
        omega, nu = voracles(x, y, sample(k2))
        return FedBiOAccLocalState(x, y, omega, nu, jnp.zeros((), jnp.int32))

    engine = (_make_local_engine(problem, cfg, voracles, "fedbioacc_local")
              if cfg.fuse_storm else None)

    def round(state, key):
        I = cfg.local_steps
        keys = jax.random.split(key, I)
        if cfg.fuse_storm:
            st = engine.init_state({"x": state.x, "y": state.y},
                                   {"nu": state.nu, "omega": state.omega},
                                   step=state.t)

            def body_flat(carry, k):
                return engine.step(carry, sample(k)), None

            st, _ = lax.scan(body_flat, st, keys)
            vt, mt = engine.views(st)
            return (FedBiOAccLocalState(vt["x"], vt["y"], mt["omega"],
                                        mt["nu"], st.step),
                    {"t": st.step})

        def body(carry, inp):
            x, y, omega, nu, t = carry
            k, is_comm = inp
            a = alpha(t)
            x_new = jax.tree.map(lambda v, m: v - cfg.lr_x * a * m, x, nu)
            y_new = jax.tree.map(lambda v, m: v - cfg.lr_y * a * m, y, omega)
            x_new = lax.cond(is_comm, client_mean, lambda v: v, x_new)
            batches = sample(k)
            o_new, n_new = voracles(x_new, y_new, batches)
            o_old, n_old = voracles(x, y, batches)
            ca2 = a * a

            def storm(new, mom, old, c):
                return jax.tree.map(
                    lambda gn, mo, go: gn + (1.0 - c * ca2) * (mo - go),
                    new, mom, old)

            omega = storm(o_new, omega, o_old, cfg.c_omega)
            nu = storm(n_new, nu, n_old, cfg.c_nu)
            nu = lax.cond(is_comm, client_mean, lambda v: v, nu)   # ν averaged too
            return (x_new, y_new, omega, nu, t + 1), None

        is_comm = jnp.arange(1, I + 1) == I
        carry = (state.x, state.y, state.omega, state.nu, state.t)
        carry, _ = lax.scan(body, carry, (keys, is_comm))
        new = FedBiOAccLocalState(*carry)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, _ = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    return Algorithm("fedbioacc_local", init, round, 2 * tree_size(x1), mean_x)
