"""FedBiO — Algorithm 1 (global lower level, Eq. 1).

Three entangled federated problems are advanced by alternating local steps:

    ω_t = ∇_y g(x_t, y_t; B_y)                       lower problem
    ν_t = ∇_x f(x_t, y_t; B_f1) − ∇_xy g(...; B_g1)·u_t   upper problem
    u_{t+1} = τ∇_y f(...; B_f2) + (I − τ∇²_yy g(...; B_g2)) u_t   Eq. (4)

Every I steps the client states (x, y, u) are averaged — under pjit with the
client axis sharded over the mesh "data" axis this is the paper's
communication round (one all-reduce of the federated state).

§Perf fusion flags (FederatedConfig), mirroring ``core.fedbioacc`` — this
was the last tree-map-only reference loop:

* ``fuse_oracles`` — all three oracle directions (ω, μ = ∇_x f − ∇_xy g·u,
  p = ∇²_yy g·u − ∇_y f) from the two shared linearizations of
  ``hypergrad.fused_oracles`` on ONE minibatch (1 batch/step instead of 5);
  the u-update is then ``u − τ·p`` — exactly ``hypergrad.u_step``.
* ``fuse_storm`` — the scan carry lives on the flat-buffer substrate via the
  sequence-spec engine: FedBiO is the momentum-less triple-sequence spec, so
  each local step is one fused plain-SGD launch over (x, y, u).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.problems import Problem
from repro.core.tree_util import (client_mean, tree_axpy, tree_size,
                                  tree_zeros_like)
from repro.optim import sequences as seqs


class FedBiOState(NamedTuple):
    x: Any       # [M, ...] upper variable
    y: Any       # [M, ...] lower variable
    u: Any       # [M, ...] Eq. (4) auxiliary variable
    t: jnp.ndarray


class Algorithm(NamedTuple):
    name: str
    init: Any
    round: Any           # (state, key) -> (state, metrics)
    comm_floats: int     # floats communicated per client per round
    mean_x: Any          # state -> averaged upper variable


def _broadcast_clients(tree, m):
    return jax.tree.map(lambda v: jnp.broadcast_to(v[None], (m,) + v.shape), tree)


def make_fedbio(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    M = problem.num_clients
    f, g = problem.f, problem.g

    def init(key):
        x1, y1 = problem.init_xy(key)
        u1 = tree_zeros_like(y1)
        return FedBiOState(
            x=_broadcast_clients(x1, M), y=_broadcast_clients(y1, M),
            u=_broadcast_clients(u1, M), t=jnp.zeros((), jnp.int32))

    if cfg.fuse_oracles:
        def sample(k):
            return problem.sample_batches(k)

        def local_step(x, y, u, batch):
            omega, mu, p = hg.fused_oracles(g, f, x, y, u, batch)
            return (tree_axpy(-cfg.lr_x, mu, x),
                    tree_axpy(-cfg.lr_y, omega, y),
                    tree_axpy(-cfg.lr_u, p, u))     # == hg.u_step
    else:
        def sample(k):
            return tuple(problem.sample_batches(kk)
                         for kk in jax.random.split(k, 5))

        def local_step(x, y, u, batches):
            by, bf1, bg1, bf2, bg2 = batches
            omega = hg.grad_y(g, x, y, by)
            nu = hg.nu_direction(g, f, x, y, u, bg1, bf1)
            y_new = tree_axpy(-cfg.lr_y, omega, y)
            x_new = tree_axpy(-cfg.lr_x, nu, x)
            u_new = hg.u_step(g, f, x, y, u, bg2, bf2, cfg.lr_u)
            return x_new, y_new, u_new

    vstep = jax.vmap(local_step)

    # flat-buffer variant: FedBiO's momentum-less triple-sequence spec on the
    # sequence-spec engine — one fused plain-SGD launch per local step.
    # without_hierarchy: the reference loops always use the paper's flat
    # averaging, so fuse_storm stays a pure perf switch for any cfg
    if cfg.fuse_storm:
        x1s, y1s = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))

        def oracle(vt, batches):
            x, y, u = vt["x"], vt["y"], vt["u"]
            if cfg.fuse_oracles:
                omega, mu, p = jax.vmap(
                    lambda xx, yy, uu, b: hg.fused_oracles(g, f, xx, yy, uu, b)
                )(x, y, u, batches)
            else:
                by, bf1, bg1, bf2, bg2 = batches
                omega = jax.vmap(
                    lambda xx, yy, b: hg.grad_y(g, xx, yy, b))(x, y, by)
                mu = jax.vmap(
                    lambda xx, yy, uu, b1, b2:
                    hg.nu_direction(g, f, xx, yy, uu, b1, b2)
                )(x, y, u, bg1, bf1)
                p = jax.vmap(
                    lambda xx, yy, uu, b1, b2:
                    hg.u_residual(g, f, xx, yy, uu, b1, b2)
                )(x, y, u, bg2, bf2)
            return {"x": mu, "y": omega, "u": p}

        engine = seqs.make_engine(cfg, seqs.SPECS["fedbio"].without_hierarchy(),
                                  {"x": x1s, "y": y1s, "u": y1s}, oracle,
                                  block=cfg.fuse_storm_block)
    else:
        engine = None

    def round(state: FedBiOState, key):
        keys = jax.random.split(key, cfg.local_steps)
        if cfg.fuse_storm:
            # flatten once per round; the scan carry stays flat across all
            # local steps, pytree views appear only at the oracle boundaries
            st = engine.init_state({"x": state.x, "y": state.y,
                                    "u": state.u}, step=state.t)

            def body_flat(carry, k):
                return engine.step(carry, sample(k)), None

            st, _ = lax.scan(body_flat, st, keys)
            vt, _ = engine.views(st)
            new = FedBiOState(vt["x"], vt["y"], vt["u"], st.step)
            return new, {"t": new.t}

        def body(carry, k):
            x, y, u = carry
            x, y, u = vstep(x, y, u, sample(k))
            return (x, y, u), None

        (x, y, u), _ = lax.scan(body, (state.x, state.y, state.u), keys)
        # communication: average all three federated sequences
        x, y, u = client_mean(x), client_mean(y), client_mean(u)
        new = FedBiOState(x, y, u, state.t + cfg.local_steps)
        metrics = {"t": new.t}
        return new, metrics

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    x1, y1 = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))
    comm = tree_size(x1) + 2 * tree_size(y1)    # x + y + u per client per round
    return Algorithm("fedbio", init, round, comm, mean_x)
