"""Bilevel problem definitions.

A :class:`Problem` bundles the per-client stochastic objectives:

* ``f(x, y, batch) -> scalar``   — upper objective f^(m)(x, y; ξ)
* ``g(x, y, batch) -> scalar``   — lower objective g^(m)(x, y; ξ) (μ-strongly
  convex in y by construction)
* ``sample_batches(key) -> batch``  — one independent oracle draw for **all M
  clients at once** (leading axis M); heterogeneity lives in the batch.

Three families:

* :func:`quadratic_problem` — synthetic heterogeneous quadratics with a
  closed-form hyper-gradient (used to validate every theorem-level claim).
* :func:`data_cleaning_problem` — the paper's Federated Data Cleaning task
  (upper var = per-sample weight logits on a shared corrupted train set,
  lower var = classifier; per-client clean validation shards).
* :func:`hyperrep_problem` — the paper's Hyper-Representation task (upper =
  MLP backbone, lower = linear head); also the template for the LLM-scale
  train step (see ``core/model_problem.py``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree_util import tree_randn_like


@dataclass(frozen=True)
class Problem:
    name: str
    num_clients: int
    init_xy: Callable[[Any], Any]          # key -> (x, y) single-client template
    f: Callable[[Any, Any, Any], Any]
    g: Callable[[Any, Any, Any], Any]
    sample_batches: Callable[[Any], Any]   # key -> per-client batch [M, ...]
    # optional closed-form helpers (synthetic quadratic only)
    exact_hypergrad: Optional[Callable] = None      # x -> Φ(x, y_x)
    exact_lower_sol: Optional[Callable] = None      # x -> y_x
    # per-client exact lower solutions (local-lower-level problems, Eq. 5)
    exact_hypergrad_local: Optional[Callable] = None

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Synthetic heterogeneous quadratic bilevel problem
# ---------------------------------------------------------------------------

def _rand_spd(key, d, mu, L, M):
    """[M, d, d] SPD matrices with spectrum in [mu, L]."""
    ks = jax.random.split(key, M)

    def one(k):
        k1, k2 = jax.random.split(k)
        q, _ = jnp.linalg.qr(jax.random.normal(k1, (d, d)))
        ev = jax.random.uniform(k2, (d,), minval=mu, maxval=L)
        return (q * ev) @ q.T

    return jax.vmap(one)(ks)


def quadratic_problem(key, *, num_clients=8, dx=10, dy=10, mu=1.0, L=5.0,
                      hetero=1.0, noise=0.1, batch_size=8,
                      local_lower: bool = False) -> Problem:
    """Heterogeneous stochastic quadratic bilevel problem.

        g^m(x,y) = ½ yᵀA_g^m y + xᵀB^m y + c_mᵀy            (+ ⟨ξ, y⟩ noise)
        f^m(x,y) = ½‖y − y0_m‖² + ½ρ‖x − x0_m‖² + xᵀD^m y   (+ ⟨ξ, ·⟩ noise)

    Closed forms (global lower level):
        y_x   = −Ā⁻¹ (B̄ᵀ x + c̄)
        ∇h(x) = ∇_x f̄(x,y_x) − B̄ Ā⁻¹ ∇_y f̄(x, y_x)
    """
    M = num_clients
    ks = jax.random.split(key, 7)
    Ag = _rand_spd(ks[0], dy, mu, L, M)
    B = jax.random.normal(ks[1], (M, dx, dy)) * (hetero * 0.3 + 0.3)
    c = jax.random.normal(ks[2], (M, dy)) * hetero
    D = jax.random.normal(ks[3], (M, dx, dy)) * 0.1
    x0 = jax.random.normal(ks[4], (M, dx)) * hetero
    y0 = jax.random.normal(ks[5], (M, dy)) * hetero
    rho = 1.0
    sigma = noise / np.sqrt(batch_size)

    def init_xy(k):
        k1, k2 = jax.random.split(k)
        return (jax.random.normal(k1, (dx,)), jax.random.normal(k2, (dy,)))

    def g(x, y, batch):
        quad = 0.5 * y @ batch["Ag"] @ y + x @ batch["B"] @ y + batch["c"] @ y
        noise_term = batch["ng"] @ y
        return quad + noise_term

    def f(x, y, batch):
        val = (0.5 * jnp.sum((y - batch["y0"]) ** 2)
               + 0.5 * rho * jnp.sum((x - batch["x0"]) ** 2)
               + x @ batch["D"] @ y)
        return val + batch["nfx"] @ x + batch["nfy"] @ y

    def sample_batches(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "Ag": Ag, "B": B, "c": c, "D": D, "x0": x0, "y0": y0,
            "ng": sigma * jax.random.normal(k1, (M, dy)),
            "nfx": sigma * jax.random.normal(k2, (M, dx)),
            "nfy": sigma * jax.random.normal(k3, (M, dy)),
        }

    # ---- closed forms ----
    Abar = jnp.mean(Ag, 0)
    Bbar = jnp.mean(B, 0)
    cbar = jnp.mean(c, 0)
    Dbar = jnp.mean(D, 0)
    x0bar = jnp.mean(x0, 0)
    y0bar = jnp.mean(y0, 0)

    def exact_lower_sol(x):
        return -jnp.linalg.solve(Abar, Bbar.T @ x + cbar)

    def exact_hypergrad(x):
        yx = exact_lower_sol(x)
        gx = rho * (x - x0bar) + Dbar @ yx
        gy = (yx - y0bar) + Dbar.T @ x
        return gx - Bbar @ jnp.linalg.solve(Abar, gy)

    def exact_hypergrad_local(x):
        """Eq. (5): h(x) = (1/M) Σ f^m(x, y_x^m), y_x^m = argmin g^m."""
        def one(Agm, Bm, cm, Dm, x0m, y0m):
            yx = -jnp.linalg.solve(Agm, Bm.T @ x + cm)
            gx = rho * (x - x0m) + Dm @ yx
            gy = (yx - y0m) + Dm.T @ x
            return gx - Bm @ jnp.linalg.solve(Agm, gy)
        return jnp.mean(jax.vmap(one)(Ag, B, c, D, x0, y0), axis=0)

    return Problem(
        name="quadratic", num_clients=M, init_xy=init_xy, f=f, g=g,
        sample_batches=sample_batches, exact_hypergrad=exact_hypergrad,
        exact_lower_sol=exact_lower_sol,
        exact_hypergrad_local=exact_hypergrad_local)


# ---------------------------------------------------------------------------
# Federated data cleaning (paper §5 experiment 1)
# ---------------------------------------------------------------------------

def make_cleaning_data(key, *, num_clients=8, n_train=256, n_val=64, dim=16,
                       classes=4, corrupt_frac=0.4):
    """Shared corrupted train set + per-client clean validation shards."""
    ks = jax.random.split(key, 6)
    w_true = jax.random.normal(ks[0], (dim, classes))
    xtr = jax.random.normal(ks[1], (n_train, dim))
    logits = xtr @ w_true
    ytr_clean = jnp.argmax(logits + 0.5 * jax.random.normal(ks[2], logits.shape), -1)
    n_bad = int(n_train * corrupt_frac)
    corrupt_mask = jnp.arange(n_train) < n_bad
    y_rand = jax.random.randint(ks[3], (n_train,), 0, classes)
    ytr = jnp.where(corrupt_mask, y_rand, ytr_clean)
    # per-client val (heterogeneous shift)
    xval = jax.random.normal(ks[4], (num_clients, n_val, dim)) \
        + 0.3 * jax.random.normal(ks[5], (num_clients, 1, dim))
    yval = jnp.argmax(jnp.einsum("mnd,dc->mnc", xval, w_true), -1)
    return {"xtr": xtr, "ytr": ytr, "corrupt_mask": corrupt_mask,
            "xval": xval, "yval": yval, "w_true": w_true}


def data_cleaning_problem(key, *, num_clients=8, n_train=256, n_val=64, dim=16,
                          classes=4, corrupt_frac=0.4, batch_size=32,
                          lower_l2=0.5) -> Problem:
    data = make_cleaning_data(key, num_clients=num_clients, n_train=n_train,
                              n_val=n_val, dim=dim, classes=classes,
                              corrupt_frac=corrupt_frac)
    M = num_clients

    def init_xy(k):
        x = jnp.zeros((n_train,))                       # weight logits
        y = 0.01 * jax.random.normal(k, (dim, classes))
        return x, y

    def _ce(w, xs, ys):
        lp = jax.nn.log_softmax(xs @ w, axis=-1)
        return -jnp.take_along_axis(lp, ys[:, None], axis=1)[:, 0]

    def g(x, y, batch):
        idx = batch["tr_idx"]
        per = _ce(y, data["xtr"][idx], data["ytr"][idx])
        w = jax.nn.sigmoid(x[idx])
        return jnp.mean(w * per) + 0.5 * lower_l2 * jnp.sum(y ** 2)

    def f(x, y, batch):
        m = batch["client"]
        idx = batch["val_idx"]
        per = _ce(y, data["xval"][m][idx], data["yval"][m][idx])
        return jnp.mean(per)

    def sample_batches(k):
        k1, k2 = jax.random.split(k)
        tr_idx = jax.random.randint(k1, (M, batch_size), 0, n_train)
        val_idx = jax.random.randint(k2, (M, batch_size), 0, n_val)
        return {"tr_idx": tr_idx, "val_idx": val_idx,
                "client": jnp.arange(M)}

    prob = Problem(name="data_cleaning", num_clients=M, init_xy=init_xy,
                   f=f, g=g, sample_batches=sample_batches)
    object.__setattr__(prob, "data", data)   # stash for evaluation scripts
    return prob


# ---------------------------------------------------------------------------
# Hyper-representation learning (paper §5 experiment 2)
# ---------------------------------------------------------------------------

def make_hyperrep_data(key, *, num_clients=8, n=256, dim=16, classes=4,
                       hetero=0.5):
    ks = jax.random.split(key, 4)
    w_shared = jax.random.normal(ks[0], (dim, dim))
    xs = jax.random.normal(ks[1], (num_clients, n, dim))
    w_cli = jax.random.normal(ks[2], (num_clients, dim, classes))
    w_common = jax.random.normal(ks[3], (dim, classes))
    w_task = w_common[None] + hetero * w_cli
    feats = jnp.tanh(jnp.einsum("mnd,de->mne", xs, w_shared))
    ys = jnp.argmax(jnp.einsum("mne,mec->mnc", feats, w_task), -1)
    return {"x": xs, "y": ys}


def hyperrep_problem(key, *, num_clients=8, n=256, dim=16, hidden=32,
                     classes=4, batch_size=32, lower_l2=0.1,
                     hetero=0.5) -> Problem:
    """Upper x = 2-layer MLP backbone; lower y = linear head."""
    data = make_hyperrep_data(key, num_clients=num_clients, n=n, dim=dim,
                              classes=classes, hetero=hetero)
    M = num_clients

    def init_xy(k):
        k1, k2, k3 = jax.random.split(k, 3)
        x = {"w1": 0.3 * jax.random.normal(k1, (dim, hidden)),
             "b1": jnp.zeros((hidden,)),
             "w2": 0.3 * jax.random.normal(k2, (hidden, hidden)),
             "b2": jnp.zeros((hidden,))}
        y = {"w": 0.1 * jax.random.normal(k3, (hidden, classes)),
             "b": jnp.zeros((classes,))}
        return x, y

    def backbone(x, inp):
        h = jnp.tanh(inp @ x["w1"] + x["b1"])
        return jnp.tanh(h @ x["w2"] + x["b2"])

    def _loss(x, y, xs, ys):
        feats = backbone(x, xs)
        lp = jax.nn.log_softmax(feats @ y["w"] + y["b"], axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, ys[:, None], axis=1))

    def g(x, y, batch):
        m, idx = batch["client"], batch["tr_idx"]
        base = _loss(x, y, data["x"][m][idx], data["y"][m][idx])
        reg = 0.5 * lower_l2 * sum(jnp.sum(v ** 2) for v in jax.tree.leaves(y))
        return base + reg

    def f(x, y, batch):
        m, idx = batch["client"], batch["val_idx"]
        return _loss(x, y, data["x"][m][idx], data["y"][m][idx])

    def sample_batches(k):
        k1, k2 = jax.random.split(k)
        half = n // 2
        tr_idx = jax.random.randint(k1, (M, batch_size), 0, half)
        val_idx = half + jax.random.randint(k2, (M, batch_size), 0, half)
        return {"tr_idx": tr_idx, "val_idx": val_idx, "client": jnp.arange(M)}

    prob = Problem(name="hyperrep", num_clients=M, init_xy=init_xy, f=f, g=g,
                   sample_batches=sample_batches)
    object.__setattr__(prob, "data", data)
    return prob


# ---------------------------------------------------------------------------
# Fair Federated Learning (paper §5 conclusion: bilevel fairness formulation)
# ---------------------------------------------------------------------------

def make_fairness_data(key, *, num_clients=8, n=256, dim=16, classes=4,
                       hard_clients=2, shift=1.5):
    """Classification shards with a **minority distribution**: the first
    ``hard_clients`` clients draw labels from a rotated ground truth. A
    uniformly-weighted model is pulled to the majority and under-serves
    them — the regime where risk-equalising client weights help."""
    ks = jax.random.split(key, 4)
    w_true = jax.random.normal(ks[0], (dim, classes))
    w_minor = w_true + shift * jax.random.normal(ks[3], (dim, classes))
    xs = jax.random.normal(ks[1], (num_clients, n, dim))
    hard_mask = jnp.arange(num_clients) < hard_clients
    w_per = jnp.where(hard_mask[:, None, None], w_minor[None], w_true[None])
    logits = jnp.einsum("mnd,mdc->mnc", xs, w_per)
    noise = 0.2 * jax.random.normal(ks[2], logits.shape)
    ys = jnp.argmax(logits + noise, -1)
    return {"x": xs, "y": ys, "hard_mask": hard_mask}


def fair_federated_problem(key, *, num_clients=8, n=256, dim=16, classes=4,
                           batch_size=32, lower_l2=0.2, beta=2.0,
                           hard_clients=2) -> Problem:
    """Bilevel Fair FL:

        lower  g^m(λ, y) = M·softmax(λ)_m · L^train_m(y) + (μ/2)||y||²
               (client average = Σ_m softmax(λ)_m L_m + reg)
        upper  f^m(λ, y) = exp(β · L^val_m(y)) / β
               (client average ≈ a smooth-max over client validation losses)

    The upper variable λ (client weight logits) learns to up-weight
    under-served clients; minimizing the smooth-max equalises client risk —
    the fairness objective the paper's conclusion refers to.
    """
    data = make_fairness_data(key, num_clients=num_clients, n=n, dim=dim,
                              classes=classes, hard_clients=hard_clients)
    M = num_clients

    def init_xy(k):
        lam = jnp.zeros((M,))
        y = 0.01 * jax.random.normal(k, (dim, classes))
        return lam, y

    def _ce(w, xs, ys):
        lp = jax.nn.log_softmax(xs @ w, axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, ys[:, None], axis=1))

    def g(lam, y, batch):
        m, idx = batch["client"], batch["tr_idx"]
        loss = _ce(y, data["x"][m][idx], data["y"][m][idx])
        w = M * jax.nn.softmax(lam)[m]
        return w * loss + 0.5 * lower_l2 * jnp.sum(y ** 2)

    def f(lam, y, batch):
        m, idx = batch["client"], batch["val_idx"]
        loss = _ce(y, data["x"][m][idx], data["y"][m][idx])
        return jnp.exp(beta * jnp.minimum(loss, 10.0)) / beta

    def sample_batches(k):
        k1, k2 = jax.random.split(k)
        half = n // 2
        return {"tr_idx": jax.random.randint(k1, (M, batch_size), 0, half),
                "val_idx": half + jax.random.randint(k2, (M, batch_size), 0, half),
                "client": jnp.arange(M)}

    prob = Problem(name="fair_fl", num_clients=M, init_xy=init_xy, f=f, g=g,
                   sample_batches=sample_batches)
    object.__setattr__(prob, "data", data)

    def client_val_losses(lam, y):
        half = n // 2

        def one(m):
            return _ce(y, data["x"][m][half:], data["y"][m][half:])

        return jax.vmap(one)(jnp.arange(M))

    object.__setattr__(prob, "client_val_losses", client_val_losses)
    return prob
