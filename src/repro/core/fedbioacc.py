"""FedBiOAcc — Algorithm 2: STORM variance reduction on all three sequences.

Per step t (learning-rate schedule α_t = δ/(u0 + t)^{1/3}):

    ŷ_{t+1} = y_t − γ α_t ω_t,   x̂_{t+1} = x_t − η α_t ν_t,
    û_{t+1} = u_t − τ α_t q_t                                   (line 4)
    [every I steps: average x, y, u]                            (lines 5–9)
    ω_{t+1} = ∇_y g(z_{t+1}; B) + (1 − c_ω α_t²)(ω_t − ∇_y g(z_t; B))
    ν_{t+1} = μ(z_{t+1}, u_{t+1}; B) + (1 − c_ν α_t²)(ν_t − μ(z_t, u_t; B))
    q_{t+1} = p(z_{t+1}, u_{t+1}; B) + (1 − c_u α_t²)(q_t − p(z_t, u_t; B))
    [every I steps: average ω, ν, q]                            (lines 13–17)

where μ = ∇_x f − ∇_xy g·u and p = ∇²_yy g·u − ∇_y f. The same fresh
minibatch is evaluated at the old and new iterate — the STORM correction.

§Perf fusion flags (FederatedConfig):

* ``fuse_oracles`` — one forward-over-reverse linearization yields all three
  oracle directions from ONE shared minibatch (``hypergrad.fused_oracles``);
  the step then samples 1 batch instead of 5.
* ``fuse_storm`` — the scan carry keeps (x, y, u) and (ν, ω, q) as flat
  per-dtype buffers (flattened once per round) and the 9-pass tree-map
  momentum/variable chain becomes one triple-sequence Pallas launch + one
  elementwise add per local step.  The fused loop is the **sequence-spec
  engine** (``repro.optim.sequences``): FedBiOAcc is declared as three
  STORM sequences (x/ν, y/ω, u/q — all hierarchically communicated) and the
  engine compiles the spec into the flat-substrate step.  The old-iterate
  oracle is evaluated *before* the variable step (same value — it only
  reads the entering iterate), which is what lets the variable step and the
  partial momentum share a single launch.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import FederatedConfig
from repro.core import hypergrad as hg
from repro.core.problems import Problem
from repro.core.fedbio import Algorithm, _broadcast_clients
from repro.core.tree_util import client_mean, tree_size, tree_zeros_like
from repro.optim import sequences as seqs


class FedBiOAccState(NamedTuple):
    x: Any
    y: Any
    u: Any
    omega: Any   # momentum for y
    nu: Any      # momentum for x
    q: Any       # momentum for u
    t: jnp.ndarray


def make_fedbioacc(problem: Problem, cfg: FederatedConfig) -> Algorithm:
    M = problem.num_clients
    f, g = problem.f, problem.g

    def alpha(t):
        return seqs.alpha_schedule(cfg, t)

    if cfg.fuse_oracles:
        def sample(k):
            return problem.sample_batches(k)

        def oracles(x, y, u, batch):
            return hg.fused_oracles(g, f, x, y, u, batch)
    else:
        def sample(k):
            return tuple(problem.sample_batches(kk)
                         for kk in jax.random.split(k, 5))

        def oracles(x, y, u, batches):
            by, bf1, bg1, bf2, bg2 = batches
            omega = hg.grad_y(g, x, y, by)
            mu = hg.nu_direction(g, f, x, y, u, bg1, bf1)
            p = hg.u_residual(g, f, x, y, u, bg2, bf2)
            return omega, mu, p

    voracles = jax.vmap(oracles)

    def init(key):
        k1, k2 = jax.random.split(key)
        x1, y1 = problem.init_xy(k1)
        u1 = tree_zeros_like(y1)
        x = _broadcast_clients(x1, M)
        y = _broadcast_clients(y1, M)
        u = _broadcast_clients(u1, M)
        omega, nu, q = voracles(x, y, u, sample(k2))
        return FedBiOAccState(x, y, u, omega, nu, q, jnp.zeros((), jnp.int32))

    def body(carry, inp):
        x, y, u, omega, nu, q, t = carry
        k, is_comm = inp
        a = alpha(t)
        # --- variable update (line 4) ---
        x_new = jax.tree.map(lambda v, m: v - cfg.lr_x * a * m, x, nu)
        y_new = jax.tree.map(lambda v, m: v - cfg.lr_y * a * m, y, omega)
        u_new = jax.tree.map(lambda v, m: v - cfg.lr_u * a * m, u, q)
        # --- communication of variables (lines 5-9) ---
        x_new = lax.cond(is_comm, client_mean, lambda v: v, x_new)
        y_new = lax.cond(is_comm, client_mean, lambda v: v, y_new)
        u_new = lax.cond(is_comm, client_mean, lambda v: v, u_new)
        # --- STORM momentum with shared minibatch (lines 10-12) ---
        batches = sample(k)
        o_new, m_new, p_new = voracles(x_new, y_new, u_new, batches)
        o_old, m_old, p_old = voracles(x, y, u, batches)
        ca2 = (a * a)

        def storm(new, mom, old, c):
            return jax.tree.map(
                lambda gn, mo, go: gn + (1.0 - c * ca2) * (mo - go),
                new, mom, old)

        omega = storm(o_new, omega, o_old, cfg.c_omega)
        nu = storm(m_new, nu, m_old, cfg.c_nu)
        q = storm(p_new, q, p_old, cfg.c_u)
        # --- communication of momenta (lines 13-17) ---
        omega = lax.cond(is_comm, client_mean, lambda v: v, omega)
        nu = lax.cond(is_comm, client_mean, lambda v: v, nu)
        q = lax.cond(is_comm, client_mean, lambda v: v, q)
        return (x_new, y_new, u_new, omega, nu, q, t + 1), None

    # flat-buffer variant of the same step: FedBiOAcc's sequence spec (three
    # hierarchically-communicated STORM sequences) compiled by the engine —
    # one fused triple-sequence launch + one add per local step
    x1s, y1s = jax.eval_shape(problem.init_xy, jax.random.PRNGKey(0))

    def oracle(vt, batches):
        omega, mu, p = voracles(vt["x"], vt["y"], vt["u"], batches)
        return {"x": mu, "y": omega, "u": p}

    # without_hierarchy: the reference loop always uses the paper's flat
    # averaging, so fuse_storm stays a pure perf switch for any cfg
    engine = (seqs.make_engine(cfg, seqs.SPECS["fedbioacc"].without_hierarchy(),
                               {"x": x1s, "y": y1s, "u": y1s}, oracle,
                               block=cfg.fuse_storm_block)
              if cfg.fuse_storm else None)

    def round(state: FedBiOAccState, key):
        I = cfg.local_steps
        keys = jax.random.split(key, I)
        if not cfg.fuse_storm:
            is_comm = jnp.arange(1, I + 1) == I      # communicate on last local step
            carry = (state.x, state.y, state.u, state.omega, state.nu,
                     state.q, state.t)
            carry, _ = lax.scan(body, carry, (keys, is_comm))
            new = FedBiOAccState(*carry)
            return new, {"t": new.t}
        # flatten once per round; the scan carry stays flat across all I
        # local steps, pytree views appear only at the oracle boundaries
        st = engine.init_state({"x": state.x, "y": state.y, "u": state.u},
                               {"nu": state.nu, "omega": state.omega,
                                "q": state.q},
                               step=state.t)

        def body_flat(carry, k):
            return engine.step(carry, sample(k)), None

        st, _ = lax.scan(body_flat, st, keys)
        vt, mt = engine.views(st)
        new = FedBiOAccState(vt["x"], vt["y"], vt["u"], mt["omega"],
                             mt["nu"], mt["q"], st.step)
        return new, {"t": new.t}

    def mean_x(state):
        return jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)

    # x + y + u + three momenta per client per round
    comm = 2 * (tree_size(x1s) + 2 * tree_size(y1s))
    return Algorithm("fedbioacc", init, round, comm, mean_x)
