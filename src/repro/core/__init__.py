# The paper's primary contribution: FedBiO / FedBiOAcc (Algorithms 1-4) and
# the baselines from Table 1, plus the bilevel-problem and hyper-gradient
# substrate they run on.
from repro.core.api import make_algorithm  # noqa: F401
from repro.core.problems import (data_cleaning_problem, hyperrep_problem,  # noqa: F401
                                 quadratic_problem)
