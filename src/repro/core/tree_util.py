"""Pytree arithmetic helpers used by all optimizers/algorithms."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, a, b):
    """b + s * a"""
    return jax.tree.map(lambda x, y: y + s * x, a, b)


def tree_vdot(a, b):
    parts = jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32),
                                               y.astype(jnp.float32)), a, b)
    return sum(jax.tree.leaves(parts), jnp.zeros((), jnp.float32))


def tree_sqnorm(a):
    return tree_vdot(a, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_randn_like(key, a, scale=1.0):
    leaves, treedef = jax.tree.flatten(a)
    keys = jax.random.split(key, len(leaves))
    out = [scale * jax.random.normal(k, x.shape, x.dtype) for k, x in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def tree_size(a) -> int:
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))


def client_mean(tree):
    """Average over the leading client axis and broadcast back.

    Under pjit with the client axis sharded over the mesh "data" axis this is
    exactly the paper's communication round: XLA lowers it to an all-reduce.
    """
    return jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape), tree)


def client_mean_grouped(tree, num_groups: int):
    """Average within contiguous client groups (pod-local averaging for the
    hierarchical multi-pod schedule). With the client axis sharded over
    ("pod","data"), group g = pod g — the all-reduce stays on the fast
    intra-pod ICI."""
    def one(x):
        M = x.shape[0]
        g = x.reshape(num_groups, M // num_groups, *x.shape[1:])
        m = jnp.mean(g, axis=1, keepdims=True)
        return jnp.broadcast_to(m, g.shape).reshape(x.shape)

    return jax.tree.map(one, tree)


def _weight_col(x, w):
    """Per-client weights → broadcastable column rescaled so the plain mean
    of ``x · col`` is the weighted mean (col = w · M/Σw).  All-ones weights
    give col = 1.0 exactly — the weighted path then reproduces
    :func:`client_mean` bit-for-bit.  Keep in sync with
    ``repro.optim.flat._weight_col`` (the flat-buffer twin)."""
    wsum = jnp.sum(w, axis=-1, keepdims=True)
    scale = jnp.where(wsum > 0, w.shape[-1] / wsum, 0.0)
    col = (w * scale).astype(x.dtype)
    return col.reshape(col.shape + (1,) * (x.ndim - col.ndim))


def client_mean_weighted(tree, w):
    """Participation-weighted client mean: the average is over participants
    only (w = 0 ⇒ non-participant) and non-participant rows pass through
    bit-identical — the pytree twin of the flat substrate's weighted
    ``client_mean_masked``."""
    def one(x):
        col = _weight_col(x, w)
        m = jnp.broadcast_to(jnp.mean(x * col, axis=0, keepdims=True), x.shape)
        return jnp.where(col > 0, m, x)

    return jax.tree.map(one, tree)


def client_mean_grouped_weighted(tree, num_groups: int, w):
    """Participation-weighted pod-local grouped mean (see
    :func:`client_mean_grouped`); empty groups pass through unchanged."""
    def one(x):
        M = x.shape[0]
        g = x.reshape(num_groups, M // num_groups, *x.shape[1:])
        col = _weight_col(g, w.reshape(num_groups, M // num_groups))
        m = jnp.broadcast_to(jnp.mean(g * col, axis=1, keepdims=True), g.shape)
        return jnp.where(col > 0, m, g).reshape(x.shape)

    return jax.tree.map(one, tree)


def client_slice(tree, m):
    return jax.tree.map(lambda x: x[m], tree)


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)
