"""Per-experiment orchestration of the three verifier passes.

``verify_experiment`` statically checks one built Experiment: the
collective/wire audit (W1xx — sharded specs, plus a 2-device mesh probe
for compressed unsharded specs so their wire dtype/bytes are proven too),
the state-slot and jaxpr-identity audits (S2xx), nothing executed beyond
tracing and one small XLA compile of the comm-only subprogram.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.rules import Finding

#: the mesh of the wire probe for compressed UNSHARDED specs — 2 data
#: shards is the smallest mesh whose collectives XLA cannot elide
PROBE_MESH = (2, 1)


def _located(findings: List[Finding], where: str) -> List[Finding]:
    return [f._replace(where=where) for f in findings]


def verify_experiment(exp, *, where: str, hlo: bool = True,
                      bare_cache: Optional[Dict[str, Any]] = None
                      ) -> Tuple[List[Finding], List[str]]:
    """(findings, notes) of one Experiment.  ``where`` labels findings
    (normally the spec path); ``hlo=False`` skips the comm-subprogram
    compile (jaxpr + structure checks only); ``bare_cache`` dedupes the
    S202 baseline across specs sharing a bare form."""
    import jax

    from repro.analysis import collectives as coll
    from repro.analysis import structure as struct
    from repro.api.build import build

    findings: List[Finding] = []
    notes: List[str] = []
    run = build(exp)
    fused = hasattr(run.step, "spec")
    if not fused:
        notes.append("unfused path: skipped (no flat substrate to audit)")
        return findings, notes

    # -- pass 1: collectives/wire ------------------------------------------
    if run.mesh is not None:
        f1 = coll.audit_step_collectives(run)
        findings += _located(f1, where)
        expected, info = coll.expected_step_collectives(run)
        n_ops = sum(expected.values())
        notes.append(f"jaxpr: {n_ops} collectives == plan "
                     f"({info['events']} events x {info['comm_elems']} "
                     f"elems/chunk)" if not f1 else "jaxpr: FAIL")
        if hlo:
            f2 = coll.audit_wire(run)
            findings += _located(f2, where)
            if not f2:
                want = coll.expected_wire_bytes(
                    expected, int(run.mesh.shape["data"]))
                notes.append("wire: " + " + ".join(
                    f"{b} B {d}" for d, b in sorted(want.items())))
    else:
        notes.append("no wire (unsharded)")
        if exp.compression is not None and len(jax.devices()) >= 2:
            probe = exp.edit(**{"execution.mesh": PROBE_MESH})
            prun = build(probe)
            f1 = coll.audit_step_collectives(prun)
            f2 = coll.audit_wire(prun) if hlo else []
            findings += _located(f1 + f2, f"{where} [mesh probe "
                                          f"{PROBE_MESH}]")
            if not (f1 or f2):
                expected, _ = coll.expected_step_collectives(prun)
                want = coll.expected_wire_bytes(expected, PROBE_MESH[0])
                notes.append(f"wire probe {PROBE_MESH}: " + " + ".join(
                    f"{b} B {d}" for d, b in sorted(want.items())))

    # -- pass 2: structure --------------------------------------------------
    findings += _located(struct.audit_state_slots(run), where)
    findings += _located(struct.audit_bare_jaxpr(exp, bare_cache), where)
    findings += _located(struct.audit_telemetry_inert(exp), where)
    if not any(f.rule.startswith("S") for f in findings):
        notes.append("state slots + bare/telemetry jaxpr identity OK")
    return findings, notes
