"""Determinism/purity source lint (the ``L3xx`` rules).

A single AST walk per file.  Rule scopes follow the layering of the
codebase: nondeterminism (L301/L302) and spec hygiene (L305/L306) apply to
ALL of ``src/repro``; host-sync (L303) applies to the engine layers that
run under ``jit`` (optim / kernels / federation / core / models /
sharding); PRNG discipline (L304) applies to the round-loop layers
(optim / federation) where resume bit-exactness demands ``fold_in``-pure
draws.  A finding on a line carrying ``# analysis: ignore[L3xx]`` is
suppressed — the justified escape hatch for driver-side timing and
init-time key fans.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable, List, Optional

from repro.analysis.rules import Finding

#: layers whose code runs inside traced/jitted functions — host sync here
#: stalls every step (L303)
ENGINE_DIRS = ("optim", "kernels", "federation", "core", "models",
               "sharding")
#: layers holding the round loop — randomness here must be fold_in-pure
#: or resume/rollback replay diverges (L304)
ROUND_DIRS = ("optim", "federation")

_IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore\[([A-Z0-9,\s]+)\]")

# dotted-suffix ban lists: the last two components of the called name
_TIME_CALLS = {("time", "time"), ("time", "time_ns"),
               ("time", "perf_counter"), ("time", "perf_counter_ns"),
               ("time", "monotonic"), ("time", "monotonic_ns"),
               ("datetime", "now"), ("datetime", "utcnow"),
               ("date", "today"), ("os", "urandom")}
_NP_NAMES = ("np", "numpy")
_SPEC_SUFFIXES = ("Spec", "Config", "Cfg")


def _dotted(node: ast.AST) -> tuple:
    """("np", "random", "rand") for np.random.rand — () if not a name."""
    parts: list = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _contains_jax_value(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in ("jnp", "jax", "lax"):
            return True
    return False


def _is_seedlike(node: ast.AST) -> bool:
    """PRNGKey arguments that are spec-derived or literal constants —
    the allowed key-creation forms (everything else is ad-hoc)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return True
    if isinstance(node, ast.Attribute) and node.attr.endswith("seed"):
        return True
    if isinstance(node, ast.Name) and node.id.endswith("seed"):
        return True
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, lines: List[str], engine: bool,
                 round_loop: bool):
        self.path = path
        self.lines = lines
        self.engine = engine
        self.round_loop = round_loop
        self.findings: List[Finding] = []

    # -- helpers ------------------------------------------------------------

    def _ignored(self, rule: str, node: ast.AST) -> bool:
        for ln in {getattr(node, "lineno", 0),
                   getattr(node, "end_lineno", 0)}:
            if 1 <= ln <= len(self.lines):
                m = _IGNORE_RE.search(self.lines[ln - 1])
                if m and rule in m.group(1):
                    return True
        return False

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        if not self._ignored(rule, node):
            self.findings.append(
                Finding(rule, f"{self.path}:{node.lineno}", message))

    # -- imports (L302: stdlib random) --------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            if a.name == "random":
                self._flag("L302", node,
                           "stdlib `random` imported — global-state RNG")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "random":
            self._flag("L302", node,
                       "stdlib `random` imported — global-state RNG")
        self.generic_visit(node)

    # -- calls (L301, L302, L303, L304) -------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        d = _dotted(node.func)
        if d and d[-2:] in _TIME_CALLS:
            self._flag("L301", node,
                       f"`{'.'.join(d)}()` is wall-clock/OS "
                       f"nondeterminism")
        if len(d) >= 2 and d[0] in _NP_NAMES and d[1] == "random":
            self._flag("L302", node,
                       f"`{'.'.join(d)}()` uses NumPy's global RNG")
        elif len(d) >= 2 and d[0] == "random":
            self._flag("L302", node,
                       f"`{'.'.join(d)}()` uses the stdlib global RNG")
        if self.engine:
            if d and d[-1] == "item" and isinstance(node.func,
                                                    ast.Attribute):
                self._flag("L303", node,
                           "`.item()` synchronizes the device value to "
                           "host")
            if (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int") and node.args
                    and _contains_jax_value(node.args[0])):
                self._flag("L303", node,
                           f"`{node.func.id}()` on a jax value blocks on "
                           f"the device")
            if len(d) >= 2 and d[0] in _NP_NAMES and d[1] in ("asarray",
                                                              "array"):
                self._flag("L303", node,
                           f"`{'.'.join(d)}()` in engine code pulls its "
                           f"argument to host memory")
        if self.round_loop and len(d) >= 2 and d[-2] == "random":
            if d[-1] == "split":
                self._flag("L304", node,
                           "`jax.random.split` carries a key chain — "
                           "round randomness must be fold_in-derived")
            elif d[-1] in ("PRNGKey", "key") and node.args and not \
                    _is_seedlike(node.args[0]):
                self._flag("L304", node,
                           f"`{'.'.join(d)}({ast.unparse(node.args[0])})` "
                           f"creates a key from a non-seed value")
        self.generic_visit(node)

    # -- class defs (L305) ---------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if node.name.endswith(_SPEC_SUFFIXES):
            for dec in node.decorator_list:
                d = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
                if not d or d[-1] != "dataclass":
                    continue
                frozen = isinstance(dec, ast.Call) and any(
                    k.arg == "frozen"
                    and isinstance(k.value, ast.Constant)
                    and k.value.value is True for k in dec.keywords)
                if not frozen:
                    self._flag("L305", node,
                               f"spec dataclass `{node.name}` is not "
                               f"frozen=True")
        self.generic_visit(node)

    # -- function defs (L306) ------------------------------------------------

    def _check_defaults(self, node) -> None:
        a = node.args
        for dflt in list(a.defaults) + [d for d in a.kw_defaults if d]:
            bad = isinstance(dflt, (ast.List, ast.Dict, ast.Set))
            if isinstance(dflt, ast.Call):
                d = _dotted(dflt.func)
                bad = bad or (d in (("list",), ("dict",), ("set",))
                              and not dflt.args and not dflt.keywords)
            if bad:
                self._flag("L306", dflt,
                           f"mutable default in `{node.name}()` aliases "
                           f"across calls")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_defaults(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node) -> None:
        self._check_defaults(node)
        self.generic_visit(node)


def _layer_of(path: str) -> Optional[str]:
    parts = os.path.normpath(path).split(os.sep)
    if "repro" in parts:
        i = len(parts) - 1 - parts[::-1].index("repro")
        if i + 1 < len(parts) - 1:
            return parts[i + 1]
    return None


def lint_source(src: str, path: str, *, engine: Optional[bool] = None,
                round_loop: Optional[bool] = None) -> List[Finding]:
    """Lint one file's source text.  ``engine``/``round_loop`` override the
    path-derived rule scopes (tests use this on temp files)."""
    layer = _layer_of(path)
    if engine is None:
        engine = layer in ENGINE_DIRS
    if round_loop is None:
        round_loop = layer in ROUND_DIRS
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("L306", f"{path}:{e.lineno or 0}",
                        f"file does not parse: {e.msg}")]
    lt = _Linter(path, src.splitlines(), engine, round_loop)
    lt.visit(tree)
    return lt.findings


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    """Lint every ``.py`` under the given files/directories."""
    findings: List[Finding] = []
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        else:
            files.append(p)
    for f in files:
        with open(f) as fh:
            findings.extend(lint_source(fh.read(), f))
    return findings
