"""``repro.analysis`` — the static invariant verifier.

Proves the engine's contracts without running it, in three passes:

1. **Collectives/wire** (``W1xx``): the traced step binds exactly the
   collectives the analytic comm plan implies (one sliced reduction per
   communicated merged run per reduction event), private tiles never
   appear in a collective operand, and the compiled communication-only
   subprogram moves byte-exact, dtype-exact traffic with zero resharding
   ops — ``repro.analysis.collectives``.
2. **Structure** (``S2xx``): every optional feature off leaves zero state
   leaves and a step jaxpr identical to the pre-feature factory build;
   events-only telemetry is jaxpr-inert — ``repro.analysis.structure``.
3. **Source lint** (``L3xx``): no wall-clock/global-RNG nondeterminism,
   no host sync in engine code, fold_in-pure round randomness, frozen
   spec dataclasses — ``repro.analysis.lint``.

CLI::

    python -m repro.analysis --experiment experiments/fedbioacc.json
    python -m repro.analysis --all experiments/ --lint src/repro

The rule registry (IDs, what each proves, fix-its) lives in
``repro.analysis.rules``; lint findings can be waived per line with
``# analysis: ignore[L3xx]``.  This module imports no accelerator code —
jax loads lazily inside the checkers, after the CLI has forced enough
host devices for the meshes it must build.
"""
from repro.analysis.rules import LINT_RULES, RULES, Finding, Rule

__all__ = ["RULES", "LINT_RULES", "Rule", "Finding"]
