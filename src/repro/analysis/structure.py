"""Bare-state / jaxpr-diff auditor (the ``S2xx`` rules).

The engine's feature contract is structural: every optional layer
(participation, faults, robustness, compression, telemetry, stragglers,
mesh/overlap, async cadences) must vanish WITHOUT RESIDUE when its knob is
off — zero extra state leaves (S201), and a step jaxpr *identical* to the
pre-feature factory build (S202) rather than merely numerically close.
What runtime uint8 bit-identity tests establish per trajectory, these
checks prove per structure, in seconds, on every committed spec.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.rules import Finding

#: the edits that switch every optional layer off — what remains is the
#: pre-feature baseline an unadorned factory call builds
BARE_EDITS = {
    # all three participation knobs: ``normalize()`` promotes a full
    # sampler with a nonzero clients_per_round (or a trace_path) back to
    # uniform/trace, so the bare form must clear the promotion triggers too
    "participation.sampler": "full",
    "participation.clients_per_round": 0,
    "participation.trace_path": None,
    "faults": None, "robustness": None, "compression": None,
    "telemetry": None, "stragglers": None,
    "execution.mesh": None, "execution.overlap": False,
    "execution.scatter_comm": False,
    "schedule.comm_every": (),
}


def bare_spec(exp):
    """``exp`` with every optional feature off (still validates)."""
    return exp.edit(**BARE_EDITS)


def step_jaxpr_str(init, step, batch_fn) -> str:
    """Canonical jaxpr text of one step on abstract state/batch — two
    builds of the same program print identically (constants appear as
    constvars, literals come from the shared config)."""
    import jax
    state = jax.eval_shape(init, jax.random.PRNGKey(0))
    batch = jax.eval_shape(batch_fn, jax.random.PRNGKey(0))
    return str(jax.make_jaxpr(step)(state, batch))


def jaxpr_diff(a: str, b: str) -> str:
    """First structural divergence of two jaxpr texts, for rule messages."""
    la, lb = a.splitlines(), b.splitlines()
    if len(la) != len(lb):
        pre = f"{len(la)} vs {len(lb)} jaxpr lines; "
    else:
        pre = ""
    for i, (x, y) in enumerate(zip(la, lb)):
        if x != y:
            return (f"{pre}first divergence at jaxpr line {i + 1}: "
                    f"{x.strip()!r} vs {y.strip()!r}")
    return pre + "one jaxpr is a prefix of the other"


def audit_state_slots(run) -> List[Finding]:
    """S201: FlatState optional slots present iff their feature is on."""
    import jax

    exp = run.spec
    where = f"spec {exp.algorithm.name}"
    if not hasattr(run.step, "spec"):
        return []                       # unfused path: no FlatState
    state = jax.eval_shape(run.init, jax.random.PRNGKey(0))
    cp = exp.compression
    expect = {
        "stale": run.participation is not None or exp.stragglers is not None,
        "retry": exp.faults is not None,
        "ef": (cp is not None and cp.topk_frac > 0
               and bool(cp.error_feedback)),
        "deadline": exp.stragglers is not None,
    }
    findings: List[Finding] = []
    for slot, on in expect.items():
        empty = getattr(state, slot) == ()
        if on and empty:
            findings.append(Finding(
                "S201", where,
                f"feature expects a `{slot}` state leaf but the built "
                f"state carries ()"))
        elif not on and not empty:
            findings.append(Finding(
                "S201", where,
                f"`{slot}` state leaf present with its feature off — "
                f"the zero-leaf contract is broken"))
    return findings


def reference_pair(exp, model):
    """The pre-feature baseline: the registered factory invoked with ONLY
    the core execution knobs — no participation/mesh/overlap/cadence/
    faults/robustness/compression/telemetry/stragglers kwargs at all."""
    from repro.api import registry
    from repro.api.build import federated_config

    entry = registry.get(exp.algorithm.name)
    _, factory_kw = entry.split_params(exp.algorithm.params_dict)
    ex = exp.execution
    return entry.factory(
        model, federated_config(exp), n_micro=ex.n_micro, remat=ex.remat,
        use_flash=ex.use_flash, use_lru_kernel=ex.use_lru_kernel,
        fuse_oracles=ex.fuse_oracles, fuse_storm=ex.fuse_storm,
        storm_block=ex.storm_block, **factory_kw)


def audit_bare_jaxpr(exp, cache: Optional[Dict[str, Any]] = None
                     ) -> List[Finding]:
    """S202: the all-features-off build of ``exp`` traces to a jaxpr
    identical to the pre-feature factory build.  ``cache`` (keyed by the
    bare spec's JSON) dedupes across committed specs sharing a bare form."""
    from repro.api.build import build

    bare = bare_spec(exp)
    key = bare.to_json()
    if cache is not None and key in cache:
        return list(cache[key])
    run = build(bare)
    findings: List[Finding] = []
    if hasattr(run.step, "spec"):
        got = step_jaxpr_str(run.init, run.step, run.batch_fn)
        ref_init, ref_step = reference_pair(bare, run.model)
        want = step_jaxpr_str(ref_init, ref_step, run.batch_fn)
        if got != want:
            findings.append(Finding(
                "S202", f"spec {exp.algorithm.name}",
                f"feature-off step is not the pre-feature baseline: "
                f"{jaxpr_diff(got, want)}"))
    if cache is not None:
        cache[key] = tuple(findings)
    return findings


def audit_telemetry_inert(exp) -> List[Finding]:
    """S203: events-only telemetry (metrics=()) is jaxpr-identical to
    telemetry=None — only specs carrying a telemetry block are checked."""
    from repro.api.build import build

    if exp.telemetry is None:
        return []
    run_ev = build(exp.edit(**{"telemetry.metrics": ()}))
    run_off = build(exp.edit(telemetry=None))
    if not (hasattr(run_ev.step, "spec") and hasattr(run_off.step, "spec")):
        return []
    a = step_jaxpr_str(run_ev.init, run_ev.step, run_ev.batch_fn)
    b = step_jaxpr_str(run_off.init, run_off.step, run_off.batch_fn)
    if a == b:
        return []
    return [Finding(
        "S203", f"spec {exp.algorithm.name}",
        f"events-only telemetry perturbs the step jaxpr: "
        f"{jaxpr_diff(a, b)}")]
