"""The rule registry of the static verifier.

Every check ``repro.analysis`` performs carries a :class:`Rule`: a stable
ID (``W1xx`` wire/collective, ``S2xx`` structure/state, ``L3xx`` source
lint), what passing it *proves* about the engine, and a fix-it message.
Findings reference rules by ID, the README's rule table is generated from
this registry, and source code can waive a lint rule per line with an
inline ``# analysis: ignore[L3xx]`` comment (wire/structure rules have no
escape hatch — they are contracts of the built artifact, not of style).
"""
from __future__ import annotations

from typing import NamedTuple


class Rule(NamedTuple):
    id: str
    name: str
    proves: str         # the invariant a clean pass establishes
    fixit: str          # what to do when the rule fires


class Finding(NamedTuple):
    """One violation: ``where`` is ``spec-path`` or ``file:line``."""
    rule: str
    where: str
    message: str

    def __str__(self) -> str:
        r = RULES[self.rule]
        return (f"{self.rule} {r.name} {self.where}: {self.message}\n"
                f"    fix: {r.fixit}")


_ALL = (
    Rule("W101", "collective-count",
         "the step's jaxpr holds exactly the collectives the analytic comm "
         "plan implies — one sliced reduction per communicated merged run "
         "per reduction event (the paper's O(eps^-1) single-collective "
         "round; ROADMAP pod-mesh gate)",
         "a reduction was added/dropped outside comm_buffers, or the "
         "expected-collective model in repro.analysis.collectives no "
         "longer mirrors flat._client_mean_masked_sharded — update the "
         "one that changed"),
    Rule("W102", "private-on-wire",
         "no private/non-participant tile ever enters a collective operand "
         "(PRIVATE sections are bit-identical by construction)",
         "a collective operand's size matches a private section run — "
         "slice the reduction around the private extents "
         "(flat._section_runs) instead of communicating them"),
    Rule("W103", "wire-dtype",
         "a quantized policy moves its reduction bytes in the narrow dtype "
         "on the wire (no silent f32 fallback, a 4x comm regression)",
         "the compiled collectives re-widened — check "
         "flat._wire_allreduce lowering and the backend's narrow-dtype "
         "reduce support (CPU re-widens bf16; int8 never promotes)"),
    Rule("W104", "wire-bytes",
         "the compiled comm subprogram's collective bytes equal the "
         "analytic telemetry.comm byte model exactly (what `comm` events "
         "bill is what the wire moves)",
         "the byte model and the lowering disagree — reconcile "
         "telemetry.comm.comm_plan / federation.compression with the "
         "reduction in flat.py"),
    Rule("W105", "resharding",
         "no resharding collectives (all-to-all / collective-permute / "
         "unplanned all-gather) sit inside the comm subprogram between "
         "oracle and fused update (ROADMAP: 'zero resharding ops')",
         "a layout change crept into the reduction path — keep the "
         "shard-major flat layout end to end (repro.optim.flat docstring)"),
    Rule("S201", "state-slots",
         "FlatState optional slots (stale/retry/ef/deadline) are () "
         "exactly when their feature is off — pre-feature checkpoints and "
         "jit caches keep their structure (the zero-leaf contract)",
         "a feature leaked a state leaf into feature-off builds — gate "
         "the slot on its knob in sequences.make_engine.init_state"),
    Rule("S202", "bare-jaxpr",
         "a spec with every optional layer off traces to a jaxpr "
         "structurally identical to the pre-feature factory build — "
         "feature-off is the LITERAL baseline path, not a near miss",
         "a default changed or a feature stopped compiling away — diff "
         "the two jaxprs (repro.analysis.structure.jaxpr_diff) and gate "
         "the divergent op on its feature knob"),
    Rule("S203", "telemetry-inert",
         "events-only telemetry (metrics=[]) traces to the identical "
         "jaxpr as telemetry=None — observability never perturbs a "
         "trajectory",
         "a metrics computation escaped the `if not tel_groups` gate in "
         "sequences — keep telemetry reads off the traced path"),
    Rule("L301", "nondet-time",
         "engine source draws no wall-clock nondeterminism (time.time, "
         "perf_counter, datetime.now, os.urandom) — trajectories are a "
         "pure function of (spec, seed, step)",
         "compute it from the step counter, or justify driver-side "
         "timing with `# analysis: ignore[L301]` (observability only, "
         "never traced)"),
    Rule("L302", "nondet-random",
         "no stdlib/NumPy global-state RNG (random.*, np.random.*) — all "
         "randomness flows through jax.random keys derived from the spec "
         "seed",
         "use jax.random with a key folded from the spec seed and the "
         "step/round counter"),
    Rule("L303", "host-sync",
         "engine code never synchronizes a traced value to the host "
         "(.item(), float()/int() on jax values, np.asarray on tracers) — "
         "steps stay fully async and jit-safe",
         "keep the value on-device (jnp), or justify an untraced "
         "reporting helper with `# analysis: ignore[L303]`"),
    Rule("L304", "prng-fold",
         "round randomness in the engine derives from fold_in on a "
         "spec-seed key — never a carried split chain or an ad-hoc key — "
         "so resume and rollback-retry are bit-exact",
         "replace jax.random.split / ad-hoc PRNGKey with "
         "fold_in(PRNGKey(spec.seed), round_idx); init-time key fans may "
         "justify `# analysis: ignore[L304]`"),
    Rule("L305", "spec-frozen",
         "every *Spec/*Config dataclass is frozen — specs are hashable "
         "jit-cache keys and cannot drift after build",
         "declare it @dataclass(frozen=True)"),
    Rule("L306", "mutable-default",
         "no mutable default argument values ([], {}, set()) — call-to-"
         "call aliasing cannot corrupt build state",
         "default to None (or a tuple) and materialize inside the "
         "function"),
)

RULES = {r.id: r for r in _ALL}
LINT_RULES = tuple(r.id for r in _ALL if r.id.startswith("L"))
