"""CLI of the static verifier — see the package docstring.

Device forcing happens HERE, before any jax import: building a sharded
spec needs as many host devices as its mesh, and the compressed-spec wire
probe needs two, so the flag is computed from the spec JSONs and exported
before the checkers (and jax underneath them) load.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import List


def _force_devices(spec_paths: List[str]) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return                          # caller chose — respect it
    need = 8                            # covers the debug meshes + probe
    for p in spec_paths:
        try:
            with open(p) as fh:
                d = json.load(fh)
        except (OSError, ValueError):
            continue
        mesh = (d.get("execution") or {}).get("mesh")
        if isinstance(mesh, (list, tuple)):
            n = 1
            for v in mesh:
                n *= int(v)
            need = max(need, n)
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={need}".strip())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="statically verify Experiment specs and lint the "
                    "source — no training, no data")
    ap.add_argument("--experiment", action="append", default=[],
                    metavar="EXP_JSON", help="verify one spec (repeatable)")
    ap.add_argument("--all", dest="all_dir", metavar="DIR",
                    help="verify every *.json under DIR")
    ap.add_argument("--lint", action="append", default=[], metavar="PATH",
                    help="lint .py files/trees (repeatable)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the comm-subprogram compile (jaxpr and "
                         "structure checks only)")
    args = ap.parse_args(argv)

    specs = list(args.experiment)
    if args.all_dir:
        specs += sorted(glob.glob(os.path.join(args.all_dir, "*.json")))
    if not specs and not args.lint:
        ap.error("nothing to do — pass --experiment/--all and/or --lint")

    from repro.analysis.rules import Finding
    failures: List[Finding] = []
    errors = 0

    if args.lint:
        from repro.analysis.lint import lint_paths
        lf = lint_paths(args.lint)
        failures += lf
        print(f"lint {' '.join(args.lint)}: "
              f"{'OK' if not lf else f'{len(lf)} finding(s)'}")

    if specs:
        _force_devices(specs)
        from repro.analysis.verify import verify_experiment
        from repro.api import Experiment
        bare_cache: dict = {}
        for p in specs:
            try:
                f, notes = verify_experiment(
                    Experiment.load(p), where=p, hlo=not args.no_hlo,
                    bare_cache=bare_cache)
            except Exception as e:      # build/validate/trace failure
                errors += 1
                print(f"ERROR {p}: {type(e).__name__}: {e}")
                continue
            failures += f
            status = "OK" if not f else f"FAIL ({len(f)} finding(s))"
            print(f"{status} {p}: " + "; ".join(notes))

    for f in failures:
        print(f)
    n = len(failures)
    print(f"repro.analysis: {len(specs)} spec(s), "
          f"{n} finding(s), {errors} error(s)")
    return 1 if (n or errors) else 0


if __name__ == "__main__":
    sys.exit(main())
