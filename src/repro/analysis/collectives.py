"""Collective/wire auditor (the ``W1xx`` rules).

Two surfaces, one expected model:

* **jaxpr** — trace the built ``run.step`` on abstract state/batch and
  collect every collective primitive (recursing through ``cond``/``scan``/
  ``shard_map`` sub-jaxprs).  The expected multiset of
  ``(primitive, axes, grouped, dtype, operand elems)`` entries is derived
  from the SAME section-extent merge the reduction uses
  (``flat._section_runs``) — one sliced reduction per communicated merged
  run per reduction event, plus the policy's stats collectives (weighted
  ``wsum``, int8 scale exchange, robust screen/clip/trim).  Nothing here
  is combined or DCE'd, so counts and operand sizes are exact (W101), and
  an unexplained operand whose size matches a private run is private
  state on the wire (W102).

* **HLO** — lower and compile the engine's communication-only subprogram
  (``run.step.comm_fn`` — no oracle, no fused update) and parse its
  collectives (``launch.hlo_stats.collective_bytes``).  XLA may combine
  all-reduces, so the HLO contract is byte-exact per dtype (W104), the
  narrow-dtype coverage of a quantized policy (W103 — the audit dryrun
  previously carried as ``_check_compressed_collectives``, now shared from
  here), and zero resharding ops between oracle and fused update (W105).
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.rules import Finding

#: jaxpr primitive names treated as collectives (both historical spellings
#: of the scatter reduction, and ``psum2`` — what ``lax.psum`` rebinds to
#: inside a ``check_rep=True`` shard_map after the replication rewrite)
COLLECTIVE_PRIMS = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
                    "psum_scatter", "reduce_scatter"}
_CANON = {"reduce_scatter": "psum_scatter", "psum2": "psum"}

#: jax dtype name -> HLO dtype token (the hlo_stats keying)
_HLO_DTYPE = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
              "int8": "s8", "uint8": "u8", "int32": "s32",
              "float64": "f64", "bool": "pred"}
_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
                "uint8": 1, "int32": 4, "float64": 8, "bool": 1}

#: one expected-collective entry: (prim, axes, grouped, dtype, elems)
Entry = Tuple[str, tuple, bool, str, int]


# ---------------------------------------------------------------------------
# jaxpr side: what the traced step actually binds
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for u in vs:
            inner = getattr(u, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner
            elif hasattr(u, "eqns"):
                yield u


def _walk(jaxpr, acc: Counter) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            p = eqn.params
            axes = p.get("axes", p.get("axis_name", ()))
            if not isinstance(axes, tuple):
                axes = (axes,)
            grouped = p.get("axis_index_groups") is not None
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is None or not hasattr(aval, "shape"):
                    continue
                elems = 1
                for d in aval.shape:
                    elems *= int(d)
                acc[(_CANON.get(name, name), tuple(str(a) for a in axes),
                     grouped, str(aval.dtype), elems)] += 1
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, acc)


def collect_collectives(fn, *abstract_args) -> Counter:
    """The multiset of collective-primitive operands in ``fn``'s jaxpr."""
    import jax
    acc: Counter = Counter()
    _walk(jax.make_jaxpr(fn)(*abstract_args).jaxpr, acc)
    return acc


# ---------------------------------------------------------------------------
# expected side: mirror of flat._client_mean_masked_sharded
# ---------------------------------------------------------------------------

class _Expect:
    """Accumulates expected entries while mirroring one reduction call."""

    def __init__(self, *, data_axis: str, model_axis: str, data_size: int,
                 use_scatter: bool, m_local: int):
        self.c: Counter = Counter()
        self.da, self.ma = (data_axis,), (model_axis,)
        self.nds = data_size
        self.use_scatter = use_scatter
        self.m_local = m_local

    def psum(self, elems: int, dtype: str = "float32", *, axes=None,
             grouped: bool = False) -> None:
        self.c[("psum", axes or self.da, grouped, dtype, elems)] += 1

    def allreduce(self, elems: int, dtype: str = "float32", *,
                  grouped: bool = False) -> None:
        # flat._allreduce: psum_scatter + all_gather iff use_scatter, no
        # groups and the run tiles evenly over the data axis
        if self.use_scatter and not grouped and elems % self.nds == 0:
            self.c[("psum_scatter", self.da, False, dtype, elems)] += 1
            self.c[("all_gather", self.da, False, dtype,
                    elems // self.nds)] += 1
        else:
            self.c[("psum", self.da, grouped, dtype, elems)] += 1

    def mean_run(self, L: int, dtype: str, *, weighted: bool, comp: bool,
                 grouped: bool, block: int, compress, robust,
                 guarded: bool) -> None:
        """One communicated merged run of ``L`` elements — the collectives
        of flat._client_mean_masked_sharded's body for it."""
        if guarded:                       # _robust_mean_sharded
            if robust is None:
                self.psum(1)              # wsum (faulty unguarded mean)
                self.allreduce(L, dtype)
                return
            if robust.screen:
                self.psum(self.m_local, axes=self.ma)      # nonfinite
            self.psum(self.m_local, axes=self.ma)          # row norms sq
            if robust.screen and robust.z_thresh > 0:
                self.psum(1)              # cnt
                self.psum(1)              # mu
                self.psum(1)              # sd
            self.psum(1)                  # wsum_eff
            if robust.aggregator == "trim":
                self.c[("all_gather", self.da, False, "float32",
                        self.m_local * L)] += 1
                self.psum(1)              # nh
            elif robust.aggregator == "clip":
                self.psum(1)              # tau
                self.allreduce(L, dtype)
            else:
                self.allreduce(L, dtype)
            return
        if comp and compress is not None:
            if weighted:
                self.psum(1, grouped=grouped)              # wsum
            if compress.quant == "int8":
                self.psum(L // block, grouped=grouped)     # scale exchange
                self.allreduce(L, "int8", grouped=grouped)
            elif compress.quant == "bf16":
                self.allreduce(L, "bfloat16", grouped=grouped)
            else:                         # top-k only: dense f32 wire
                self.allreduce(L, "float32", grouped=grouped)
            return
        if weighted:
            self.psum(1, grouped=grouped)                  # wsum
        self.allreduce(L, dtype, grouped=grouped)


def _weight_sentinels(aspec, participation, weighted: bool) -> tuple:
    """Per-section weight identities mirroring sequences.staleness_weights
    (one shared array per distinct discount α — what _section_runs merges
    on)."""
    from repro.optim import sequences as seqs
    n = len(aspec.sequences)
    if not weighted:
        return (None,) * n
    alphas = seqs.effective_staleness(aspec, participation)
    if all(a == 1.0 for a in alphas):
        w = object()
        return (w,) * n
    by_alpha = {a: object() for a in set(alphas)}
    return tuple(by_alpha[a] for a in alphas)


def expected_step_collectives(run) -> Tuple[Counter, Dict[str, Any]]:
    """(expected multiset, info) for the full step of a built sharded run
    — empty off-mesh (the unsharded reduction is collective-free).

    ``info`` carries ``private_elems`` (merged private-run lengths, for
    W102 classification), ``comm_elems`` (per-event communicated payload
    elems, one shard chunk) and ``events`` (reduction events per step)."""
    from repro.optim import flat
    from repro.optim.sequences import HIERARCHICAL, PRIVATE

    step = run.step
    flat_spec, aspec = step.spec, step.aspec
    exp = run.spec
    info: Dict[str, Any] = {"events": 0, "comm_elems": 0,
                            "private_elems": set()}
    if run.mesh is None:
        return Counter(), info
    # the default axis names of make_shard_ctx — custom-named prebuilt
    # ShardCtx meshes are not reachable from an Experiment spec
    data_axis, model_axis = "data", "model"
    data_size = int(run.mesh.shape[data_axis])
    m_local = exp.problem.num_clients // data_size
    use_scatter = bool(exp.execution.scatter_comm)
    weighted = (step.participation is not None or step.faults is not None
                or step.stragglers is not None)
    guarded = step.faults is not None or step.robustness is not None
    robust = None
    if step.robustness is not None:
        robust = flat.RobustCfg(
            aggregator=step.robustness.aggregator,
            screen=step.robustness.screen,
            z_thresh=step.robustness.z_thresh,
            clip_factor=step.robustness.clip_factor,
            trim_frac=step.robustness.trim_frac)
    compress = step.compression
    comm_secs = tuple(q.section for q in aspec.sequences
                      if q.comm != PRIVATE)
    comp_of_sec = None
    if compress is not None:
        csecs = set(compress.sections or comm_secs)
        comp_of_sec = tuple(nm in csecs for nm in flat_spec.sections)
    w_of_sec = _weight_sentinels(aspec, step.participation, weighted)
    policies = aspec.policies
    cadence = tuple(q.comm_every for q in aspec.sequences)
    n = len(policies)
    hier_on = exp.schedule.hierarchy_period > 0
    events = 2 if aspec.has_momentum else 1
    info["events"] = events

    exp_c = _Expect(data_axis=data_axis, model_axis=model_axis,
                    data_size=data_size, use_scatter=use_scatter,
                    m_local=m_local)

    def one_call(modes):
        for grp in flat_spec.groups:
            dtype = str(grp.dtype)
            for mode, w, a, stop, comp in flat._section_runs(
                    grp, 1, modes, w_of_sec, comp_of_sec):
                L = stop - a
                if mode == "none":
                    info["private_elems"].add(L)
                    continue
                exp_c.mean_run(L, dtype, weighted=w is not None, comp=comp,
                               grouped=(mode == "group"), block=grp.block,
                               compress=compress, robust=robust,
                               guarded=guarded)

    for _ in range(events):
        for c in sorted(set(cadence)):
            live = tuple(i for i in range(n)
                         if cadence[i] == c and policies[i] != PRIVATE)
            if not live:
                continue
            modes_comm = tuple("mean" if i in live else "none"
                               for i in range(n))
            one_call(modes_comm)
            if hier_on and any(policies[i] == HIERARCHICAL for i in live):
                modes_local = tuple(
                    ("group" if policies[i] == HIERARCHICAL else "mean")
                    if i in live else "none" for i in range(n))
                one_call(modes_local)

    # per-event communicated payload elems (cadence-1 view, one chunk)
    modes_all = tuple("mean" if p != PRIVATE else "none" for p in policies)
    for grp in flat_spec.groups:
        for mode, _, a, stop, _ in flat._section_runs(
                grp, 1, modes_all, w_of_sec, comp_of_sec):
            if mode != "none":
                info["comm_elems"] += stop - a
    return exp_c.c, info


def _fmt_entry(e: Entry, k: int) -> str:
    prim, axes, grouped, dtype, elems = e
    g = " grouped" if grouped else ""
    return f"{k}x {prim}[{dtype} x{elems} over {'/'.join(axes)}{g}]"


def audit_step_collectives(run) -> List[Finding]:
    """W101/W102 on the full-step jaxpr of a built run, cross-checked
    against the analytic telemetry.comm plan."""
    import jax

    from repro.telemetry.comm import comm_plan

    where = f"spec {run.spec.algorithm.name}"
    state = jax.eval_shape(run.init, jax.random.PRNGKey(0))
    batch = jax.eval_shape(run.batch_fn, jax.random.PRNGKey(0))
    actual = collect_collectives(run.step, state, batch)
    expected, info = expected_step_collectives(run)
    findings: List[Finding] = []

    plan = comm_plan(run.step.spec, run.step.aspec, run.spec.compression)
    if plan is not None and run.mesh is not None:
        shards = run.step.spec.shards
        plan_elems = sum(e for _, e, _, _ in plan.sections)
        if plan.reductions != info["events"] or \
                plan_elems != info["comm_elems"] * shards:
            findings.append(Finding(
                "W101", where,
                f"analytic comm plan disagrees with the section-extent "
                f"walk: plan {plan.reductions} reductions x {plan_elems} "
                f"elems vs {info['events']} events x "
                f"{info['comm_elems'] * shards} elems"))

    if actual == expected:
        return findings
    extra = actual - expected
    missing = expected - actual
    for e, k in sorted(extra.items()):
        prim, axes, grouped, dtype, elems = e
        if elems in info["private_elems"]:
            findings.append(Finding(
                "W102", where,
                f"collective operand matches a PRIVATE section run: "
                f"{_fmt_entry(e, k)}"))
        else:
            findings.append(Finding(
                "W101", where,
                f"unplanned collective in the step jaxpr: "
                f"{_fmt_entry(e, k)}"))
    for e, k in sorted(missing.items()):
        findings.append(Finding(
            "W101", where,
            f"planned collective missing from the step jaxpr: "
            f"{_fmt_entry(e, k)}"))
    return findings


# ---------------------------------------------------------------------------
# HLO side: the compiled comm-only subprogram
# ---------------------------------------------------------------------------

def check_compressed_collectives(exp, flat_spec,
                                 coll: Dict[str, Any]) -> Dict[str, Any]:
    """Audit a compressed spec's compiled collectives against the analytic
    wire model: a quantized policy must move the reduction bytes in the
    narrow dtype.  Raises ``RuntimeError`` if it lowered to f32 collectives
    instead (fail LOUDLY — that is a silent 4x comm regression).

    The comparison is per-dtype, not total: model-parallel compute
    collectives (activation all-reduces, all-to-alls, permutes)
    legitimately stay f32, so the criterion is that the narrow-dtype bytes
    cover what the compressed reductions analytically move — the
    per-shard-chunk extents of every compressed section at the quant's
    value width, for BOTH the variables and the momentum reduction of each
    comm event.  Shared by ``launch.dryrun`` (full-step HLO) and the W103
    audit here (comm-subprogram HLO) — one byte model, no drift."""
    from repro.optim.sequences import PRIVATE, SPECS
    from repro.telemetry.comm import compressed_chunk_elems
    cp = exp.compression
    narrow = {"bf16": ("bf16",), "int8": ("s8", "u8")}[cp.quant]
    aspec = SPECS[exp.algorithm.name]
    elems = compressed_chunk_elems(flat_spec, aspec, cp)
    vbytes = {"bf16": 2, "int8": 1}[cp.quant]
    reductions = 2 if aspec.has_momentum else 1
    expected = reductions * elems * vbytes      # one shard chunk each
    by_dtype = coll.get("bytes_by_dtype", {})
    narrow_b = sum(by_dtype.get(d, 0) for d in narrow)
    if narrow_b < 0.9 * expected:
        hint = ""
        if cp.quant == "bf16":
            hint = (" (note: the host CPU backend has no native bf16 "
                    "reduce and re-widens bf16 all-reduces to f32 — the "
                    "bf16 wire guarantee holds on TPU only; int8 moves "
                    "integer collectives, which no backend promotes)")
        raise RuntimeError(
            f"compressed spec (quant={cp.quant!r}) lowered to f32 "
            f"collectives: the narrow-dtype collective bytes "
            f"({narrow_b} B in {narrow}) do not cover the analytic wire "
            f"model of the compressed reductions ({expected} B = "
            f"{reductions} reductions x {elems} elems x {vbytes} B) — "
            f"dtype breakdown: {by_dtype}{hint}")
    return {"ok": True, "narrow_bytes": narrow_b,
            "expected_bytes": expected, "bytes_by_dtype": by_dtype}


def expected_wire_bytes(expected: Counter, data_size: int) -> Dict[str, int]:
    """HLO bytes-by-dtype the expected multiset implies.  Entries carry
    jaxpr OPERAND elems; ``hlo_stats`` sums RESULT bytes, so the scattered
    reduction shrinks by the axis size and the gather grows by it."""
    out: Dict[str, int] = {}
    for (prim, _, _, dtype, elems), k in expected.items():
        n = elems
        if prim == "psum_scatter":
            n = elems // data_size
        elif prim == "all_gather":
            n = elems * data_size
        hd = _HLO_DTYPE.get(dtype, dtype)
        out[hd] = out.get(hd, 0) + k * n * _DTYPE_BYTES[dtype]
    return out


def audit_wire(run, coll: Optional[Dict[str, Any]] = None) -> List[Finding]:
    """W103/W104/W105 on the compiled communication-only subprogram.

    ``coll`` injects precomputed ``hlo_stats.collective_bytes`` output
    (tests); otherwise the comm subprogram is lowered and compiled here."""
    import jax

    where = f"spec {run.spec.algorithm.name}"
    comm_fn = getattr(run.step, "comm_fn", None)
    if run.mesh is None or comm_fn is None:
        return []
    expected, _ = expected_step_collectives(run)
    if coll is None:
        from repro.launch.hlo_stats import collective_bytes
        state = jax.eval_shape(run.init, jax.random.PRNGKey(0))
        sh = run.shardings(state)
        with run.mesh:
            compiled = jax.jit(comm_fn, in_shardings=(sh,),
                               out_shardings=sh).lower(state).compile()
        coll = collective_bytes(compiled.as_text())
    findings: List[Finding] = []
    counts = coll.get("counts", {})

    # W105: resharding ops have no business between oracle and update
    for op in ("all-to-all", "collective-permute"):
        if counts.get(op, 0):
            findings.append(Finding(
                "W105", where,
                f"{counts[op]} {op} op(s) in the comm subprogram "
                f"({coll['bytes'][op]} B) — the reduction path resharded"))
    exp_gathers = sum(k for (p, *_), k in expected.items()
                      if p == "all_gather")
    if exp_gathers == 0 and counts.get("all-gather", 0):
        findings.append(Finding(
            "W105", where,
            f"{counts['all-gather']} all-gather op(s) in the comm "
            f"subprogram but the plan has none (no scatter-comm, no "
            f"trimmed mean)"))

    # W103: quantized policies must keep the narrow dtype on the wire
    cp = run.spec.compression
    if cp is not None and cp.quant is not None:
        try:
            check_compressed_collectives(run.spec, run.step.spec, coll)
        except RuntimeError as e:
            findings.append(Finding("W103", where, str(e)))

    # W104: byte-exact per dtype (XLA may combine ops, never bytes).
    # CPU re-widens bf16 reduces (documented W103 hint) — skip exactness
    # there for bf16 policies.
    if not (cp is not None and cp.quant == "bf16"
            and jax.default_backend() == "cpu"):
        want = expected_wire_bytes(expected,
                                   int(run.mesh.shape["data"]))
        got = dict(coll.get("bytes_by_dtype", {}))
        if want != got:
            findings.append(Finding(
                "W104", where,
                f"compiled comm-subprogram collective bytes "
                f"{got} != analytic model {want}"))
    return findings
