#!/usr/bin/env bash
# Smoke gate: the tier-1 suite, a spec-driven smoke-train of every federated
# algorithm (committed repro.api Experiment JSONs), checkpoint-resume from an
# embedded spec, and a fast benchmark pass (with the machine-readable kernel
# perf artifact, BENCH_kernels.json).
#
#   ./scripts/check.sh            # full tier-1 + smoke trains + benchmarks
#   ./scripts/check.sh --bench    # benchmarks only
#   ./scripts/check.sh --smoke    # smoke trains only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--bench" && "${1:-}" != "--smoke" ]]; then
    python -m pytest -x -q
fi

if [[ "${1:-}" != "--bench" ]]; then
    # every committed Experiment spec must parse and validate
    python -m repro.api.validate experiments/*.json

    # static invariant verifier (fast pre-train gate): every committed
    # spec's traced collectives must equal the analytic comm plan, wire
    # bytes/dtypes must match the compression policy, feature-off builds
    # must be jaxpr-identical to the baseline, and the source must pass
    # the determinism lint — failures name the rule ID and the offending
    # spec / file:line before any training time is spent
    python -m repro.analysis --all experiments/ --lint src/repro

    # every algorithm end-to-end from its committed declarative spec (the
    # flat-substrate engine with fused oracles; fedbioacc_local's spec also
    # exercises 2-of-4 uniform participation) — the exact path
    # `--experiment exp.json` users run
    for algo in fedbio fedbioacc fedbio_local fedbioacc_local fedavg; do
        echo "smoke-train: $algo (from experiments/$algo.json)"
        python -m repro.launch.train --experiment "experiments/$algo.json" \
            --log-every 1
    done

    # checkpoint-resume from the embedded spec: train half the run with a
    # checkpoint, then continue it with --resume and ZERO re-specified flags
    ckpt="$(mktemp -d)"
    echo "smoke-train: fedbioacc spec + checkpoint @ 3, resume to 4"
    python -m repro.launch.train --experiment experiments/fedbioacc.json \
        --steps 4 --log-every 2 --ckpt-dir "$ckpt" --ckpt-every 3
    python -m repro.launch.train --resume "$ckpt" --log-every 1
    rm -rf "$ckpt"

    # multi-device: the sharded flat substrate on a 4x2 debug mesh (8 forced
    # host devices) — shard_map fused launches, real psum reductions, and
    # the comm/compute overlap schedule, from the committed sharded spec
    echo "smoke-train: fedbioacc (sharded 4x2 mesh + overlap, from spec)"
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.train \
        --experiment experiments/fedbioacc_sharded_overlap.json --log-every 2

    # fault tolerance: NaN + byzantine injection with the screened clip
    # aggregator and rollback budget, from the committed faulty spec — the
    # run must stay finite (the unguarded engine demonstrably diverges on
    # this spec; see tests/test_fault_tolerance.py)
    echo "smoke-train: fedbioacc_faulty (NaN+byzantine, clip + rollback)"
    python -m repro.launch.train \
        --experiment experiments/fedbioacc_faulty.json --log-every 1

    # compressed communication: per-tile-scaled int8 quantization + 10%
    # top-k sparsified sends with error feedback, from the committed
    # compressed spec — the dryrun HLO audit for the same policy lives in
    # tests/test_compressed_comm.py and the bytes/convergence trade-off in
    # BENCH_kernels.json:compressed_comm
    echo "smoke-train: fedbioacc_int8_topk (int8 + top-k 10% + EF)"
    python -m repro.launch.train \
        --experiment experiments/fedbioacc_int8_topk.json --log-every 1

    # telemetry: train the committed telemetry spec with an event-stream
    # sink, then the stream must parse, be schema-valid, carry the expected
    # event types, reconcile every comm event's wire bytes against the
    # analytic model rebuilt from its embedded spec, and show the STORM
    # momentum norm trending down (the hypergradient-estimation proxy)
    tdir="$(mktemp -d)"
    echo "smoke-train: fedbioacc_telemetry (event stream -> validate)"
    python -m repro.launch.train \
        --experiment experiments/fedbioacc_telemetry.json --log-every 2 \
        --telemetry-sink "$tdir/events.jsonl"
    python -m repro.telemetry.validate "$tdir/events.jsonl" \
        --expect run_start,metrics,comm,span,run_end \
        --trend-decreasing mom_norm/u
    python -m repro.launch.metrics "$tdir/events.jsonl" --table
    # compressed run: ef_norm/quant_err metrics in-band, comm events billed
    # at the compressed wire rate — reconciled against the same model
    echo "smoke-train: fedbioacc_int8_topk + telemetry sink -> validate"
    python -m repro.launch.train \
        --experiment experiments/fedbioacc_int8_topk.json --log-every 2 \
        --telemetry-sink "$tdir/events_topk.jsonl"
    python -m repro.telemetry.validate "$tdir/events_topk.jsonl" \
        --expect run_start,metrics,comm,run_end
    # rollback audit trail: all-NaN senders reaching an UNSCREENED mean must
    # roll back, exhaust the retry budget (non-zero exit), and leave
    # rollback + retry_budget_exhausted events on the stream
    python - "$tdir/faulty_noscreen.json" <<'PY'
import sys
from repro.api import Experiment
exp = Experiment.load("experiments/fedbioacc_faulty.json").edit(**{
    "faults.nan_rate": 1.0, "schedule.steps": 6,
    "robustness.screen": False, "robustness.aggregator": "mean"})
open(sys.argv[1], "w").write(exp.to_json())
PY
    echo "smoke-train: faulty no-screen -> rollback events (expected fail)"
    if python -m repro.launch.train \
        --experiment "$tdir/faulty_noscreen.json" --log-every 2 \
        --telemetry-sink "$tdir/events_rollback.jsonl"; then
        echo "ERROR: retry-budget run exited 0"; exit 1
    fi
    python -m repro.telemetry.validate "$tdir/events_rollback.jsonl" \
        --expect rollback,retry_budget_exhausted,run_end
    # stragglers: elastic rounds (deadline + quorum + over-provisioned
    # uniform sampling) from the committed straggler spec — the stream must
    # carry deadline events and pass the straggler invariants (arrivals >=
    # quorum on every accepted round, per-segment rounds increasing)
    echo "smoke-train: fedbioacc_straggler (elastic rounds -> validate)"
    python -m repro.launch.train \
        --experiment experiments/fedbioacc_straggler.json --log-every 2 \
        --telemetry-sink "$tdir/events_straggler.jsonl"
    python -m repro.telemetry.validate "$tdir/events_straggler.jsonl" \
        --expect run_start,metrics,deadline,run_end
    rm -rf "$tdir"

    # crash auto-resume: hard-kill the run mid-way (after the step-2
    # checkpoint), then the --max-restarts supervisor resumes it from the
    # atomic checkpoint and completes — the kill-mid-run drill end-to-end
    ckpt="$(mktemp -d)"
    echo "smoke-train: fedbioacc kill @ step 3 -> auto-resume to 4"
    python -m repro.launch.train --experiment experiments/fedbioacc.json \
        --steps 4 --log-every 2 --ckpt-dir "$ckpt" --ckpt-every 2 \
        --max-restarts 2 --restart-backoff 0.2 --crash-at-step 3
    rm -rf "$ckpt"
fi

if [[ "${1:-}" != "--smoke" ]]; then
    python -m benchmarks.run --fast --json
fi
echo "check.sh: OK"
