#!/usr/bin/env bash
# Smoke gate: the tier-1 suite plus a fast benchmark pass (with the
# machine-readable kernel perf artifact, BENCH_kernels.json).
#
#   ./scripts/check.sh            # full tier-1 + fast benchmarks
#   ./scripts/check.sh --bench    # benchmarks only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--bench" ]]; then
    python -m pytest -x -q
fi
python -m benchmarks.run --fast --json
echo "check.sh: OK"
