#!/usr/bin/env bash
# Smoke gate: the tier-1 suite, a fused smoke-train of every federated
# algorithm, and a fast benchmark pass (with the machine-readable kernel
# perf artifact, BENCH_kernels.json).
#
#   ./scripts/check.sh            # full tier-1 + smoke trains + benchmarks
#   ./scripts/check.sh --bench    # benchmarks only
#   ./scripts/check.sh --smoke    # smoke trains only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--bench" && "${1:-}" != "--smoke" ]]; then
    python -m pytest -x -q
fi

if [[ "${1:-}" != "--bench" ]]; then
    # every algorithm end-to-end on the flat substrate (sequence-spec engine:
    # fused STORM/heavy-ball updates + section-masked communication) with the
    # fused oracles on — the exact path `--fuse-storm --fuse-oracles` users run
    for algo in fedbio fedbioacc fedbio_local fedbioacc_local fedavg; do
        echo "smoke-train: $algo (fused)"
        python -m repro.launch.train --arch mamba2-130m --reduced \
            --algo "$algo" --steps 2 --clients 2 --per-client 1 --seq 32 \
            --local-steps 2 --neumann-q 2 --log-every 1 \
            --fuse-storm --fuse-oracles
    done
    # partial participation through the participation engine: 4-of-8 uniform
    # client sampling, gated fused launches + participants-only reductions
    for algo in fedbioacc fedbioacc_local; do
        echo "smoke-train: $algo (fused, 4-of-8 participation)"
        python -m repro.launch.train --arch mamba2-130m --reduced \
            --algo "$algo" --steps 2 --clients 8 --clients-per-round 4 \
            --per-client 1 --seq 32 --local-steps 2 --neumann-q 2 \
            --log-every 1 --fuse-storm --fuse-oracles
    done
    # multi-device: the sharded flat substrate on a 4x2 debug mesh (8 forced
    # host devices) — shard_map fused launches, real psum reductions, and
    # the comm/compute overlap schedule, for a few communication rounds
    echo "smoke-train: fedbioacc (fused, sharded 4x2 mesh, overlap)"
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.train --arch mamba2-130m --reduced \
        --algo fedbioacc --steps 4 --clients 4 --per-client 1 --seq 32 \
        --local-steps 2 --log-every 2 --fuse-storm --fuse-oracles \
        --mesh 4,2 --overlap
fi

if [[ "${1:-}" != "--smoke" ]]; then
    python -m benchmarks.run --fast --json
fi
echo "check.sh: OK"
